"""Train an expert end-to-end: ~100M-parameter dense model, a few hundred
steps with checkpoint/restart (the CoE story: experts are trained/fine-tuned
independently, then registered into the composition).

    PYTHONPATH=src python examples/train_expert.py --steps 200
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, make_source
from repro.distributed import stepfn
from repro.launch.mesh import single_device_mesh
from repro.models import get_model
from repro.optim import AdamWConfig, init_opt_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_expert_ckpt")
    args = ap.parse_args()

    # ~100M-param llama-style expert
    cfg = dataclasses.replace(
        get_config("samba-coe-expert-7b"),
        name="expert-100m", n_layers=8, d_model=512, n_heads=8, n_kv_heads=8,
        head_dim=64, d_ff=1536, vocab_size=32000, attn_chunk=128)
    model = get_model(cfg)
    print(f"training {cfg.name}: {cfg.n_params()/1e6:.1f}M params")

    mesh = single_device_mesh()
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=20, decay_steps=args.steps)
    step_fn, state_sh, _ = stepfn.make_train_step(cfg, mesh, opt_cfg)
    source = make_source(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.seq,
                                    global_batch=args.batch))
    ckpt = CheckpointManager(args.ckpt, keep=2)

    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        state = jax.device_put({"params": params,
                                "opt": init_opt_state(params)}, state_sh)
        restored, start = ckpt.restore_state(state, state_sh)
        if restored is not None:
            state = restored
            print(f"resumed from step {start}")
        for step in range(start, args.steps):
            batch = jax.tree.map(jnp.asarray, source.batch_at(step))
            state, metrics = step_fn(state, batch)
            if step % 20 == 0 or step == args.steps - 1:
                print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                      f"lr={float(metrics['lr']):.2e}")
            if (step + 1) % 100 == 0:
                ckpt.save(step + 1, state)
                print(f"checkpointed step {step+1}")
        ckpt.save(args.steps, state)
    print("done — register this expert into a CoE with "
          "examples/coe_serving.py")


if __name__ == "__main__":
    main()
