"""End-to-end driver: serve a Composition of Experts with the
continuous-batching engine over the paged KV pool (paper §V/§VI-C).

Builds N experts + a router (optionally carving the HBM tier into a weight
share and a KV share via ``--kv-reserve-experts``), replays a staggered
request trace through the engine, and reports the Fig-1 switch/execute
breakdown, LRU + paged-pool statistics, slot occupancy, and per-request
latency percentiles. Pass ``--scheduler run_to_completion`` to feel the
baseline the engine replaces.

    PYTHONPATH=src python examples/coe_serving.py [--n-experts 6]
    PYTHONPATH=src python examples/coe_serving.py --scheduler run_to_completion
"""
import argparse
import tempfile
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import CompositionOfExperts, ExpertHandle, HashRouter
from repro.models import get_model
from repro.serving import Request, ServingEngine
from repro.store import make_store


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-experts", type=int, default=6)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--scheduler", default="continuous",
                    choices=["continuous", "run_to_completion"])
    ap.add_argument("--hbm-experts", type=float, default=2.5,
                    help="HBM tier capacity in units of one expert "
                    "(forces evictions when < n-experts)")
    ap.add_argument("--kv-reserve-experts", type=float, default=0.0,
                    help="slice of the HBM tier reserved for the paged KV "
                    "pool, in units of one expert (0 = size the pool for "
                    "n-slots full-length requests instead)")
    ap.add_argument("--store", default="host",
                    help="capacity-tier backend: host | mmap[:dir] | "
                    "int8[:block] (mmap defaults to a temp dir)")
    ap.add_argument("--tagged-fraction", type=float, default=0.25,
                    help="fraction of requests submitted with a caller "
                    "tag (expert pinned by the client); the rest arrive "
                    "expert=None and are routed by the composition's "
                    "router at submit")
    ap.add_argument("--trace", default=None, metavar="PATH", nargs="?",
                    const="results/trace_coe_serving.json",
                    help="record request-lifecycle spans and export a "
                    "Chrome-trace / Perfetto JSON (default "
                    "results/trace_coe_serving.json; open at "
                    "https://ui.perfetto.dev)")
    args = ap.parse_args()

    if args.trace:
        from repro.obs import trace
        trace.enable()

    cfg = reduced(get_config("samba-coe-expert-7b"))
    model = get_model(cfg)
    rng = jax.random.PRNGKey(0)

    print(f"building {args.n_experts} experts "
          f"({cfg.n_params()/1e6:.1f}M params each) on the capacity tier...")
    experts = []
    for i in range(args.n_experts):
        p = model.init(jax.random.fold_in(rng, i))
        experts.append(jax.tree.map(np.asarray, p))     # host = "DDR"
    nbytes = sum(x.nbytes for x in jax.tree.leaves(experts[0]))

    store = make_store(args.store, root=tempfile.mkdtemp(prefix="coe-store-")
                       if args.store.startswith("mmap") else None)
    coe = CompositionOfExperts(
        HashRouter(args.n_experts), None,
        hbm_capacity_bytes=int(args.hbm_experts * nbytes),
        kv_reserve_bytes=int(args.kv_reserve_experts * nbytes),
        store=store)
    domains = ["code", "math", "translate", "chat", "legal", "medical"]
    for i, host in enumerate(experts):
        coe.register(ExpertHandle(f"expert-{domains[i % len(domains)]}-{i}",
                                  cfg, host, domain=domains[i % len(domains)]))

    engine = ServingEngine(coe, cfg, max_len=48, n_slots=args.n_slots,
                           block_size=8, scheduler=args.scheduler)
    rs = np.random.RandomState(0)

    # staggered trace: half the requests queued up-front, the rest submitted
    # while the engine is already decoding (continuous admission at work).
    # A --tagged-fraction arrive caller-tagged (client pinned an expert);
    # the rest are expert=None and get routed at submit (§II).
    names = coe.expert_names()
    n_tagged = int(args.requests * args.tagged_fraction)
    reqs = [Request(
        rid=i, tokens=rs.randint(0, cfg.vocab_size, (16,)).astype(np.int32),
        max_new_tokens=int(rs.randint(4, 13)),
        expert=names[i % len(names)] if i < n_tagged else None)
        for i in range(args.requests)]
    upfront, late = reqs[: args.requests // 2], reqs[args.requests // 2:]
    t0 = time.perf_counter()
    for r in upfront:
        engine.submit(r)
    done = []
    while engine.has_work or late:
        if late:                     # trickle the rest in while decoding
            engine.submit(late.pop(0))
        done.extend(engine.step())
    wall = time.perf_counter() - t0

    st = engine.stats
    cs = coe.cache.stats
    ps = engine.pool.stats
    print(f"\n[{args.scheduler}] served {st.requests} requests / "
          f"{st.tokens_out} tokens in {wall:.2f}s "
          f"({st.tokens_out/wall:.1f} tok/s)")
    total = st.switch_s + st.exec_s + st.prefill_s + st.route_s
    print(f"Fig-1 breakdown: route {100*st.route_s/total:.1f}% | "
          f"switch {100*st.switch_s/total:.1f}% | "
          f"prefill {100*st.prefill_s/total:.1f}% | "
          f"decode {100*st.exec_s/total:.1f}%")
    print(f"scheduler: {st.decode_rounds} decode rounds, "
          f"mean slot occupancy {st.mean_occupancy:.2f}, "
          f"{st.switches} expert switches")
    print(f"HBM weight cache: hits={cs.hits} misses={cs.misses} "
          f"prefetch_hits={cs.prefetch_hits} evictions={cs.evictions} "
          f"copied_in={cs.bytes_copied_in>>20}MiB "
          f"copyback_elided={cs.bytes_copyback_elided>>20}MiB (read-only)")
    print(f"prefetch pipeline [{args.store}]: stall {cs.switch_seconds*1e3:.0f}ms "
          f"of {cs.copy_seconds*1e3:.0f}ms load "
          f"(store-read {cs.store_read_seconds*1e3:.0f}ms + "
          f"h2d {cs.h2d_seconds*1e3:.0f}ms), overlap {cs.overlap_ratio:.0%}; "
          f"capacity tier holds {coe.store.total_stored_bytes()>>20}MiB")
    print(f"paged KV pool: allocs={ps.allocs} frees={ps.frees} "
          f"peak_blocks={ps.peak_blocks} leaked={ps.blocks_in_use}")
    lat = np.array([r.latency_s for r in done]) * 1e3
    print(f"latency: p50={np.percentile(lat, 50):.0f}ms "
          f"p99={np.percentile(lat, 99):.0f}ms")
    by_expert = {}
    for r in done:
        by_expert[r.expert] = by_expert.get(r.expert, 0) + 1
    print(f"requests per expert ({n_tagged} caller-tagged, "
          f"{len(done) - n_tagged} router-routed):", by_expert)

    if args.trace:
        from repro.obs import trace
        trace.disable()
        path = trace.export(args.trace)
        print(f"trace: {len(trace.events())} events -> {path} "
              f"(open at https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
