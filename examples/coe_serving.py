"""End-to-end driver: serve a Composition of Experts with batched requests
through the three-tier memory system (the paper's deployment, §V/§VI-C).

Builds 6 experts + a router, submits a mixed batch of requests, and reports
the Fig-1 switch/execute breakdown, LRU cache statistics, and throughput.

    PYTHONPATH=src python examples/coe_serving.py [--n-experts 6]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import CompositionOfExperts, ExpertHandle, HashRouter
from repro.models import get_model
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-experts", type=int, default=6)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--hbm-experts", type=float, default=2.5,
                    help="HBM capacity in units of one expert (forces "
                    "evictions when < n-experts)")
    args = ap.parse_args()

    cfg = reduced(get_config("samba-coe-expert-7b"))
    model = get_model(cfg)
    rng = jax.random.PRNGKey(0)

    print(f"building {args.n_experts} experts "
          f"({cfg.n_params()/1e6:.1f}M params each) on the capacity tier...")
    experts = []
    for i in range(args.n_experts):
        p = model.init(jax.random.fold_in(rng, i))
        experts.append(jax.tree.map(np.asarray, p))     # host = "DDR"
    nbytes = sum(x.nbytes for x in jax.tree.leaves(experts[0]))

    coe = CompositionOfExperts(HashRouter(args.n_experts), None,
                               hbm_capacity_bytes=int(args.hbm_experts * nbytes))
    domains = ["code", "math", "translate", "chat", "legal", "medical"]
    for i, host in enumerate(experts):
        coe.register(ExpertHandle(f"expert-{domains[i % len(domains)]}-{i}",
                                  cfg, host, domain=domains[i % len(domains)]))

    engine = ServingEngine(coe, cfg, max_len=48)
    rs = np.random.RandomState(0)
    for i in range(args.requests):
        engine.submit(Request(
            rid=i, tokens=rs.randint(0, cfg.vocab_size, (24,)).astype(np.int32),
            max_new_tokens=8))

    t0 = time.perf_counter()
    done = engine.step()
    wall = time.perf_counter() - t0

    st = engine.stats
    cs = coe.cache.stats
    print(f"\nserved {len(done)} requests / {st.tokens_out} tokens "
          f"in {wall:.2f}s ({st.tokens_out/wall:.1f} tok/s)")
    total = st.switch_s + st.exec_s + st.route_s
    print(f"Fig-1 breakdown: route {100*st.route_s/total:.1f}% | "
          f"switch {100*st.switch_s/total:.1f}% | "
          f"execute {100*st.exec_s/total:.1f}%")
    print(f"HBM cache: hits={cs.hits} misses={cs.misses} "
          f"evictions={cs.evictions} copied_in={cs.bytes_copied_in>>20}MiB "
          f"copyback_elided={cs.bytes_copyback_elided>>20}MiB (read-only)")
    by_expert = {}
    for r in done:
        by_expert.setdefault(r.expert, 0)
        by_expert[r.expert] += 1
    print("requests per expert:", by_expert)


if __name__ == "__main__":
    main()
