"""FlashFFTConv / Monarch showcase (paper Fig 3-4, Table I, 13x claim).

Runs the fused Monarch pipeline kernel (Gemm0 -> Mul -> Transpose -> Gemm1)
and the fully-fused FFT-conv kernel against the op-by-op baseline, printing
the operational-intensity ledger and the measured wall-time ratio.

    PYTHONPATH=src python examples/monarch_fftconv.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.monarch_fft import (monarch, monarch_conv,
                                       operational_intensity, ref)


def timeit(fn, n=5):
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / n


def main():
    print("Table I — operational intensity of the Fig-3 pipeline "
          "(1M-point Monarch, bf16):")
    for level, label in [("none", "No fusion"),
                         ("gemm0_mul_t", "Gemm0-Mul-Transpose"),
                         ("full", "Fully spatially fused")]:
        oi = operational_intensity(16, 1024, 1024, fusion=level)
        print(f"  {label:24s} {oi:8.1f} flops/byte")

    B, N1, N2 = 4, 128, 128
    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, 8)
    x = jax.random.normal(ks[0], (B, N1, N2))
    w0 = jax.random.normal(ks[1], (N1, N1)) / np.sqrt(N1)
    tw = jax.random.normal(ks[2], (N1, N2))
    w1 = jax.random.normal(ks[3], (N2, N2)) / np.sqrt(N2)

    out = monarch(x, w0, tw, w1)
    exp = ref.monarch_ref(x, w0, tw, w1)
    print(f"\nfused Pallas kernel vs oracle: max_err="
          f"{float(jnp.max(jnp.abs(out - exp))):.2e}")

    filt = jax.random.normal(ks[4], (N2, N1))
    w0i = jax.random.normal(ks[5], (N2, N2)) / np.sqrt(N2)
    twi = jax.random.normal(ks[6], (N2, N1))
    w1i = jax.random.normal(ks[7], (N1, N1)) / np.sqrt(N1)
    outc = monarch_conv(x, w0, tw, w1, filt, w0i, twi, w1i)
    expc = ref.monarch_conv_ref(x, w0, tw, w1, filt, w0i, twi, w1i)
    print(f"fused FFT-conv (6 ops, ONE kernel call) vs oracle: max_err="
          f"{float(jnp.max(jnp.abs(outc - expc))):.2e}")

    # measured: single fused jit vs op-by-op materialization
    fused = jax.jit(lambda: ref.monarch_conv_ref(x, w0, tw, w1, filt, w0i,
                                                 twi, w1i))
    j1 = jax.jit(lambda: ref.monarch_unfused_ref(x, w0, tw, w1))
    j2 = jax.jit(lambda f: f * filt)
    j3 = jax.jit(lambda f: ref.monarch_unfused_ref(f, w0i, twi, w1i))
    def unfused():
        f = j1(); jax.block_until_ready(f)
        f = j2(f); jax.block_until_ready(f)
        return j3(f)
    tf, tu = timeit(fused), timeit(unfused)
    print(f"\nmeasured (CPU, XLA-fusion analogue of the spatial fusion): "
          f"fused {tf*1e6:.0f}us vs unfused {tu*1e6:.0f}us "
          f"-> {tu/tf:.2f}x")


if __name__ == "__main__":
    main()
