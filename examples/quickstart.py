"""Quickstart: build a reduced expert, run forward / prefill / decode, and
peek at the three-tier memory + fusion reports.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core.fusion import model_fusion_report
from repro.core.memory_tiers import Symbol, plan_placement
from repro.models import get_model


def main():
    # 1. a Llama2-7B-class expert (reduced so it runs on a laptop CPU)
    cfg = reduced(get_config("samba-coe-expert-7b"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"arch={cfg.name} params={cfg.n_params()/1e6:.2f}M")

    # 2. forward + prefill + a few decode steps
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    logits = model.forward(params, {"tokens": toks})
    print("forward logits:", logits.shape)
    last, cache = model.prefill(params, {"tokens": toks}, max_len=32)
    tok = jnp.argmax(last, -1)
    for t in range(4):
        lg, cache = model.decode_step(params, cache, tok[:, None],
                                      jnp.int32(16 + t))
        tok = jnp.argmax(lg, -1)
        print("decode step", t, "->", np.asarray(tok))

    # 3. the paper's fusion ledger for this model (Fig 11 / Table I analogue)
    rep = model_fusion_report(get_config("samba-coe-expert-7b"), batch=8,
                              ctx=4096)
    print(f"fusion: {rep.unfused_kernels} unfused kernels -> "
          f"{rep.fused_kernels} fused ({rep.launch_ratio:.1f}x), "
          f"intensity {rep.intensity_unfused:.1f} -> "
          f"{rep.intensity_fused:.1f} flops/byte")

    # 4. static lifetime allocation (paper §V-A): overlapping lifetimes never
    # share addresses; spilling picks lowest-bandwidth symbols first
    syms = [
        Symbol("weights", 6 << 20, 0, 100, read_only=True,
               transfer_footprint=600 << 20),
        Symbol("kv_cache", 3 << 20, 0, 100, transfer_footprint=300 << 20),
        Symbol("act_a", 2 << 20, 1, 2, transfer_footprint=2 << 20),
        Symbol("act_b", 2 << 20, 3, 4, transfer_footprint=2 << 20),
    ]
    alloc, spilled = plan_placement(syms, hbm_capacity=10 << 20)
    print(f"placement: peak={alloc.peak >> 20}MiB spilled={spilled} "
          f"(act_a/act_b share an address: "
          f"{alloc.offsets.get('act_a') == alloc.offsets.get('act_b')})")


if __name__ == "__main__":
    main()
