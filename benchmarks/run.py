"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = the paper-comparable
ratio for that table). Measured numbers come from this CPU container where a
real measurement is meaningful (kernel-launch overheads, switching engine,
fusion wall-time); cross-machine latency/footprint projections come from the
calibrated bandwidth model (core/bandwidth_model.py) with the paper's own
hardware constants — the analytic path the paper itself uses for its DGX
comparisons (§VI-C).

    PYTHONPATH=src python -m benchmarks.run            # all tables
    PYTHONPATH=src python -m benchmarks.run --only fig11
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import trace as obs_trace
from repro.obs.metrics import MetricsRegistry

RESULTS = Path(__file__).resolve().parent.parent / "results"

ROWS = []


def _gated_metrics(values: dict) -> dict:
    """Publish the gated bench metrics as registry gauges and read the
    emitted dict back off a registry snapshot — the JSON the CI gate
    (``tools/check_bench.py``) consumes is a registry view, the same
    pipeline ``--metrics-port`` serves, not a hand-built dict."""
    reg = MetricsRegistry()
    for k, v in values.items():
        reg.gauge(k).set(float(v))
    snap = reg.snapshot()
    return {k: snap[k] for k in values}


def _results_dir() -> Path:
    """``results/`` is gitignored and may not exist on a fresh clone —
    every writer creates it on demand."""
    RESULTS.mkdir(parents=True, exist_ok=True)
    return RESULTS


def _ensure_host_devices(n: int):
    """The node sweep emulates ``n`` sockets; this must run before anything
    initializes the JAX backend (importing repro.node does not)."""
    from repro.node.topology import ensure_emulated_sockets
    ensure_emulated_sockets(n)


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.2f},{derived}"
    ROWS.append(row)
    print(row)


def _timeit(fn, n=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / n * 1e6      # us


# ----------------------------------------------------------------------
# Table I: operational intensity vs fusion level (Monarch FFT pipeline)
# ----------------------------------------------------------------------
def bench_table1_intensity():
    """Paper Table I (39.5 / 102.6 / 410.4 flops/byte for their 1M-point
    Monarch). Our ledger uses N1=N2=256 factor matrices; the absolute
    numbers depend on factor size / dtype (unstated in the paper) — the
    reproduced CLAIM is the ordering and that fusion crosses the ~150
    flops/byte memory/compute boundary (A100 ridge point)."""
    from repro.kernels.monarch_fft import operational_intensity, monarch, ref
    for level in ("none", "gemm0_mul_t", "full"):
        oi = operational_intensity(16, 256, 256, fusion=level)
        emit(f"table1_intensity_{level}", 0.0,
             f"OI={oi:.1f}flops/byte,{'compute' if oi > 150 else 'memory'}"
             f"-bound_on_A100")
    # measured: fused (one jit) vs op-by-op with host dispatch between
    B, N1, N2 = 16, 256, 256
    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, 4)
    x = jax.random.normal(ks[0], (B, N1, N2))
    w0 = jax.random.normal(ks[1], (N1, N1)) / np.sqrt(N1)
    tw = jax.random.normal(ks[2], (N1, N2))
    w1 = jax.random.normal(ks[3], (N2, N2)) / np.sqrt(N2)
    fused = jax.jit(lambda x: ref.monarch_ref(x, w0, tw, w1))
    j_g0 = jax.jit(lambda x: jnp.einsum("ij,bjk->bik", w0, x))
    j_mul = jax.jit(lambda a: a * tw)
    j_t = jax.jit(lambda a: a.transpose(0, 2, 1))
    j_g1 = jax.jit(lambda at: jnp.einsum("ij,bjk->bik", w1, at))
    def unfused():
        a = j_g0(x); jax.block_until_ready(a)
        a = j_mul(a); jax.block_until_ready(a)
        a = j_t(a); jax.block_until_ready(a)
        return j_g1(a)
    tf = _timeit(lambda: fused(x))
    tu = _timeit(unfused)
    emit("table1_measured_fused", tf, f"speedup={tu/tf:.2f}x_vs_unfused")


# ----------------------------------------------------------------------
# Fig 10: fused vs unfused speedup per benchmark (decode/prefill/train)
# ----------------------------------------------------------------------
def bench_fig10_fusion_speedup():
    """Wall-clock: whole fused decoder-layer decode step as ONE jit vs one
    jit per op with host dispatch between (the paper's unfused baseline)."""
    from repro.kernels.fused_decode import ref as fd
    B, D, n_q, n_kv, dh, F, S = 8, 512, 8, 2, 64, 2048, 1024
    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, 8)
    x = jax.random.normal(ks[0], (B, D), jnp.float32)
    p = {
        "attn_norm": jnp.ones(D), "mlp_norm": jnp.ones(D),
        "w_qkv": jax.random.normal(ks[1], (D, (n_q + 2 * n_kv) * dh)) / 23,
        "w_o": jax.random.normal(ks[2], (n_q * dh, D)) / 23,
        "w_gate": jax.random.normal(ks[3], (D, F)) / 23,
        "w_up": jax.random.normal(ks[4], (D, F)) / 23,
        "w_down": jax.random.normal(ks[5], (F, D)) / 45,
    }
    kc = jax.random.normal(ks[6], (B, S, n_kv, dh))
    vc = jax.random.normal(ks[7], (B, S, n_kv, dh))
    pos = jnp.int32(S - 1)

    fused = jax.jit(lambda x, kc, vc: fd.decoder_layer_step_ref(
        x, p, kc, vc, pos, n_q=n_q, n_kv=n_kv, dh=dh))

    j_qkv = jax.jit(lambda x: fd.qkv_rope_ref(x, p["attn_norm"], p["w_qkv"],
                                              pos, n_q=n_q, n_kv=n_kv, dh=dh))
    j_dus = jax.jit(lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(
        c, u, i, 1))
    from repro.kernels.flash_attention.ref import decode_attention_ref
    j_attn = jax.jit(decode_attention_ref)
    j_oproj = jax.jit(lambda x, o: x + (o.reshape(B, n_q * dh) @ p["w_o"]))
    j_ffn = jax.jit(lambda x: fd.ffn_swiglu_ref(x, p["mlp_norm"], p["w_gate"],
                                                p["w_up"], p["w_down"]))

    def unfused(x, kc, vc):
        qkv = j_qkv(x); jax.block_until_ready(qkv)
        q = qkv[:n_q].transpose(1, 0, 2)
        kk = qkv[n_q:n_q + n_kv].transpose(1, 0, 2)
        vv = qkv[n_q + n_kv:].transpose(1, 0, 2)
        kc = j_dus(kc, kk[:, None], pos); jax.block_until_ready(kc)
        vc = j_dus(vc, vv[:, None], pos); jax.block_until_ready(vc)
        o = j_attn(q, kc, vc, pos + 1); jax.block_until_ready(o)
        y = j_oproj(x, o); jax.block_until_ready(y)
        return j_ffn(y)

    tf = _timeit(lambda: fused(x, kc, vc)[0])
    tu = _timeit(lambda: unfused(x, kc, vc))
    emit("fig10_decode_layer_fused", tf, f"speedup={tu/tf:.2f}x_vs_unfused")

    # model-level analytic HBM-traffic ratios. Decode is weight/cache-bound
    # (ratio near the paper's low end); prefill/train materialize large
    # activations unfused (the paper's 2-3x regime).
    from repro.configs import get_config
    from repro.core.fusion import model_fusion_report
    cases = [("samba-coe-expert-7b", 8, 4096, 1, "decode"),
             ("mixtral-8x7b", 8, 4096, 1, "decode"),
             ("samba-coe-expert-7b", 8, 4096, 4096, "prefill"),
             ("qwen2.5-32b", 8, 4096, 4096, "prefill")]
    for arch, b, ctx, seq, kind in cases:
        rep = model_fusion_report(get_config(arch), batch=b, ctx=ctx, seq=seq)
        emit(f"fig10_model_{arch}_{kind}", 0.0,
             f"hbm_traffic_ratio={rep.traffic_ratio:.2f}x,"
             f"launch_ratio={rep.launch_ratio:.1f}x")


# ----------------------------------------------------------------------
# Fig 11: kernel-call ratio unfused/fused
# ----------------------------------------------------------------------
def bench_fig11_kernel_calls():
    from repro.configs import get_config
    from repro.core.fusion import model_fusion_report
    cases = [
        ("llama7B-4k-decode", "samba-coe-expert-7b", 8, 4096),
        ("llama7B-4k-prefill", "samba-coe-expert-7b", 8, 1),
        ("mixtral-decode", "mixtral-8x7b", 8, 4096),
        ("qwen32B-decode", "qwen2.5-32b", 8, 32768),
        ("deepseek-decode", "deepseek-v2-lite-16b", 8, 32768),
    ]
    for name, arch, b, ctx in cases:
        rep = model_fusion_report(get_config(arch), batch=b, ctx=ctx)
        emit(f"fig11_{name}", 0.0,
             f"launch_ratio={rep.launch_ratio:.1f}x"
             f"({rep.unfused_kernels}->{rep.fused_kernels})")


# ----------------------------------------------------------------------
# Fig 12 + Table V: CoE latency vs expert count, cross-machine
# ----------------------------------------------------------------------
def bench_fig12_tableV_coe_latency():
    """Fig 12: latency to generate 20 tokens (BS=8) vs the number of experts
    HOSTED on one node. Below HBM capacity all experts are resident; above
    it the LRU misses scale with 1 - resident/hosted (the paper's spike when
    experts spill past HBM). Table V ratios are read off the 150-expert
    point — the Samba-CoE deployment size."""
    from repro.core import DGX_A100, DGX_H100, SN40L_NODE, TPU_V5E_NODE
    from repro.core.bandwidth_model import coe_latency, decode_step_cost

    seven_b = int(7e9)
    bytes_7b = seven_b * 2
    kv_ctx = 2 * 32 * 4096 * 128 * 2          # llama2-7B KV @4k
    hosted_pts = (10, 50, 150, 850)
    n_used = 8                                 # BS=8, distinct experts
    out = {}
    for machine in (SN40L_NODE, DGX_A100, DGX_H100, TPU_V5E_NODE):
        resident_cap = int(machine.hbm.capacity * machine.sockets_per_node
                           * 0.92 // bytes_7b)
        curve = []
        for hosted in hosted_pts:
            resident = min(hosted, resident_cap)
            hit = resident / hosted
            dc = decode_step_cost(seven_b, kv_ctx, n_used, machine)
            lat = coe_latency(n_used, bytes_7b,
                              int(round(n_used * hit)), dc, 20, machine)
            curve.append(lat["total_s"])
        out[machine.name] = curve
        emit(f"fig12_latency_{machine.name}",
             curve[hosted_pts.index(150)] * 1e6,
             "curve_s=" + "/".join("%.3f" % c for c in curve) +
             f"_at_experts={hosted_pts}")
    i150 = hosted_pts.index(150)
    for key, label in (("dgx-a100", "vs_dgx_a100"), ("dgx-h100", "vs_dgx_h100")):
        emit(f"tableV_overall_speedup_{label}", 0.0,
             f"{out[key][i150]/out['sn40l'][i150]:.1f}x_at_150_experts")
    from repro.core.bandwidth_model import switch_cost
    emit("tableV_switch_speedup", 0.0,
         f"vs_a100={switch_cost(bytes_7b, DGX_A100)/switch_cost(bytes_7b, SN40L_NODE):.0f}x,"
         f"vs_h100={switch_cost(bytes_7b, DGX_H100)/switch_cost(bytes_7b, SN40L_NODE):.0f}x")
    # the TPU deployment this framework targets, same workload
    emit("tableV_tpu_v5e_vs_dgx_a100", 0.0,
         f"{out['dgx-a100'][i150]/out['tpu-v5e'][i150]:.1f}x_at_150_experts")


# ----------------------------------------------------------------------
# Fig 13: system footprint vs expert count
# ----------------------------------------------------------------------
def bench_fig13_footprint():
    from repro.core import DGX_A100, DGX_H100, SN40L_NODE
    from repro.core.bandwidth_model import footprint_nodes
    bytes_7b = int(7e9) * 2
    for n in (50, 150, 425, 850):
        sn = footprint_nodes(n, bytes_7b, SN40L_NODE, use_capacity_tier=True)
        da = footprint_nodes(n, bytes_7b, DGX_A100, use_capacity_tier=False)
        dh = footprint_nodes(n, bytes_7b, DGX_H100, use_capacity_tier=False)
        emit(f"fig13_footprint_{n}experts", 0.0,
             f"sn40l={sn},dgx_a100={da},dgx_h100={dh},ratio={da/sn:.0f}x")


# ----------------------------------------------------------------------
# Table IV: decode throughput (tokens/s/user) roofline projections
# ----------------------------------------------------------------------
def bench_tableIV_decode_throughput():
    from repro.configs import get_config
    from repro.core import SN40L_NODE, TPU_V5E_NODE
    from repro.core.bandwidth_model import decode_step_cost
    cases = [("llama31-8b-class", "granite-8b", 16),
             ("llama31-70b-class", "qwen2.5-32b", 16),
             ("llama2-7b-expert", "samba-coe-expert-7b", 8)]
    for name, arch, tp in cases:
        cfg = get_config(arch)
        n = cfg.n_active_params()
        kv_ctx = 2 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim * 8192 * 2
        for machine in (SN40L_NODE, TPU_V5E_NODE):
            dc = decode_step_cost(n, kv_ctx, 1, machine, tp=tp)
            tput = 1.0 / dc.step_s
            emit(f"tableIV_{name}_{machine.name}", dc.step_s * 1e6,
                 f"tokens/s/user={tput:.0f},bound={dc.bottleneck}")


# ----------------------------------------------------------------------
# Fig 1: measured switch vs execute breakdown on THIS machine
# ----------------------------------------------------------------------
def bench_fig1_switching_measured():
    from repro.configs import get_config, reduced
    from repro.core import CompositionOfExperts, ExpertHandle, HashRouter
    from repro.models import get_model
    from repro.serving import Request, ServingEngine
    cfg = reduced(get_config("samba-coe-expert-7b"))
    m = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    experts = [jax.tree.map(np.asarray, m.init(jax.random.fold_in(rng, i)))
               for i in range(4)]
    nbytes = sum(x.nbytes for x in jax.tree.leaves(experts[0]))
    coe = CompositionOfExperts(HashRouter(4), None, int(2.5 * nbytes))
    for i, h in enumerate(experts):
        coe.register(ExpertHandle(f"e{i}", cfg, h))
    eng = ServingEngine(coe, cfg, max_len=48, n_slots=8, block_size=8)
    rs = np.random.RandomState(0)
    for i in range(8):
        eng.submit(Request(rid=i, tokens=rs.randint(
            0, cfg.vocab_size, (32,)).astype(np.int32), max_new_tokens=8))
    eng.drain()
    st = eng.stats
    exec_s = st.exec_s + st.prefill_s
    total = st.switch_s + exec_s + st.route_s
    emit("fig1_measured_breakdown", total * 1e6,
         f"switch%={100*st.switch_s/total:.1f},exec%={100*exec_s/total:.1f},"
         f"hits={coe.cache.stats.hits},misses={coe.cache.stats.misses}")
    cs = coe.cache.stats
    # copy bandwidth over the full load path (store read + H2D), not the
    # caller-side stall — prefetch hides most of the latter
    bw = cs.bytes_copied_in / max(cs.copy_seconds, 1e-9)
    emit("fig1_measured_copy_bw", cs.copy_seconds * 1e6,
         f"host_to_device_GBps={bw/1e9:.2f},"
         f"stall_s={cs.switch_seconds:.4f},overlap={cs.overlap_ratio:.2f}")


# ----------------------------------------------------------------------
# Fig 12 (measured): switch latency + tokens/s vs expert count + backend
# ----------------------------------------------------------------------
def bench_sweep_switching(tiny: bool = False):
    """Measured Fig-12 companion to the analytic ``fig12`` rows: sweep the
    number of hosted experts and the capacity-tier backend (host DRAM,
    mmap-on-disk, int8-quantized) with the HBM tier pinned to ~1.5 experts,
    so every switch must reload from the store. ``mode=async`` runs the
    double-buffered prefetch pipeline (next group's expert loads during the
    current group's decode); ``mode=cold`` disables prefetch — the
    cold-reload baseline where the whole store-read + H2D copy sits on the
    critical path. ``overlap_ratio`` compares per-switch stalls where the
    modes actually differ — async's stall per *prefetched* switch vs cold's
    stall per miss — because each generate() pass opens with one
    unavoidable cold miss in BOTH modes, and a total-stall ratio would let
    that shared term drown the signal at small sweep sizes. Emits
    ``results/bench_switching.json`` (rows + a flat ``metrics`` dict that
    ``tools/check_bench.py`` gates CI on)."""
    import shutil
    import tempfile

    from repro.configs import get_config, reduced
    from repro.core import CompositionOfExperts, ExpertHandle
    from repro.models import get_model
    from repro.store import make_store

    class FirstTokenRouter:
        def __init__(self, n):
            self.n = n

        def route(self, params, tokens):
            return jnp.asarray(np.asarray(tokens)[:, 0] % self.n)

    cfg = reduced(get_config("samba-coe-expert-7b"))
    m = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    counts = [2, 3] if tiny else [2, 4, 6]
    per_expert = 1 if tiny else 2            # prompts per expert group
    n_tokens = 6 if tiny else 12
    rounds = 2                               # timed generate() passes
    S = 8
    hosts = [jax.tree.map(np.asarray, m.init(jax.random.fold_in(rng, i)))
             for i in range(max(counts))]
    nbytes = sum(x.nbytes for x in jax.tree.leaves(hosts[0]))
    backends = ["host", "mmap", "int8"]

    rs = np.random.RandomState(0)
    rows, metrics = [], {}
    tmp = tempfile.mkdtemp(prefix="bench-switching-")
    try:
        for backend in backends:
            for n in counts:
                prompts = rs.randint(0, cfg.vocab_size,
                                     (n * per_expert, S)).astype(np.int32)
                prompts[:, 0] = np.arange(n * per_expert) % n
                per_switch = {}
                for mode in ("async", "cold"):
                    store = make_store(
                        backend, root=f"{tmp}/{backend}-{n}-{mode}")
                    coe = CompositionOfExperts(
                        FirstTokenRouter(n), None, int(1.5 * nbytes),
                        store=store)
                    for i in range(n):
                        coe.register(ExpertHandle(f"e{i}", cfg, hosts[i]))
                    prefetch = mode == "async"
                    coe.generate(prompts, 2, prefetch_next=prefetch)  # warmup
                    for e in coe.cache.expert_ids():
                        coe.cache.drop(e)
                    coe.cache.stats.reset()
                    t0 = time.perf_counter()
                    for _ in range(rounds):
                        coe.generate(prompts, n_tokens,
                                     prefetch_next=prefetch)
                    wall = time.perf_counter() - t0
                    coe.cache.close()
                    st = coe.cache.stats
                    tps = rounds * prompts.shape[0] * n_tokens / wall
                    switches = st.hits + st.misses
                    # the per-switch stall where the modes differ: async is
                    # judged on its prefetched switches, cold on its misses
                    if mode == "async" and st.prefetch_hits:
                        per_switch[mode] = (st.stall_prefetch_seconds
                                            / st.prefetch_hits)
                    else:
                        per_switch[mode] = (st.stall_miss_seconds
                                            / max(st.misses, 1))
                    rows.append({
                        "backend": backend, "n_experts": n, "mode": mode,
                        "wall_s": wall, "tokens_per_s": tps,
                        "switches": switches,
                        "switch_stall_s": st.switch_seconds,
                        "stall_miss_s": st.stall_miss_seconds,
                        "stall_prefetch_s": st.stall_prefetch_seconds,
                        "stall_failed_prefetch_s":
                            st.stall_failed_prefetch_seconds,
                        "prefetch_failures": st.prefetch_failures,
                        "stall_per_switch_ms": 1e3 * per_switch[mode],
                        "store_read_s": st.store_read_seconds,
                        "h2d_s": st.h2d_seconds,
                        "pipeline_overlap": st.overlap_ratio,
                        "misses": st.misses,
                        "prefetch_hits": st.prefetch_hits,
                        "evictions": st.evictions,
                        "expert_hbm_bytes": nbytes,
                        "expert_stored_bytes": store.stored_bytes("e0"),
                    })
                    emit(f"sweep_switching_{backend}_n{n}_{mode}",
                         wall * 1e6,
                         f"tokens/s={tps:.1f},"
                         f"stall_ms={st.switch_seconds*1e3:.1f},"
                         f"stall_per_switch_ms={per_switch[mode]*1e3:.1f},"
                         f"read_ms={st.store_read_seconds*1e3:.1f},"
                         f"h2d_ms={st.h2d_seconds*1e3:.1f},"
                         f"prefetch_hits={st.prefetch_hits}")
                overlap = (1.0 - per_switch["async"] / per_switch["cold"]
                           if per_switch["cold"] > 0 else 0.0)
                metrics[f"switching:{backend}:n{n}:overlap_ratio"] = overlap
                a = next(r for r in rows if r["backend"] == backend
                         and r["n_experts"] == n and r["mode"] == "async")
                c = next(r for r in rows if r["backend"] == backend
                         and r["n_experts"] == n and r["mode"] == "cold")
                metrics[f"switching:{backend}:n{n}:tps_async_vs_cold"] = (
                    a["tokens_per_s"] / c["tokens_per_s"])
                emit(f"sweep_switching_{backend}_n{n}_overlap", 0.0,
                     f"overlap_ratio={overlap:.2f}_vs_cold_reload")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    doc = {"schema": 1,
           "config": {"arch": "samba-coe-expert-7b(reduced)",
                      "expert_counts": counts, "backends": backends,
                      "per_expert_prompts": per_expert,
                      "n_tokens": n_tokens, "rounds": rounds,
                      "hbm_capacity_experts": 1.5, "tiny": tiny},
           "rows": rows, "metrics": _gated_metrics(metrics)}
    (_results_dir() / "bench_switching.json").write_text(
        json.dumps(doc, indent=1))


# ----------------------------------------------------------------------
# Arrival-rate sweep: run-to-completion vs continuous batching (§VI-C)
# ----------------------------------------------------------------------
def bench_sweep_arrival(tiny: bool = False, backend: str = "both"):
    """Offered-load sweep over the serving engine. One Poisson request trace
    per offered rate (requests/s; ``inf`` = burst, every request queued at
    t=0) is replayed against BOTH schedulers on the same paged KV substrate
    and the same compiled step functions — the measured difference is pure
    scheduling. Emits achieved tokens/s and p50/p99 request latency; the
    final row is the continuous/run-to-completion throughput ratio at the
    highest offered load (the paper's keep-the-chip-busy claim).

    A second, fused-vs-unfused axis (the Fig-6 analogue) replays one fixed
    burst through the serving backends selected by ``backend`` ('xla' /
    'fused' / 'both'): per backend it records achieved tokens/s, the
    measured HBM traffic of one compiled decode step, and the measured
    operational intensity next to ``core/fusion.py``'s predictions. These
    runs use float32 weights and KV (the backends' strict-parity dtype —
    see ``serving/backends.py``), so with ``backend='both'`` the greedy
    token streams are asserted identical across backends.

    Every replayed request carries generous TTFT+TPOT deadlines, so the
    sweep also reports **goodput** (SLO-met tokens/s, ``obs.slo``) and SLO
    attainment per scheduler and per backend: on a healthy engine nearly
    every request meets the deadlines and goodput tracks throughput; a
    scheduling collapse (queueing wedge, stalled decode) turns the missed
    deadlines into a goodput drop the CI gate catches even when raw
    tokens/s survives."""
    import hashlib

    from repro.configs import get_config, reduced
    from repro.core import CompositionOfExperts, ExpertHandle, HashRouter
    from repro.core.fusion import backend_prediction
    from repro.models import get_model
    from repro.obs.slo import request_slo_met
    from repro.serving import Request, ServingEngine
    from repro.serving.backends import fused_kernel_hbm_bytes

    cfg = reduced(get_config("samba-coe-expert-7b"))
    # generous deadlines relative to the tiny sweep's measured latencies
    # (ttft_p99 ~0.06s, per-token ~10ms): headroom for CI jitter, tight
    # enough that a structural stall blows them
    slo_ttft, slo_tpot = 1.0, 0.5
    m = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    n_exp = 3
    experts = [jax.tree.map(np.asarray, m.init(jax.random.fold_in(rng, i)))
               for i in range(n_exp)]
    nbytes = sum(x.nbytes for x in jax.tree.leaves(experts[0]))

    def mk_engine(scheduler, runner=None):
        coe = CompositionOfExperts(HashRouter(n_exp), None, int(2.5 * nbytes))
        for i, h in enumerate(experts):
            coe.register(ExpertHandle(f"e{i}", cfg, h))
        return ServingEngine(coe, cfg, max_len=32, n_slots=4, block_size=8,
                             scheduler=scheduler, runner=runner)

    # one fixed trace per offered load: (arrival offset s, prompt, max_new).
    # decode-heavy mix (short prompts, long + uneven outputs): the regime
    # where scheduling — not prefill — decides throughput (§VI-C decode).
    rs = np.random.RandomState(0)
    n_req = 8 if tiny else 20
    prompts = [rs.randint(0, cfg.vocab_size, (10,)).astype(np.int32)
               for _ in range(n_req)]
    new_toks = [int(rs.randint(4, 23)) for _ in range(n_req)]
    loads = [float("inf")] if tiny else [4.0, 12.0, float("inf")]
    repeats = 2 if tiny else 3
    # wall time is noisy on shared machines: best-of-N,
    # schedulers alternated within each repeat
    traces = {}
    for lam in loads:
        if np.isinf(lam):
            offs = np.zeros(n_req)
        else:
            offs = np.cumsum(rs.exponential(1.0 / lam, n_req))
        traces[lam] = list(zip(offs, prompts, new_toks))

    def serve_trace(eng, trace):
        pending = list(trace)
        done = []
        t0 = time.perf_counter()
        rid = 0
        while pending or eng.has_work:
            now = time.perf_counter() - t0
            while pending and pending[0][0] <= now:
                off, toks, n_new = pending.pop(0)
                r = Request(rid=rid, tokens=toks, max_new_tokens=n_new,
                            slo_ttft_s=slo_ttft, slo_tpot_s=slo_tpot)
                r.arrival_s = t0 + off   # offered arrival, not submit time:
                eng.submit(r)            # queueing delay while the engine is
                rid += 1                 # mid-step must count in latency
            if not eng.has_work and pending:
                time.sleep(min(pending[0][0] - now, 0.05))
                continue
            done.extend(eng.step())
        return done, time.perf_counter() - t0

    shared_runner = None
    best = {}                       # (sched, lam) -> dict of the fastest run
    for lam in loads:
        for rep in range(repeats):
            for sched in ("run_to_completion", "continuous"):
                eng = mk_engine(sched, runner=shared_runner)
                shared_runner = eng.runner    # share the compile cache
                # AOT-compile every prefill bucket + the decode step
                # outside the timed window (a mid-burst bucket compile
                # would otherwise land in the TTFT percentiles)
                eng.warmup()
                eng.submit(Request(rid=10_000, tokens=np.zeros(10, np.int32),
                                   max_new_tokens=2))
                eng.drain()
                eng.stats.reset()
                done, wall = serve_trace(eng, traces[lam])
                lat = np.array([r.latency_s for r in done])
                ttft = np.array([r.first_token_s - r.arrival_s
                                 for r in done])
                met = [r for r in done if request_slo_met(r)]
                run = {"wall": wall,
                       "tps": sum(r.max_new_tokens for r in done) / wall,
                       "p50": np.percentile(lat, 50), "p99": np.percentile(lat, 99),
                       "ttft_p50": np.percentile(ttft, 50),
                       "ttft_p99": np.percentile(ttft, 99),
                       "goodput": sum(r.max_new_tokens for r in met) / wall,
                       "attain": len(met) / len(done),
                       "occ": eng.stats.mean_occupancy,
                       "switches": eng.stats.switches}
                key = (sched, lam)
                if key not in best:
                    best[key] = run
                else:       # per-metric best across repeats: a repeat can win
                    b = best[key]   # on tps while a hiccup inflates its p99
                    b["tps"] = max(b["tps"], run["tps"])
                    b["wall"] = min(b["wall"], run["wall"])
                    b["p50"] = min(b["p50"], run["p50"])
                    b["p99"] = min(b["p99"], run["p99"])
                    b["ttft_p50"] = min(b["ttft_p50"], run["ttft_p50"])
                    b["ttft_p99"] = min(b["ttft_p99"], run["ttft_p99"])
                    b["goodput"] = max(b["goodput"], run["goodput"])
                    b["attain"] = max(b["attain"], run["attain"])
                    b["occ"] = max(b["occ"], run["occ"])
                    b["switches"] = min(b["switches"], run["switches"])
    for sched in ("run_to_completion", "continuous"):
        for lam in loads:
            b = best[(sched, lam)]
            label = "inf" if np.isinf(lam) else f"{lam:g}"
            emit(f"sweep_{sched}_load_{label}", b["wall"] * 1e6,
                 f"tokens/s={b['tps']:.1f},p50_ms={b['p50']*1e3:.0f},"
                 f"p99_ms={b['p99']*1e3:.0f},"
                 f"ttft_p99_ms={b['ttft_p99']*1e3:.0f},"
                 f"goodput={b['goodput']:.1f},"
                 f"slo_attainment={b['attain']:.2f},"
                 f"occupancy={b['occ']:.2f},"
                 f"switches={b['switches']},best_of={repeats}")
    hi = loads[-1]
    ratio = best[("continuous", hi)]["tps"] / best[("run_to_completion", hi)]["tps"]
    emit("sweep_continuous_vs_rtc_highest_load", 0.0,
         f"throughput_ratio={ratio:.2f}x_at_burst")

    # ---- fused-vs-unfused axis (Fig-6 analogue) -------------------------
    # float32 weights + KV: the backends' strict-parity dtype, so greedy
    # token streams must be identical across backends (bf16 parity is
    # fp-tolerance only — the XLA body rounds every op boundary to bf16
    # while the fused kernels keep activations f32 in VMEM)
    backends = {"xla": ["xla"], "fused": ["fused"],
                "both": ["xla", "fused"]}[backend]
    f32 = lambda t: jax.tree.map(
        lambda x: np.asarray(x, np.float32)
        if x.dtype == jnp.bfloat16 else np.asarray(x), t)
    experts32 = [f32(e) for e in experts]
    nbytes32 = sum(x.nbytes for x in jax.tree.leaves(experts32[0]))
    n_freq = 6 if tiny else 12
    fus_trace = [(rs.randint(0, cfg.vocab_size, (10,)).astype(np.int32),
                  int(rs.randint(6, 18))) for _ in range(n_freq)]

    fus_rows, digests = [], {}
    for bk in backends:
        coe = CompositionOfExperts(HashRouter(n_exp), None,
                                   int(2.5 * nbytes32))
        for i, h in enumerate(experts32):
            coe.register(ExpertHandle(f"e{i}", cfg, h))
        eng = ServingEngine(coe, cfg, max_len=32, n_slots=4, block_size=8,
                            backend=bk, kv_dtype=jnp.float32)
        # warm the compile cache (all prefill buckets + the decode step)
        # outside the timed window
        eng.warmup()
        eng.submit(Request(rid=10_000, tokens=np.zeros(10, np.int32),
                           max_new_tokens=2))
        eng.drain()
        eng.stats.reset()
        t0 = time.perf_counter()
        for rid, (toks, n_new) in enumerate(fus_trace):
            eng.submit(Request(rid=rid, tokens=toks, max_new_tokens=n_new,
                               slo_ttft_s=slo_ttft, slo_tpot_s=slo_tpot))
        fdone = eng.drain()
        wall = time.perf_counter() - t0
        tps = sum(r.max_new_tokens for r in fdone) / wall
        fmet = [r for r in fdone if request_slo_met(r)]
        fgoodput = sum(r.max_new_tokens for r in fmet) / wall
        outs = {r.rid: r.output for r in fdone}
        digests[bk] = hashlib.sha256(
            b"".join(outs[i].tobytes() for i in sorted(outs))).hexdigest()[:16]

        # measured HBM traffic of one compiled (n_slots, 1) decode step.
        # xla: the compiled step's XLA cost model. fused: XLA treats Pallas
        # calls as opaque (and the CPU interpret-mode lowering inflates
        # them), so the exact DMA accounting of the kernels' grid x
        # BlockSpec tiles is used instead — the step is kernel-dominated
        # (only the K/V scatter and embed/head stay outside them)
        B, ctx = eng.n_slots, eng.max_blocks * eng.block
        if bk == "fused":
            step_bytes = float(fused_kernel_hbm_bytes(
                cfg, B, eng.max_blocks, eng.block, kv_itemsize=4,
                p_itemsize=4, act_itemsize=4))
            measurement = "pallas_dma_accounting"
        else:
            cost = eng.runner.step_cost_analysis((eng.n_slots, 1)) or {}
            step_bytes = float(cost.get("bytes accessed", 0.0))
            measurement = "xla_cost_analysis"
        pred = backend_prediction(cfg, B, ctx, bk, dtype_bytes=4)
        intensity = pred["flops"] / step_bytes if step_bytes else 0.0
        fus_rows.append({
            "backend": bk, "tokens_per_s": tps, "wall_s": wall,
            "goodput_tok_s": fgoodput,
            "slo_attainment": len(fmet) / len(fdone),
            "measured_step_bytes": step_bytes,
            "measured_intensity": intensity,
            "measurement": measurement,
            "predicted_step_bytes": pred["predicted_hbm_bytes"],
            "predicted_intensity": pred["predicted_intensity"],
            "flops_per_step": pred["flops"],
            "token_digest": digests[bk]})
        emit(f"sweep_fusion_{bk}", wall * 1e6,
             f"tokens/s={tps:.1f},goodput={fgoodput:.1f},"
             f"slo_attainment={len(fmet) / len(fdone):.2f},"
             f"measured_MB_per_step={step_bytes/1e6:.2f},"
             f"measured_intensity={intensity:.1f},"
             f"predicted_intensity={pred['predicted_intensity']:.1f}")
    if len(backends) == 2:
        if digests["xla"] != digests["fused"]:
            raise AssertionError(
                "fused backend diverged from xla greedy token streams "
                f"(digest {digests['fused']} != {digests['xla']})")
        emit("sweep_fusion_parity", 0.0,
             f"tokens_identical=1,digest={digests['xla']}")

    rows = []
    for (sched, lam), b in best.items():
        rows.append({"scheduler": sched,
                     "offered_load": "inf" if np.isinf(lam) else lam,
                     "wall_s": b["wall"], "tokens_per_s": b["tps"],
                     "p50_s": float(b["p50"]), "p99_s": float(b["p99"]),
                     "ttft_p50_s": float(b["ttft_p50"]),
                     "ttft_p99_s": float(b["ttft_p99"]),
                     "goodput_tok_s": float(b["goodput"]),
                     "slo_attainment": float(b["attain"]),
                     "occupancy": b["occ"], "switches": b["switches"],
                     "best_of": repeats})
    metrics = {
        "arrival:continuous:tps@burst": best[("continuous", hi)]["tps"],
        "arrival:continuous_vs_rtc_ratio": ratio,
        "arrival:continuous:p99_s@burst": best[("continuous", hi)]["p99"],
        "arrival:continuous:ttft_p99_s@burst":
            float(best[("continuous", hi)]["ttft_p99"]),
        "arrival:continuous:goodput@burst":
            float(best[("continuous", hi)]["goodput"]),
        "arrival:continuous:slo_attainment@burst":
            float(best[("continuous", hi)]["attain"]),
    }
    if "fused" in digests:
        frow = next(r for r in fus_rows if r["backend"] == "fused")
        metrics["arrival:fused:tps@burst"] = frow["tokens_per_s"]
        metrics["arrival:fused:measured_intensity"] = \
            frow["measured_intensity"]
    if len(backends) == 2:
        xrow = next(r for r in fus_rows if r["backend"] == "xla")
        metrics["arrival:fused:tokens_identical"] = 1.0
        metrics["arrival:fused:intensity_ratio"] = (
            frow["measured_intensity"] / xrow["measured_intensity"]
            if xrow["measured_intensity"] else 0.0)
    doc = {"schema": 1,
           "config": {"arch": "samba-coe-expert-7b(reduced)",
                      "n_requests": n_req, "repeats": repeats,
                      "loads": ["inf" if np.isinf(l) else l for l in loads],
                      "slo": {"ttft_s": slo_ttft, "tpot_s": slo_tpot},
                      "tiny": tiny, "backend_axis": backends},
           "rows": rows,
           "fusion_axis": {"dtype": "float32", "n_requests": n_freq,
                           "rows": fus_rows},
           "metrics": _gated_metrics(metrics)}
    (_results_dir() / "bench_arrival.json").write_text(
        json.dumps(doc, indent=1))


# ----------------------------------------------------------------------
# Node sweep: tokens/s + latency vs socket-group shape (Table V analogue)
# ----------------------------------------------------------------------
def bench_sweep_node(tiny: bool = False):
    """Multi-socket node sweep over socket-group shapes (TP x groups: 8x1,
    4x2, 2x4, 1x8) on 8 emulated CPU sockets, at saturating offered load
    (every request queued at t=0) — the Table V footprint/throughput
    analogue. One fixed request trace and one expert set (padded once for
    TP=8 so every shape runs the *same* model) replay against each shape;
    total decode slots are held constant across shapes. Reports achieved
    tokens/s, p50/p99 latency, inter-group imbalance and switch stalls, and
    emits ``results/bench_node.json`` with flat metrics — the headline is
    ``node:multi_vs_1group_tps``, multi-group throughput over the single
    TP=8 group, which must stay strictly above 1 (gated in CI)."""
    _ensure_host_devices(8)    # covers --sweep-node AND --only sweep_node
    from repro.configs import get_config, pad_for_tp, reduced
    from repro.core import HashRouter
    from repro.models import get_model
    from repro.node import make_node_topology, RDUNode
    from repro.serving import Request

    shapes = [(8, 1), (4, 2), (2, 4), (1, 8)]
    n_exp = 4 if tiny else 6
    n_req = 12 if tiny else 32
    total_slots = 8
    S = 8
    cfg = pad_for_tp(reduced(get_config("samba-coe-expert-7b")), 8)
    m = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    experts = [jax.tree.map(np.asarray, m.init(jax.random.fold_in(rng, i)))
               for i in range(n_exp)]
    nbytes = sum(x.nbytes for x in jax.tree.leaves(experts[0]))
    rs = np.random.RandomState(0)
    trace = [(i, rs.randint(0, cfg.vocab_size, (S,)).astype(np.int32),
              int(rs.randint(4, 9 if tiny else 13))) for i in range(n_req)]

    rows, metrics = [], {}
    for tp, n_groups in shapes:
        topo = make_node_topology(tp, n_groups)
        node = RDUNode(topo, cfg, HashRouter(n_exp), None,
                       group_hbm_bytes=int(3.0 * nbytes),
                       group_kv_reserve_bytes=int(0.8 * nbytes),
                       n_slots=max(1, total_slots // n_groups),
                       block_size=8, max_len=S + (16 if tiny else 20))
        for i, h in enumerate(experts):
            node.register_expert(f"e{i}", h)
        placement = node.plan()
        # warm every group's compile cache outside the timed window
        for w, gs in enumerate(node.groups):
            gs.engine.submit(Request(
                rid=100_000 + w, tokens=np.zeros(S, np.int32),
                max_new_tokens=2, expert=node.expert_names()[0]))
        node.drain()
        for gs in node.groups:
            gs.engine.stats.reset()
            gs.coe.cache.stats.reset()
            gs.submitted = 0
        node.route_s = 0.0

        t0 = time.perf_counter()
        for rid, toks, n_new in trace:
            node.submit(Request(rid=rid, tokens=toks, max_new_tokens=n_new))
        done = node.drain()
        wall = time.perf_counter() - t0
        node.close()
        st = node.stats()
        lat = np.array([r.latency_s for r in done])
        tps = st.tokens_out / wall
        name = topo.name
        rows.append({
            "shape": name, "tp": tp, "n_groups": n_groups,
            "wall_s": wall, "tokens_per_s": tps,
            "p50_s": float(np.percentile(lat, 50)),
            "p99_s": float(np.percentile(lat, 99)),
            "imbalance": st.imbalance,
            "switch_stall_s": st.switch_stall_s,
            "starvation_overrides": st.starvation_overrides,
            "spilled_experts": len(placement.spilled),
            "group_hbm_bytes": int(3.0 * nbytes),
            "resident_experts_per_group":
                node.groups[0].coe.hbm_budget.resident_experts(nbytes),
            "per_group_tokens": [g["tokens_out"] for g in st.per_group],
        })
        metrics[f"node:{name}:tokens_per_s"] = tps
        emit(f"sweep_node_{name}", wall * 1e6,
             f"tokens/s={tps:.1f},p50_ms={rows[-1]['p50_s']*1e3:.0f},"
             f"p99_ms={rows[-1]['p99_s']*1e3:.0f},"
             f"imbalance={st.imbalance:.2f},"
             f"stall_ms={st.switch_stall_s*1e3:.0f}")

    one_group = next(r for r in rows if r["n_groups"] == 1)
    multi_best = max((r for r in rows if r["n_groups"] > 1),
                     key=lambda r: r["tokens_per_s"])
    ratio = multi_best["tokens_per_s"] / one_group["tokens_per_s"]
    metrics["node:multi_vs_1group_tps"] = ratio
    emit("sweep_node_multi_vs_1group", 0.0,
         f"throughput_ratio={ratio:.2f}x_best={multi_best['shape']}"
         f"_vs_{one_group['shape']}_at_burst")

    doc = {"schema": 1,
           "config": {"arch": "samba-coe-expert-7b(reduced,pad_tp8)",
                      "shapes": [f"{t}x{g}" for t, g in shapes],
                      "n_experts": n_exp, "n_requests": n_req,
                      "total_slots": total_slots, "tiny": tiny},
           "rows": rows, "metrics": _gated_metrics(metrics)}
    (_results_dir() / "bench_node.json").write_text(json.dumps(doc, indent=1))


# ----------------------------------------------------------------------
# Prefill sweep: AOT bucketed packed prefill + prefill/decode disaggregation
# ----------------------------------------------------------------------
def bench_sweep_prefill(tiny: bool = False):
    """Two axes around the prefill path (``serving/prefill.py``).

    Axis A (single engine): one mixed-length burst — every prompt length
    DISTINCT, the worst case for a compile-per-shape prefill — replayed
    against ``prefill_mode='packed'`` (power-of-two buckets AOT-compiled at
    ``warmup()``, multiple prompts packed per forward) and
    ``prefill_mode='sequential'`` (one ``prefill_kv`` jit per novel
    length). TTFT is first-token time minus offered arrival (t=0 for the
    whole burst, so queueing counts). Sequential pays a fresh XLA compile
    for nearly every request; packed must pay ZERO after warmup — the
    ``record_compile`` hook counts them and CI gates the count at exactly 0.

    Axis B (8 emulated sockets): the same burst against a DISAGGREGATED
    node (1 dedicated prefill group handing KV blocks off to 3 decode
    groups) and a colocated node (4 decode groups prefill for themselves).
    The handoff moves prefilled KV blocks byte-for-byte, so the greedy
    token streams must be IDENTICAL — gated via a sha256 digest over all
    outputs."""
    _ensure_host_devices(8)
    import hashlib

    from repro.configs import get_config, reduced
    from repro.core import CompositionOfExperts, ExpertHandle, HashRouter
    from repro.models import get_model
    from repro.node import make_node_topology, RDUNode
    from repro.serving import Request, ServingEngine
    from repro.serving.prefill import compile_count, reset_compile_counts

    cfg = reduced(get_config("samba-coe-expert-7b"))
    m = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    n_exp = 2 if tiny else 3
    experts = [jax.tree.map(np.asarray, m.init(jax.random.fold_in(rng, i)))
               for i in range(n_exp)]
    nbytes = sum(x.nbytes for x in jax.tree.leaves(experts[0]))

    rs = np.random.RandomState(0)
    n_req = 8 if tiny else 18
    lengths = rs.permutation(np.arange(4, 4 + n_req))
    burst = [(rs.randint(0, cfg.vocab_size, (int(L),)).astype(np.int32),
              int(rs.randint(3, 7))) for L in lengths]
    max_len = int(lengths.max()) + 8 + 8      # prompt + max_new + slack

    def mk_engine(mode):
        coe = CompositionOfExperts(HashRouter(n_exp), None, int(2.5 * nbytes))
        for i, h in enumerate(experts):
            coe.register(ExpertHandle(f"e{i}", cfg, h))
        return ServingEngine(coe, cfg, max_len=max_len, n_slots=4,
                             block_size=8, prefill_mode=mode)

    def replay(submit, drain):
        t0 = time.perf_counter()
        for rid, (toks, n_new) in enumerate(burst):
            r = Request(rid=rid, tokens=toks, max_new_tokens=n_new)
            r.arrival_s = t0                 # burst: all offered at t=0
            submit(r)
        done = drain()
        wall = time.perf_counter() - t0
        ttft = np.array([r.first_token_s - r.arrival_s for r in done])
        return done, wall, ttft

    rows, metrics = [], {}
    repeats = 2
    best = {}
    for mode in ("packed", "sequential"):
        for _ in range(repeats):
            eng = mk_engine(mode)
            eng.warmup()
            reset_compile_counts()
            _, wall, ttft = replay(eng.submit, eng.drain)
            run = {"wall": wall, "compiles": compile_count(),
                   "ttft_p50": float(np.percentile(ttft, 50)),
                   "ttft_p99": float(np.percentile(ttft, 99))}
            if mode not in best:
                best[mode] = run
            else:           # best-of-N: wall noise must not gate CI
                b = best[mode]
                b["wall"] = min(b["wall"], run["wall"])
                b["ttft_p50"] = min(b["ttft_p50"], run["ttft_p50"])
                b["ttft_p99"] = min(b["ttft_p99"], run["ttft_p99"])
                b["compiles"] = max(b["compiles"], run["compiles"])
        b = best[mode]
        rows.append({"axis": "packed_vs_sequential", "mode": mode,
                     "wall_s": b["wall"], "n_requests": n_req,
                     "ttft_p50_s": b["ttft_p50"],
                     "ttft_p99_s": b["ttft_p99"],
                     "recompiles_after_warmup": b["compiles"],
                     "best_of": repeats})
        emit(f"sweep_prefill_{mode}", b["wall"] * 1e6,
             f"ttft_p50_ms={b['ttft_p50']*1e3:.0f},"
             f"ttft_p99_ms={b['ttft_p99']*1e3:.0f},"
             f"recompiles_after_warmup={b['compiles']}")
    ratio = best["sequential"]["ttft_p99"] / best["packed"]["ttft_p99"]
    metrics["prefill:packed:recompiles_after_warmup"] = \
        float(best["packed"]["compiles"])
    metrics["prefill:packed:ttft_p99_s@burst"] = best["packed"]["ttft_p99"]
    metrics["prefill:packed_vs_seq_ttft_p99"] = ratio
    emit("sweep_prefill_packed_vs_seq", 0.0,
         f"ttft_p99_ratio={ratio:.2f}x_at_burst")

    # ---- axis B: disaggregated vs colocated node ------------------------
    digests = {}
    for mode, n_pref in (("disagg", 1), ("colocated", 0)):
        topo = make_node_topology(1, 4)
        node = RDUNode(topo, cfg, HashRouter(n_exp), None,
                       group_hbm_bytes=int(3.0 * nbytes),
                       group_kv_reserve_bytes=int(0.8 * nbytes),
                       prefill_groups=n_pref,
                       n_slots=4, block_size=8, max_len=max_len)
        for i, h in enumerate(experts):
            node.register_expert(f"e{i}", h)
        node.warmup()
        reset_compile_counts()
        done, wall, ttft = replay(node.submit, node.drain)
        compiles = compile_count()
        within = node.hbm_within_budget()
        node.close()
        outs = {r.rid: r.output for r in done}
        digests[mode] = hashlib.sha256(
            b"".join(outs[i].tobytes() for i in sorted(outs))).hexdigest()[:16]
        rows.append({"axis": "disagg_vs_colocated", "mode": mode,
                     "wall_s": wall, "n_requests": n_req,
                     "ttft_p50_s": float(np.percentile(ttft, 50)),
                     "ttft_p99_s": float(np.percentile(ttft, 99)),
                     "recompiles_after_warmup": compiles,
                     "hbm_within_budget": within,
                     "token_digest": digests[mode]})
        emit(f"sweep_prefill_node_{mode}", wall * 1e6,
             f"ttft_p50_ms={np.percentile(ttft, 50)*1e3:.0f},"
             f"ttft_p99_ms={np.percentile(ttft, 99)*1e3:.0f},"
             f"recompiles_after_warmup={compiles},"
             f"digest={digests[mode]}")
    identical = float(digests["disagg"] == digests["colocated"])
    if not identical:
        raise AssertionError(
            "disaggregated node diverged from colocated greedy token "
            f"streams (digest {digests['disagg']} != {digests['colocated']})")
    metrics["prefill:disagg:tokens_identical"] = identical
    emit("sweep_prefill_disagg_parity", 0.0,
         f"tokens_identical={int(identical)},digest={digests['disagg']}")

    doc = {"schema": 1,
           "config": {"arch": "samba-coe-expert-7b(reduced)",
                      "n_requests": n_req, "n_experts": n_exp,
                      "prompt_lengths": [int(x) for x in lengths],
                      "repeats": repeats, "tiny": tiny},
           "rows": rows, "metrics": _gated_metrics(metrics)}
    (_results_dir() / "bench_prefill.json").write_text(
        json.dumps(doc, indent=1))


# ----------------------------------------------------------------------
# Tenancy sweep: prefix sharing + sessions vs re-prefill-everything
# ----------------------------------------------------------------------
def bench_sweep_tenancy(tiny: bool = False):
    """Trace-replay tenancy sweep: a population of multi-turn sessions with
    a realistic prompt-share distribution (70% of sessions open with one of
    a handful of per-tenant system prompts; turn counts mixed 1-3; each
    turn re-sends the whole conversation plus fresh user tokens) is
    replayed wave-by-wave against TWO engines on identical traffic:

      * ``shared``   — ``prefix_sharing=True``: the PrefixIndex dedups the
        system prompts across sessions, SessionManager retention hands each
        session's history KV to its next turn, and hits prefill only the
        un-shared suffix;
      * ``unshared`` — the baseline engine, which re-prefills every prompt
        token of every turn.

    Measures shared-vs-unshared TTFT p99, the fraction of prompt tokens
    whose prefill was avoided, and KV bytes deduplicated. float32 weights
    and KV (the strict-parity dtype): the greedy token streams of the two
    engines are asserted byte-identical — sharing must change WHERE bytes
    live, never WHAT tokens come out. After each shared run the retained
    state is released and the pool is asserted fully recycled (zero leaked
    blocks). A final ungated pass drives the shared engine through
    ``StreamingFrontend`` (per-tenant quotas + streaming callbacks)."""
    import hashlib

    from repro.configs import get_config, reduced
    from repro.core import CompositionOfExperts, ExpertHandle, HashRouter
    from repro.models import get_model
    from repro.serving import (QuotaExceeded, Request, ServingEngine,
                               StreamingFrontend, TenantQuota)

    cfg = reduced(get_config("samba-coe-expert-7b"))
    m = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    n_exp = 2
    f32 = lambda t: jax.tree.map(
        lambda x: np.asarray(x, np.float32)
        if x.dtype == jnp.bfloat16 else np.asarray(x), t)
    experts = [f32(jax.tree.map(np.asarray,
                                m.init(jax.random.fold_in(rng, i))))
               for i in range(n_exp)]
    nbytes = sum(x.nbytes for x in jax.tree.leaves(experts[0]))

    max_len = 256
    n_slots = 4 if tiny else 8
    n_sessions = 12 if tiny else 30
    repeats = 2

    # ---- the session trace (fixed across engines and repeats) -----------
    # 70% of sessions open with one of two shared system prompts; the rest
    # carry a private prompt of the same length. User turns append 8-24
    # fresh tokens; turn counts cycle 1/2/3 (mean 2 — short-chat regime).
    # The 96-token system prompt keeps prefill compute (what sharing
    # avoids) the dominant TTFT term even at reduced model scale.
    rs = np.random.RandomState(7)
    SYS = 96
    sys_prompts = [rs.randint(1, cfg.vocab_size, (SYS,)).astype(np.int32)
                   for _ in range(2)]
    sessions = []
    for s in range(n_sessions):
        shared_sys = s < int(round(0.7 * n_sessions))
        sysp = (sys_prompts[s % 2] if shared_sys
                else rs.randint(1, cfg.vocab_size, (SYS,)).astype(np.int32))
        turns = 1 + (s % 3)
        sessions.append({
            "sid": f"s{s}", "expert": f"e{s % n_exp}", "sys": sysp,
            "turns": turns, "shared_sys": shared_sys,
            "user": [rs.randint(1, cfg.vocab_size,
                                (int(rs.randint(8, 25)),)).astype(np.int32)
                     for _ in range(turns)],
            # short replies keep the TTFT tail prefill-bound (what
            # sharing avoids) rather than decode-queueing-bound
            "new": [int(rs.randint(2, 6)) for _ in range(turns)]})
    max_turns = max(s["turns"] for s in sessions)

    def mk_engine(sharing: bool) -> ServingEngine:
        coe = CompositionOfExperts(HashRouter(n_exp), None,
                                   int(2.5 * nbytes))
        for i, h in enumerate(experts):
            coe.register(ExpertHandle(f"e{i}", cfg, h))
        return ServingEngine(coe, cfg, max_len=max_len, n_slots=n_slots,
                             block_size=8, prefix_sharing=sharing,
                             kv_dtype=jnp.float32)

    def replay(sharing: bool):
        eng = mk_engine(sharing)
        eng.warmup()
        # primer: serve one request per shared system prompt per expert so
        # the timed waves hit a WARM index — steady-state serving, not a
        # cold start (run on both engines: same compiles, same cache state)
        for j, sp in enumerate(sys_prompts):
            for e in range(n_exp):
                eng.submit(Request(
                    rid=900_000 + j * n_exp + e,
                    tokens=np.concatenate([sp, np.asarray([j + 1], np.int32)]),
                    max_new_tokens=2, expert=f"e{e}"))
        eng.drain()
        eng.stats.reset()
        hit0 = eng.stats.prefix_hit_tokens      # reset() zeroes; belt+braces
        cow0 = eng.pool.stats.cow_splits

        outs, ttfts = {}, []
        history = {}                 # sid -> committed conversation tokens
        prompt_tokens = 0
        t0 = time.perf_counter()
        for w in range(max_turns):
            wave = [s for s in sessions if s["turns"] > w]
            batch = []
            for s in wave:
                prev = history.get(s["sid"])
                base = s["sys"] if prev is None else prev
                p = np.concatenate([base, s["user"][w]])
                rid = w * 1000 + int(s["sid"][1:])
                batch.append((s, rid, p))
                prompt_tokens += len(p)
                eng.submit(Request(
                    rid=rid, tokens=p, max_new_tokens=s["new"][w],
                    expert=s["expert"],
                    session_id=s["sid"] if sharing else None))
            done = {r.rid: r for r in eng.drain()}
            for s, rid, p in batch:
                r = done[rid]
                outs[rid] = r.output
                ttfts.append(r.first_token_s - r.arrival_s)
                # next turn re-sends conversation so far (prompt + output)
                history[s["sid"]] = np.concatenate(
                    [p, r.output]).astype(np.int32)
        wall = time.perf_counter() - t0

        hit = int(eng.stats.prefix_hit_tokens - hit0)
        cow = int(eng.pool.stats.cow_splits - cow0)
        digest = hashlib.sha256(
            b"".join(outs[i].tobytes()
                     for i in sorted(outs))).hexdigest()[:16]
        per_tok = eng.pool._per_block_bytes() / eng.block
        if sharing:
            # zero-leak invariant: dropping retained sessions + the index
            # must return the pool to empty — refcounting never strands a
            # block
            eng.release_shared()
            if eng.pool.stats.blocks_in_use != 0:
                raise AssertionError(
                    f"prefix sharing leaked "
                    f"{eng.pool.stats.blocks_in_use} blocks after release")
        return {"wall": wall, "ttft_p99": float(np.percentile(ttfts, 99)),
                "ttft_p50": float(np.percentile(ttfts, 50)),
                "digest": digest, "hit_tokens": hit, "cow_splits": cow,
                "prompt_tokens": prompt_tokens,
                "kv_bytes_deduped": hit * per_tok,
                "evictions": (eng.sessions.evictions if sharing else 0)}

    best, rows = {}, []
    for rep in range(repeats):
        for mode, sharing in (("unshared", False), ("shared", True)):
            run = replay(sharing)
            b = best.setdefault(mode, run)
            if run["digest"] != b["digest"]:
                raise AssertionError(
                    f"{mode} run diverged across repeats "
                    f"(digest {run['digest']} != {b['digest']})")
            b["wall"] = min(b["wall"], run["wall"])
            b["ttft_p99"] = min(b["ttft_p99"], run["ttft_p99"])
            b["ttft_p50"] = min(b["ttft_p50"], run["ttft_p50"])
    if best["shared"]["digest"] != best["unshared"]["digest"]:
        raise AssertionError(
            "prefix sharing changed the token streams (digest "
            f"{best['shared']['digest']} != {best['unshared']['digest']})")
    for mode in ("unshared", "shared"):
        b = best[mode]
        rows.append({"mode": mode, "wall_s": b["wall"],
                     "ttft_p50_s": b["ttft_p50"],
                     "ttft_p99_s": b["ttft_p99"],
                     "hit_tokens": b["hit_tokens"],
                     "cow_splits": b["cow_splits"],
                     "prompt_tokens": b["prompt_tokens"],
                     "kv_bytes_deduped": b["kv_bytes_deduped"],
                     "token_digest": b["digest"]})
        emit(f"sweep_tenancy_{mode}", b["wall"] * 1e6,
             f"ttft_p50_ms={b['ttft_p50']*1e3:.0f},"
             f"ttft_p99_ms={b['ttft_p99']*1e3:.0f},"
             f"hit_tokens={b['hit_tokens']},"
             f"cow_splits={b['cow_splits']},digest={b['digest']}")
    avoided = best["shared"]["hit_tokens"] / best["shared"]["prompt_tokens"]
    ratio = best["unshared"]["ttft_p99"] / best["shared"]["ttft_p99"]
    emit("sweep_tenancy_summary", 0.0,
         f"prefill_tokens_avoided={avoided:.2f},"
         f"ttft_p99_speedup={ratio:.2f}x,"
         f"kv_MB_deduped={best['shared']['kv_bytes_deduped']/1e6:.2f},"
         f"tokens_identical=1")

    # ---- frontend pass (ungated rows): quotas + streaming ---------------
    eng = mk_engine(sharing=True)
    eng.warmup()
    fe = StreamingFrontend(eng, quotas={
        "paid": TenantQuota(max_concurrent=n_slots),
        "free": TenantQuota(max_concurrent=1)})
    streams, rejected = [], 0
    fe_prompt = np.concatenate(
        [sys_prompts[0], np.asarray([3, 1, 4], np.int32)])
    for i in range(4):
        tenant = "paid" if i < 2 else "free"
        try:
            streams.append(fe.submit(fe_prompt, 4, tenant=tenant,
                                     session_id=f"fe{i}",
                                     priority=1 if tenant == "paid" else 0,
                                     slo_ttft_s=5.0))
        except QuotaExceeded:
            rejected += 1
    streamed = sum(len(st.drain()) for st in streams)
    fe.join(timeout=120)
    fe.close()
    eng.release_shared()
    rows.append({"mode": "frontend", "submitted": len(streams),
                 "rejected_quota": rejected, "streamed_tokens": streamed})
    emit("sweep_tenancy_frontend", 0.0,
         f"submitted={len(streams)},rejected_quota={rejected},"
         f"streamed_tokens={streamed}")

    metrics = {
        "tenancy:shared:ttft_p99_s": best["shared"]["ttft_p99"],
        "tenancy:prefill_tokens_avoided_frac": float(avoided),
        "tenancy:unshared_vs_shared_ttft_p99": float(ratio),
        "tenancy:tokens_identical": 1.0,
    }
    doc = {"schema": 1,
           "config": {"arch": "samba-coe-expert-7b(reduced)",
                      "n_sessions": n_sessions, "n_experts": n_exp,
                      "sys_prompt_tokens": SYS, "prompt_share": 0.7,
                      "max_turns": max_turns, "repeats": repeats,
                      "dtype": "float32", "tiny": tiny},
           "rows": rows,
           "kv_bytes_deduped": best["shared"]["kv_bytes_deduped"],
           "session_evictions": best["shared"]["evictions"],
           "metrics": _gated_metrics(metrics)}
    (_results_dir() / "bench_tenancy.json").write_text(
        json.dumps(doc, indent=1))


# ----------------------------------------------------------------------
def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--sweep-arrival", action="store_true",
                    help="run ONLY the offered-load serving sweep "
                         "(run-to-completion vs continuous batching)")
    ap.add_argument("--sweep-switching", action="store_true",
                    help="run ONLY the Fig-12 switching sweep (expert count "
                         "x store backend, async prefetch vs cold reload)")
    ap.add_argument("--sweep-node", action="store_true",
                    help="run ONLY the multi-socket node sweep (tokens/s + "
                         "latency vs socket-group shape on 8 emulated "
                         "sockets)")
    ap.add_argument("--sweep-prefill", action="store_true",
                    help="run ONLY the prefill sweep (packed AOT buckets vs "
                         "sequential recompiles; disaggregated vs colocated "
                         "node on 8 emulated sockets)")
    ap.add_argument("--sweep-tenancy", action="store_true",
                    help="run ONLY the tenancy sweep (copy-on-write prefix "
                         "sharing + session retention vs re-prefill "
                         "baseline; asserts identical token streams)")
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized sweep configs (fewer experts/requests/"
                         "repeats); used by the bench-smoke CI job")
    ap.add_argument("--backend", default="both",
                    choices=["xla", "fused", "both"],
                    help="decode backends for the --sweep-arrival fusion "
                         "axis (serving/backends.py); 'both' additionally "
                         "asserts the greedy token streams are identical "
                         "across backends")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record engine/cache/node spans while benching and "
                         "export a Chrome-trace / Perfetto JSON here "
                         "(default results/trace_bench.json when the flag "
                         "is given with no value)", nargs="?",
                    const="__default__")
    args = ap.parse_args(argv)
    if args.trace_out is not None:
        obs_trace.enable()
    if args.sweep_node or args.sweep_prefill:
        # before ANY sweep dispatches: a combined invocation (e.g.
        # --sweep-arrival --sweep-node) must not let the earlier sweep
        # initialize the backend with too few devices
        _ensure_host_devices(8)
    benches = {
        "table1": bench_table1_intensity,
        "fig10": bench_fig10_fusion_speedup,
        "fig11": bench_fig11_kernel_calls,
        "fig12": bench_fig12_tableV_coe_latency,
        "fig13": bench_fig13_footprint,
        "tableIV": bench_tableIV_decode_throughput,
        "fig1": bench_fig1_switching_measured,
        "sweep": bench_sweep_arrival,
        "sweep_switching": bench_sweep_switching,
        "sweep_node": bench_sweep_node,
        "sweep_prefill": bench_sweep_prefill,
        "sweep_tenancy": bench_sweep_tenancy,
    }
    print("name,us_per_call,derived")
    any_sweep = (args.sweep_arrival or args.sweep_switching
                 or args.sweep_node or args.sweep_prefill
                 or args.sweep_tenancy)
    try:
        if any_sweep:
            if args.sweep_arrival:
                bench_sweep_arrival(tiny=args.tiny, backend=args.backend)
            if args.sweep_switching:
                bench_sweep_switching(tiny=args.tiny)
            if args.sweep_node:
                bench_sweep_node(tiny=args.tiny)
            if args.sweep_prefill:
                bench_sweep_prefill(tiny=args.tiny)
            if args.sweep_tenancy:
                bench_sweep_tenancy(tiny=args.tiny)
        else:
            for name, fn in benches.items():
                if args.only:
                    if args.only != name:
                        continue
                elif name in ("sweep", "sweep_switching", "sweep_node",
                              "sweep_prefill", "sweep_tenancy"):
                    continue          # heavy: opt-in via --sweep-* flags
                fn()
    except BaseException:
        # postmortem for the CI artifact: the flight recorder saw every
        # admit/switch/evict right up to the failure
        from repro.obs import flightrec, get_registry
        out = flightrec.dump(_results_dir() / "flight_bench.json",
                             get_registry(), reason="bench_failure")
        print(f"bench failed — flight-recorder bundle -> {out}")
        raise
    if args.trace_out is not None:
        obs_trace.disable()
        out = (args.trace_out if args.trace_out != "__default__"
               else _results_dir() / "trace_bench.json")
        path = obs_trace.export(out)
        doc = json.loads(Path(path).read_text())
        problems = obs_trace.validate_chrome_trace(doc)
        print(f"trace: {len(doc['traceEvents'])} events -> {path}"
              + (f" ({len(problems)} schema problems)" if problems else ""))
    csv_path = _results_dir() / "benchmarks.csv"
    if any_sweep or args.only:
        # partial runs append (dedup by row name) instead of clobbering
        old = []
        if csv_path.exists():
            new_names = {r.split(",")[0] for r in ROWS}
            old = [l for l in csv_path.read_text().splitlines()
                   if l and l.split(",")[0] not in new_names]
        csv_path.write_text("\n".join(old + ROWS) + "\n")
    else:
        csv_path.write_text("\n".join(ROWS) + "\n")


if __name__ == "__main__":
    main()
