"""Render the §Roofline table from results/dryrun.json (single-pod cells)
and pick hillclimb candidates.

    PYTHONPATH=src python -m benchmarks.roofline_table [--mesh single|multi]
"""
import argparse
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun.json"


def fmt_s(x):
    return f"{x*1e3:9.2f}ms"


def load_rows(mesh="single"):
    import os
    import sys
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
    from repro.configs import get_config, pad_for_tp
    from repro.configs.base import SHAPE_CELLS
    from repro.launch.roofline import min_traffic_bytes, HBM_BW

    d = json.loads(RESULTS.read_text())
    rows = []
    for key, v in sorted(d.items()):
        arch, cell, m = key.split("|")
        if m != mesh:
            continue
        if v.get("status") == "skipped":
            rows.append({"arch": arch, "cell": cell, "skipped": True,
                         "reason": v["reason"]})
            continue
        if v.get("status") != "ok":
            continue
        r = v["roofline"]
        cfg = pad_for_tp(get_config(arch), 16)
        cellobj = next(c for c in SHAPE_CELLS if c.name == cell)
        chips = r["chips"]
        floor_s = min_traffic_bytes(cfg, cellobj) / (chips * HBM_BW)
        rows.append({
            "arch": arch, "cell": cell, "skipped": False,
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "bottleneck": r["bottleneck"],
            "useful": r["useful_flops_ratio"],
            "frac": r["roofline_fraction"],
            "flops": r["hlo_flops"], "bytes": r["hlo_bytes"],
            "coll": r["collective_bytes"],
            "model_flops": r["model_flops"],
            "floor_s": floor_s,
            "corrected": "loopfix" in v,
            "temp_gib": v["memory"]["temp_bytes_per_device"] / 2**30,
            "args_gib": v["memory"]["argument_bytes_per_device"] / 2**30,
        })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = load_rows(args.mesh)
    hdr = ["arch", "cell", "compute", "memory", "collective", "floor",
           "bound", "useful", "roofline_frac", "temp_GiB"]
    if args.markdown:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
    else:
        print(f"{'arch':22s} {'cell':12s} {'compute':>10s} {'memory':>10s} "
              f"{'collect':>10s} {'floor':>9s} {'bound':>10s} {'useful':>7s} "
              f"{'frac':>7s} {'tempGiB':>8s}")
    for r in rows:
        if r["skipped"]:
            line = [r["arch"], r["cell"]] + ["-"] * 3 + ["-", "SKIP", "-",
                                                         "-", "-"]
        else:
            line = [r["arch"], r["cell"], fmt_s(r["compute_s"]).strip(),
                    fmt_s(r["memory_s"]).strip(),
                    fmt_s(r["collective_s"]).strip(),
                    fmt_s(r["floor_s"]).strip(), r["bottleneck"],
                    f"{r['useful']:.2f}", f"{r['frac']:.4f}",
                    f"{r['temp_gib']:.1f}"]
        if args.markdown:
            print("| " + " | ".join(str(x) for x in line) + " |")
        else:
            print(f"{line[0]:22s} {line[1]:12s} {line[2]:>10s} {line[3]:>10s} "
                  f"{line[4]:>10s} {line[5]:>9s} {line[6]:>10s} {line[7]:>7s} "
                  f"{line[8]:>7s} {line[9]:>8s}")
    live = [r for r in rows if not r["skipped"]]
    if live:
        worst = min(live, key=lambda r: r["frac"])
        coll = max(live, key=lambda r: r["collective_s"] /
                   max(r["memory_s"], r["compute_s"], 1e-12))
        print(f"\nworst roofline fraction : {worst['arch']} x {worst['cell']}"
              f" (frac={worst['frac']:.4f})")
        print(f"most collective-bound   : {coll['arch']} x {coll['cell']}"
              f" (coll/max(other)={coll['collective_s']/max(coll['memory_s'], coll['compute_s'], 1e-12):.2f})")


if __name__ == "__main__":
    main()
