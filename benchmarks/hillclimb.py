import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""§Perf hillclimb runner: compile a (arch x cell) with a named variant of
PerfOptions, extract loop-corrected roofline terms, and append the iteration
to results/perf.json.

    PYTHONPATH=src python -m benchmarks.hillclimb --arch granite-8b \
        --cell decode_32k --variant cache_seq_shard
"""
import argparse
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent.parent / "results" / "perf.json"

VARIANTS = {}


def _variants():
    global VARIANTS
    if VARIANTS:
        return VARIANTS
    from repro.distributed.ctx import PerfOptions
    VARIANTS = {
        "baseline": PerfOptions(),
        "cache_seq_shard": PerfOptions(cache_seq_shard=True),
        "no_sp": PerfOptions(activation_sp=False),
        "moe_a2a": PerfOptions(moe_dispatch_constraint=True),
        "moe_a2a_no_sp": PerfOptions(moe_dispatch_constraint=True,
                                     activation_sp=False),
        "cap1": PerfOptions(capacity_factor=1.0),
        "moe_a2a_cap1": PerfOptions(moe_dispatch_constraint=True,
                                    capacity_factor=1.0),
        "moe_a2a_cap1_no_sp": PerfOptions(moe_dispatch_constraint=True,
                                          capacity_factor=1.0,
                                          activation_sp=False),
        "ep_local": PerfOptions(moe_ep_local=True),
        "ep_local_no_sp": PerfOptions(moe_ep_local=True, activation_sp=False),
        "ep_local_cap1": PerfOptions(moe_ep_local=True, capacity_factor=1.0),
        "ep_local_cap1_no_sp": PerfOptions(moe_ep_local=True,
                                           capacity_factor=1.0,
                                           activation_sp=False),
        "no_sp_onehot": PerfOptions(activation_sp=False, onehot_xent=True),
        "onehot": PerfOptions(onehot_xent=True),
        "seqshard_carry": PerfOptions(cache_seq_shard=True,
                                      decode_cache_carry=True),
        "carry_only": PerfOptions(decode_cache_carry=True),
        "ep_local_onehot": PerfOptions(moe_ep_local=True, onehot_xent=True),
        "ep_local_onehot_no_sp": PerfOptions(moe_ep_local=True,
                                             onehot_xent=True,
                                             activation_sp=False),
        "no_sp_bf16chunk": PerfOptions(activation_sp=False, mlstm_bf16=True),
    }
    return VARIANTS


def run(arch: str, cell: str, variant: str, multi_pod=False):
    from repro.distributed import ctx
    from repro.launch.dryrun import compile_cost
    from repro.launch.loopfix import corrected_cell_costs
    from repro.launch.roofline import RooflineTerms, model_flops_cell
    from repro.configs import get_config, pad_for_tp
    from repro.configs.base import SHAPE_CELLS

    opts = _variants()[variant]
    cellobj = next(c for c in SHAPE_CELLS if c.name == cell)
    cfg = pad_for_tp(get_config(arch), 16)
    with ctx.perf_options(opts):
        out = corrected_cell_costs(arch, cell, multi_pod, compile_cost)
        # also a full (scanned) compile for memory_analysis
        from repro.launch.dryrun import _lower_for
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=multi_pod)
        compiled = _lower_for(cfg, cellobj, mesh).compile()
        mem = compiled.memory_analysis()
    chips = 512 if multi_pod else 256
    terms = RooflineTerms(
        arch=arch, cell=cell,
        mesh="multi" if multi_pod else "single", chips=chips,
        hlo_flops=out["flops"] * chips, hlo_bytes=out["bytes"] * chips,
        collective_bytes=out["coll"] * chips, collective_breakdown={},
        model_flops=model_flops_cell(cfg, cellobj))
    rec = {
        "arch": arch, "cell": cell, "variant": variant,
        "compute_s": terms.compute_s, "memory_s": terms.memory_s,
        "collective_s": terms.collective_s, "bottleneck": terms.bottleneck,
        "useful": terms.useful_flops_ratio,
        "roofline_fraction": terms.roofline_fraction,
        "step_s": terms.step_s,
        "temp_gib": mem.temp_size_in_bytes / 2**30,
        "args_gib": mem.argument_size_in_bytes / 2**30,
    }
    print(f"[{arch} x {cell} x {variant}] "
          f"compute={terms.compute_s*1e3:.2f}ms memory={terms.memory_s*1e3:.2f}ms "
          f"coll={terms.collective_s*1e3:.2f}ms step={terms.step_s*1e3:.2f}ms "
          f"({terms.bottleneck}) frac={terms.roofline_fraction:.4f} "
          f"temp={rec['temp_gib']:.1f}GiB")
    hist = json.loads(RESULTS.read_text()) if RESULTS.exists() else []
    hist = [h for h in hist if not (h["arch"] == arch and h["cell"] == cell
                                    and h["variant"] == variant)]
    hist.append(rec)
    RESULTS.write_text(json.dumps(hist, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--cell", required=True)
    ap.add_argument("--variant", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    run(args.arch, args.cell, args.variant, args.multi_pod)


if __name__ == "__main__":
    import os
    main()
