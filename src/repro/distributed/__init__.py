"""Distributed layer. Submodules are imported directly to avoid import
cycles with repro.models (which uses repro.distributed.ctx):

    from repro.distributed import ctx            # safe everywhere
    from repro.distributed import partitioning   # needs repro.models.common
    from repro.distributed import stepfn         # needs repro.models
"""
from repro.distributed import ctx

__all__ = ["ctx"]
