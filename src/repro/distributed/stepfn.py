"""Distributed step functions: jit-compiled train / prefill / decode steps
with explicit in/out shardings for any (arch x mesh).

These are exactly what the multi-pod dry-run lowers and what train.py /
serve.py execute. One code path — no dry-run-only forks.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.distributed import partitioning as part
from repro.models import get_model
from repro.optim import AdamWConfig, adamw_update, init_opt_state


# ----------------------------------------------------------------------
# loss
# ----------------------------------------------------------------------

def softmax_xent(logits, targets):
    """Mean token cross-entropy; fp32 logsumexp."""
    from repro.distributed import ctx
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    if ctx.perf().onehot_xent:
        # iota-compare select: elementwise on the vocab-sharded logits, the
        # reduction psums partials — no all-gather of the logits
        V = lf.shape[-1]
        hit = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1) ==             targets[..., None]
        gold = jnp.sum(jnp.where(hit, lf, 0.0), axis=-1)
    else:
        gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def chunked_xent(cfg, model, params, h_fn, batch, n_chunks=8):
    """Cross-entropy without materializing the full (B,S,V) logits:
    the final hidden states are unembedded and reduced per sequence chunk
    (python loop — exact costs, bounded peak memory)."""
    from repro.models import transformer as T
    h = h_fn()
    B, S, D = h.shape
    c = S // n_chunks
    total = 0.0
    for i in range(n_chunks):
        hs = h[:, i * c:(i + 1) * c]
        ts = batch["targets"][:, i * c:(i + 1) * c]
        logits = T.unembed(cfg, params, hs)
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(lf, ts[..., None], axis=-1)[..., 0]
        total = total + jnp.sum(lse - gold)
    return total / (B * S)


# ----------------------------------------------------------------------
# train step
# ----------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, mesh: Mesh,
                    opt_cfg: Optional[AdamWConfig] = None, *,
                    remat: bool = True, jit: bool = True,
                    accum_steps: int = 1):
    """Returns (step_fn, state_shardings, batch_sharding_fn).

    state = {'params': ..., 'opt': {'mu','nu','step'}}
    batch = {'tokens': (B,S), 'targets': (B,S)[, 'enc_embeds': ...]}

    accum_steps > 1: the global batch splits into microbatches along dim 0
    with f32 gradient accumulation before one optimizer step — the standard
    lever when the per-step activation footprint exceeds HBM.
    """
    model = get_model(cfg)
    opt_cfg = opt_cfg or AdamWConfig()
    specs = model.param_specs()
    pspecs = part.param_pspecs(specs, mesh)
    state_pspecs = {
        "params": pspecs,
        "opt": {"mu": pspecs, "nu": pspecs, "step": P()},
    }
    state_shardings = jax.tree.map(
        lambda p: NamedSharding(mesh, p), state_pspecs,
        is_leaf=lambda x: isinstance(x, P))

    def loss_fn(params, batch):
        logits = model.forward(params, batch, remat=remat)
        return softmax_xent(logits, batch["targets"])

    def step_fn(state, batch):
        if accum_steps <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def acc_body(carry, mb):
                loss_acc, gacc = carry
                l, g = jax.value_and_grad(loss_fn)(state["params"], mb)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return (loss_acc + l, gacc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            (loss, grads), _ = jax.lax.scan(acc_body, (0.0, zeros), micro)
            loss = loss / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
        new_params, new_opt, metrics = adamw_update(
            opt_cfg, state["params"], grads, state["opt"])
        metrics = dict(metrics, loss=loss)
        return {"params": new_params, "opt": new_opt}, metrics

    def batch_shardings(batch_tree):
        bp = part.batch_pspecs(cfg, batch_tree, mesh)
        return jax.tree.map(lambda p: NamedSharding(mesh, p), bp,
                            is_leaf=lambda x: isinstance(x, P))

    if not jit:
        return step_fn, state_shardings, batch_shardings
    fn = jax.jit(
        step_fn,
        in_shardings=(state_shardings, None),
        out_shardings=(state_shardings, None),
        donate_argnums=(0,),
    )
    return fn, state_shardings, batch_shardings


def abstract_train_state(cfg: ModelConfig, mesh: Mesh):
    """ShapeDtypeStructs (with shardings) for the train state — dry-run input."""
    model = get_model(cfg)
    specs = model.param_specs()
    pspecs = part.param_pspecs(specs, mesh)

    def sds(s, p):
        return jax.ShapeDtypeStruct(s.shape, s.dtype,
                                    sharding=NamedSharding(mesh, p))

    from repro.models.common import ParamSpec
    params = jax.tree.map(sds, specs, pspecs,
                          is_leaf=lambda x: isinstance(x, ParamSpec))
    f32 = lambda s, p: jax.ShapeDtypeStruct(s.shape, jnp.float32,
                                            sharding=NamedSharding(mesh, p))
    mu = jax.tree.map(f32, specs, pspecs,
                      is_leaf=lambda x: isinstance(x, ParamSpec))
    nu = jax.tree.map(f32, specs, pspecs,
                      is_leaf=lambda x: isinstance(x, ParamSpec))
    step = jax.ShapeDtypeStruct((), jnp.int32,
                                sharding=NamedSharding(mesh, P()))
    return {"params": params, "opt": {"mu": mu, "nu": nu, "step": step}}


# ----------------------------------------------------------------------
# serving steps
# ----------------------------------------------------------------------

def _fitted_cache_pspecs(cfg, mesh, batch, max_len):
    model = get_model(cfg)
    cs = model.cache_spec(batch, max_len)
    cp = part.cache_pspecs(cfg, mesh)
    return part.fit_pspec_tree(cs, cp, mesh)


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, max_len: int, *,
                      batch: int = 0, jit: bool = True):
    model = get_model(cfg)
    specs = model.param_specs()
    pspecs = part.param_pspecs(specs, mesh)
    param_sh = jax.tree.map(lambda p: NamedSharding(mesh, p), pspecs,
                            is_leaf=lambda x: isinstance(x, P))
    cpsp = _fitted_cache_pspecs(cfg, mesh, batch or 8, max_len)
    cache_sh = jax.tree.map(lambda p: NamedSharding(mesh, p), cpsp,
                            is_leaf=lambda x: isinstance(x, P))
    dp = part.data_axes(mesh)
    logits_sh = NamedSharding(
        mesh, part.fit_pspec((batch or 8, cfg.vocab_size),
                             P(dp if dp else None, None), mesh))

    def prefill_fn(params, batch):
        return model.prefill(params, batch, max_len)

    if not jit:
        return prefill_fn, param_sh, cache_sh
    fn = jax.jit(prefill_fn, in_shardings=(param_sh, None),
                 out_shardings=(logits_sh, cache_sh))
    return fn, param_sh, cache_sh


def make_decode_step(cfg: ModelConfig, mesh: Mesh, *, batch: int = 0,
                     max_len: int = 0, jit: bool = True):
    model = get_model(cfg)
    specs = model.param_specs()
    pspecs = part.param_pspecs(specs, mesh)
    param_sh = jax.tree.map(lambda p: NamedSharding(mesh, p), pspecs,
                            is_leaf=lambda x: isinstance(x, P))
    cpsp = _fitted_cache_pspecs(cfg, mesh, batch or 8, max_len or 1024)
    cache_sh = jax.tree.map(lambda p: NamedSharding(mesh, p), cpsp,
                            is_leaf=lambda x: isinstance(x, P))
    dp = part.data_axes(mesh)
    logits_sh = NamedSharding(
        mesh, part.fit_pspec((batch or 8, cfg.vocab_size),
                             P(dp if dp else None, None), mesh))

    # per-layer in-scan constraints: strip the leading layer/group dim of
    # the fitted cache pspecs
    layer_ps = {}
    if cfg.family in ("dense", "moe", "encdec", "rglru"):
        layer_ps["cache_kv"] = P(*cpsp["k"][1:])
    if cfg.family == "mla_moe":
        layer_ps["cache_mla"] = P(*cpsp["ckv"][1:])

    def decode_fn(params, cache, tokens, pos):
        from repro.distributed import ctx
        with ctx.named_shardings(**layer_ps):
            return model.decode_step(params, cache, tokens, pos)

    if not jit:
        return decode_fn, param_sh, cache_sh
    fn = jax.jit(decode_fn,
                 in_shardings=(param_sh, cache_sh, None, None),
                 out_shardings=(logits_sh, cache_sh),
                 donate_argnums=(1,))
    return fn, param_sh, cache_sh


def abstract_cache(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int):
    model = get_model(cfg)
    cs = model.cache_spec(batch, max_len)
    cp = _fitted_cache_pspecs(cfg, mesh, batch, max_len)
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                          sharding=NamedSharding(mesh, p)),
        cs, cp, is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)))
