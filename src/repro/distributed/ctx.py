"""Activation-sharding context: lets step builders impose a sharding
constraint on the inter-layer residual stream without threading mesh details
through every model family.

Megatron-SP analogue: during training the residual (B, S, D) is constrained
to shard S over 'model' between layers, so the per-layer scan carries saved
for backward shrink by the TP degree; GSPMD inserts the all-gather /
reduce-scatter pairs around attention/MLP automatically.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import PartitionSpec

try:                                   # public API, jax >= 0.6
    shard_map = jax.shard_map
except AttributeError:                 # jax 0.4.x: experimental, check_rep
    from jax.experimental.shard_map import shard_map as _shard_map_expt

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return _shard_map_expt(f, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_rep=check_vma)

_ACTIVATION_PSPEC: Optional[PartitionSpec] = None
_NAMED: dict = {}


@contextlib.contextmanager
def activation_sharding(pspec: Optional[PartitionSpec]):
    global _ACTIVATION_PSPEC
    prev = _ACTIVATION_PSPEC
    _ACTIVATION_PSPEC = pspec
    try:
        yield
    finally:
        _ACTIVATION_PSPEC = prev


@contextlib.contextmanager
def named_shardings(**pspecs):
    """Named sharding constraints for family-internal tensors (e.g. the
    per-layer KV cache slice inside the decode scan — pinning it stops GSPMD
    from re-sharding the carry and all-gathering the whole cache)."""
    global _NAMED
    prev = dict(_NAMED)
    _NAMED.update(pspecs)
    try:
        yield
    finally:
        _NAMED = prev


def constrain(h):
    """Apply the active activation constraint to a (B, S, D) residual."""
    if _ACTIVATION_PSPEC is None:
        return h
    try:
        return jax.lax.with_sharding_constraint(h, _ACTIVATION_PSPEC)
    except Exception:
        return h


def constrain_named(name: str, x):
    p = _NAMED.get(name)
    if p is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, p)
    except Exception:
        return x


# ----------------------------------------------------------------------
# Layer-scan unroll control.
#
# XLA's cost analysis counts a while-loop body ONCE (no trip-count
# multiplication), so the roofline extractor compiles reduced-depth models
# with fully-unrolled layer scans to recover exact per-layer costs
# (launch/loopfix.py). Models route their layer/group scans through
# ``lscan`` so that unrolling can be switched on from outside.
# ----------------------------------------------------------------------
_LAYER_UNROLL = False


@contextlib.contextmanager
def unrolled_layer_scans():
    global _LAYER_UNROLL
    prev = _LAYER_UNROLL
    _LAYER_UNROLL = True
    try:
        yield
    finally:
        _LAYER_UNROLL = prev


def lscan(body, init, xs, length=None):
    """Layer scan: jax.lax.scan that fully unrolls under
    ``unrolled_layer_scans()`` (used by the roofline corrector)."""
    if length is None:
        length = jax.tree.leaves(xs)[0].shape[0]
    unroll = length if _LAYER_UNROLL else 1
    return jax.lax.scan(body, init, xs, length=length, unroll=unroll)


# ----------------------------------------------------------------------
# Perf options (the §Perf hillclimb knobs). Defaults = paper-faithful
# baseline; variants are switched per-compile by the hillclimb runner.
# ----------------------------------------------------------------------
import dataclasses as _dc


@_dc.dataclass(frozen=True)
class PerfOptions:
    # decode: shard the KV-cache sequence dim over 'model' when kv-heads
    # can't shard (flash-decode context parallelism; GQA kv<16 archs)
    cache_seq_shard: bool = False
    # train: Megatron-SP sequence-sharded residuals between layers
    activation_sp: bool = True
    # MoE: pin dispatch buffers so EP resolves to all-to-all, not gathers
    moe_dispatch_constraint: bool = False
    # MoE: capacity factor override (0 = keep config)
    capacity_factor: float = 0.0
    # train: chunked-vocab cross entropy (never materialize full logits)
    chunked_loss: bool = False
    # MoE: shard_map-local EP dispatch (no global sort/scatter collectives)
    moe_ep_local: bool = False
    # loss: select gold logits via iota-compare (shardable over vocab)
    # instead of take_along_axis (which gathers the sharded logits)
    onehot_xent: bool = False
    # decode: thread the full KV cache through the layer loop as a carry
    # (in-place slice updates) instead of scan xs/ys reassembly
    decode_cache_carry: bool = False
    # xlstm: bf16 chunkwise mLSTM compute (f32 gates/state only) — halves
    # the TP all-reduce payloads
    mlstm_bf16: bool = False


_PERF = PerfOptions()


@contextlib.contextmanager
def perf_options(opts: "PerfOptions"):
    global _PERF
    prev = _PERF
    _PERF = opts
    try:
        yield
    finally:
        _PERF = prev


def perf() -> "PerfOptions":
    return _PERF


_MESH = None


@contextlib.contextmanager
def mesh_ctx(mesh):
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        yield
    finally:
        _MESH = prev


def current_mesh():
    return _MESH
