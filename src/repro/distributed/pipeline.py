"""Pipeline parallelism via shard_map + collective_permute (GPipe schedule).

The paper's RDU maps pipeline stages spatially on-chip (PCU chains); across
sockets its P2P protocol streams activations between stages fused with
compute (§VII). The TPU analogue: each mesh slice along the 'stage' axis
holds a contiguous block of layers; microbatch activations flow stage→stage
with ``collective_permute`` inside one shard_map — the collective is part of
the same compiled program, so XLA overlaps it with the next microbatch's
compute (the paper's 'collectives fused and pipelined with compute').

Schedule: GPipe-style fill/drain loop, T = M + S - 1 ticks for M microbatches
over S stages. Stage s computes on tick t iff s <= t < s + M.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.ctx import shard_map


def pipeline_apply(stage_fn: Callable, stage_params: Any, x: jnp.ndarray,
                   mesh: Mesh, *, axis: str = "stage"):
    """Run ``y = stage_{S-1}(...stage_0(x))`` as a pipeline over mesh axis.

    stage_fn(params_slice, microbatch) -> microbatch (same shape).
    stage_params: pytree with leading dim S (one slice per stage).
    x: (M, ...) microbatches, M >= 1.
    Returns (M, ...) outputs.
    """
    S = mesh.shape[axis]
    M = x.shape[0]
    T = M + S - 1

    pspec_params = jax.tree.map(lambda _: P(axis), stage_params)
    pspec_x = P(axis)  # microbatches land on stage 0; padded layout below

    def body(params, xs):
        # params: (1, ...) this stage's slice; xs: (M_local,...) only stage 0
        # holds real data (we broadcast-pad for shard_map's even-sharding).
        params = jax.tree.map(lambda a: a[0], params)
        idx = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(xs[0])                     # current activation
        outs = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if t < M)
            take = jnp.clip(t, 0, M - 1)
            fresh = xs[take]
            buf = jnp.where(idx == 0, jnp.where(t < M, fresh, buf), buf)
            # compute where the stage is active: s <= t < s + M
            active = (idx <= t) & (t < idx + M)
            y = stage_fn(params, buf)
            buf2 = jnp.where(active, y, buf)
            # last stage emits microbatch t - (S-1)
            oidx = jnp.clip(t - (S - 1), 0, M - 1)
            emit = (idx == S - 1) & (t >= S - 1)
            outs = jnp.where(emit, outs.at[oidx].set(buf2), outs)
            # shift: stage s sends to s+1 (ring permute; last->first discarded)
            perm = [(i, (i + 1) % S) for i in range(S)]
            buf3 = jax.lax.ppermute(buf2, axis, perm)
            return buf3, outs

        buf, outs = jax.lax.fori_loop(0, T, tick, (buf, outs))
        # only the last stage holds real outputs; psum replicates them
        return jax.lax.psum(outs, axis)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(pspec_params, P(None)),   # x replicated; stage 0 reads it
        out_specs=P(None),
        check_vma=False,
    )
    outs = fn(stage_params, x)
    return outs


def sequential_apply(stage_fn: Callable, stage_params: Any, x: jnp.ndarray):
    """Oracle: apply all stages sequentially to each microbatch."""
    S = jax.tree.leaves(stage_params)[0].shape[0]

    def one(mb):
        h = mb
        for s in range(S):
            ps = jax.tree.map(lambda a: a[s], stage_params)
            h = stage_fn(ps, h)
        return h

    return jax.vmap(one)(x)
