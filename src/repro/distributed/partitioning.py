"""Logical-axis partitioning: maps ParamSpec logical axes onto mesh axes.

Mesh contract (launch/mesh.py):
    single-pod: (16, 16)  ('data', 'model')
    multi-pod : (2, 16, 16) ('pod', 'data', 'model')

Sharding rules (see DESIGN.md §5):
  * batch-like axes shard over ('pod','data');
  * TP axes ('q_heads', 'ffn', 'vocab', 'rnn', 'mlstm_v', 'mlstm_vh',
    'expert_ffn') shard over 'model';
  * 'experts' shards over 'model' (EP) when divisible — then 'expert_ffn'
    stays replicated inside each expert;
  * 'kv_heads' shards over 'model' only when divisible (GQA kv<16 replicates
    the small KV projections instead of inflating the cache);
  * anything unlisted is replicated.

A dim is sharded only when its size divides the mesh-axis size — the configs
are pre-padded by ``pad_for_tp`` so the hot dims always divide.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec

# logical axis -> mesh axis (None = replicate). Order matters for tensors
# carrying two shardable axes: earlier-listed axes win the mesh axis.
TP_AXES = ("experts", "q_heads", "kv_heads", "ffn", "expert_ffn", "vocab",
           "rnn", "mlstm_v", "mlstm_vh", "kv_seq")
BATCH_AXES = ("batch",)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _mesh_size(mesh: Mesh, axis: str) -> int:
    return mesh.shape[axis]


def leaf_pspec(s: ParamSpec, mesh: Mesh) -> P:
    """PartitionSpec for one ParamSpec leaf."""
    model_used = False
    entries = []
    # EP decision for this leaf: if an 'experts' dim is present and divisible,
    # it takes the model axis and 'expert_ffn' replicates.
    axes = s.axes
    has_ep = False
    for dim, ax in zip(s.shape, axes):
        if ax == "experts" and "model" in mesh.axis_names and \
                dim % _mesh_size(mesh, "model") == 0:
            has_ep = True
    for dim, ax in zip(s.shape, axes):
        if ax in BATCH_AXES:
            da = data_axes(mesh)
            total = int(np.prod([_mesh_size(mesh, a) for a in da])) if da else 1
            entries.append(da if da and dim % total == 0 else None)
            continue
        if ax in TP_AXES and "model" in mesh.axis_names and not model_used:
            if has_ep and ax == "expert_ffn":
                entries.append(None)
                continue
            msize = _mesh_size(mesh, "model")
            if dim % msize == 0 and dim >= msize:
                entries.append("model")
                model_used = True
                continue
        entries.append(None)
    return P(*entries)


def param_shardings(specs, mesh: Mesh):
    """NamedSharding tree matching a ParamSpec tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, leaf_pspec(s, mesh)),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def param_pspecs(specs, mesh: Mesh):
    return jax.tree.map(
        lambda s: leaf_pspec(s, mesh),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


# ----------------------------------------------------------------------
# Cache shardings (per family)
# ----------------------------------------------------------------------

def cache_pspecs(cfg: ModelConfig, mesh: Mesh) -> Any:
    dp = data_axes(mesh)
    dpp = dp if dp else None
    m = "model" if "model" in mesh.axis_names else None
    msize = _mesh_size(mesh, "model") if m else 1

    from repro.distributed import ctx
    seq_shard = ctx.perf().cache_seq_shard

    if cfg.family in ("dense", "moe"):
        kv = m if (m and cfg.n_kv_heads % msize == 0) else None
        if kv is None and seq_shard and m:
            sp = P(None, dpp, m, None, None)     # context-parallel cache
        else:
            sp = P(None, dpp, None, kv, None)
        return {"k": sp, "v": sp}
    if cfg.family == "mla_moe":
        sp = (P(None, dpp, m, None) if (seq_shard and m)
              else P(None, dpp, None, None))
        out = {"ckv": sp, "krope": sp}
        if cfg.first_dense_layers:
            out["ckv0"] = sp
            out["krope0"] = sp
        return out
    if cfg.family == "encdec":
        kv = m if (m and cfg.n_kv_heads % msize == 0) else None
        sp = P(None, dpp, None, kv, None)
        return {"k": sp, "v": sp, "cross_k": sp, "cross_v": sp}
    if cfg.family == "rglru":
        rnn = m if (m and cfg.d_rnn % msize == 0) else None
        out = {
            "rec": {"h": P(None, None, dpp, rnn),
                    "conv": P(None, None, dpp, None, rnn)},
            "k": P(None, dpp, None, None, None),
            "v": P(None, dpp, None, None, None),
        }
        from repro.models.rglru import _group_counts
        if _group_counts(cfg)[1]:
            out["tail"] = {"h": P(None, dpp, rnn),
                           "conv": P(None, dpp, None, rnn)}
        return out
    if cfg.family == "xlstm":
        from repro.models.xlstm import _dims
        D, Di, H, dh, _ = _dims(cfg)
        v = m if (m and dh % msize == 0) else None
        vi = m if (m and Di % msize == 0) else None
        return {
            "mlstm": {"C": P(None, None, dpp, None, None, v),
                      "n": P(None, None, dpp, None, None),
                      "conv": P(None, None, dpp, None, vi)},
            "slstm": {k: P(None, dpp, None) for k in ("h", "c", "n", "m")},
        }
    raise KeyError(cfg.family)


def paged_pool_pspec(cfg: ModelConfig, mesh: Mesh) -> P:
    """PartitionSpec for the serving-time paged KV pool, layout
    ``(layers, rows, block, kv_heads, head_dim)`` (serving/kvcache.py).

    Same rule as ``cache_pspecs`` for the dense cache: shard the kv-head dim
    over 'model' when it divides, else replicate (GQA kv < tp replicates the
    small KV rather than inflating the pool — see module docstring)."""
    m = "model" if "model" in mesh.axis_names else None
    msize = _mesh_size(mesh, "model") if m else 1
    kv = m if (m and cfg.n_kv_heads % msize == 0
               and cfg.n_kv_heads >= msize) else None
    return P(None, None, None, kv, None)


def cache_layer_pspecs(cfg: ModelConfig, mesh: Mesh) -> Dict[str, P]:
    """Per-layer cache-slice pspecs (leading layer/group dim stripped) for
    the in-scan sharding constraints (ctx.named_shardings)."""
    cp = cache_pspecs(cfg, mesh)
    out: Dict[str, P] = {}
    if cfg.family in ("dense", "moe", "encdec", "rglru"):
        out["cache_kv"] = P(*cp["k"][1:])
    if cfg.family == "mla_moe":
        out["cache_mla"] = P(*cp["ckv"][1:])
    return out


def batch_pspecs(cfg: ModelConfig, batch: Dict[str, Any], mesh: Mesh):
    dp = data_axes(mesh)
    dpp = dp if dp else None
    out = {}
    for k, v in batch.items():
        nd = len(v.shape)
        out[k] = P(dpp, *([None] * (nd - 1)))
    return out


def shardings_from_pspecs(pspecs, mesh: Mesh):
    return jax.tree.map(
        lambda p: NamedSharding(mesh, p), pspecs,
        is_leaf=lambda x: isinstance(x, P))


def fit_pspec(shape, pspec: P, mesh: Mesh) -> P:
    """Drop sharding on dims that don't divide the mesh axes evenly (e.g.
    batch=1 in the long_500k cell)."""
    entries = []
    for i, entry in enumerate(pspec):
        if entry is None or i >= len(shape):
            entries.append(None if i >= len(shape) else entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = int(np.prod([mesh.shape[a] for a in axes]))
        entries.append(entry if shape[i] % total == 0 and shape[i] >= total
                       else None)
    # preserve rank
    while len(entries) < len(shape):
        entries.append(None)
    return P(*entries[: len(shape)])


def fit_pspec_tree(sds_tree, pspec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s, p: fit_pspec(s.shape, p, mesh), sds_tree, pspec_tree,
        is_leaf=lambda x: isinstance(x, (P, jax.ShapeDtypeStruct)))
