"""Int8 error-feedback gradient compression for data-parallel all-reduce.

Distributed-optimization trick for 1000+ node scale: DP gradient all-reduce
bytes drop 4x (f32 -> int8 + per-tensor scale) with an error-feedback
residual carried in the optimizer state so the quantization error is
re-injected next step (convergence-safe in practice; see DESIGN.md §5).

Implemented with shard_map + explicit psum so the wire format is actually
int8->int32 (GSPMD's implicit reduction would promote to f32). Off by
default; enabled with TrainConfig.grad_compression='int8'.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.ctx import shard_map


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum_int8(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Inside shard_map: all-reduce-mean x over ``axis_name`` in int8.

    Two-phase: (1) pmax a shared per-tensor scale (4 bytes on the wire) so
    every replica quantizes on the same grid; (2) psum the int8 payload as
    int32 (no overflow for <=2^23 replicas). Quantization error is bounded
    by one grid step of the global max.
    """
    smax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / smax), -127, 127).astype(jnp.int8)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return qsum.astype(jnp.float32) * smax / n


def make_compressed_allreduce(mesh: Mesh, axis: str = "data"):
    """Returns fn(grads_pytree) -> mean-reduced grads over the data axis,
    with int8 wire format. Grads must be replicated over `axis` per shard
    (the usual per-replica local gradients)."""

    def _one(g):
        def body(gl):
            return compressed_psum_int8(gl, axis)
        return shard_map(
            body, mesh=mesh, in_specs=P(axis), out_specs=P(),
            check_vma=False,
        )(g)

    def reduce_tree(grads):
        return jax.tree.map(_one, grads)

    return reduce_tree


def error_feedback_update(grad, residual):
    """Apply error feedback: compress(grad + residual); new residual is the
    quantization error. Returns (compressed_value, new_residual)."""
    target = grad.astype(jnp.float32) + residual
    q, scale = quantize_int8(target)
    deq = dequantize_int8(q, scale)
    return deq, target - deq
