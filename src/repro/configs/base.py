"""Config system: ModelConfig covers every assigned architecture family.

Families:
  dense    : decoder-only transformer (llama/qwen/starcoder/chatglm/granite)
  moe      : decoder-only with MoE FFN (mixtral)
  mla_moe  : MLA attention + MoE FFN (deepseek-v2-lite)
  encdec   : encoder-decoder (whisper)
  rglru    : RG-LRU + local-attention hybrid (recurrentgemma)
  xlstm    : mLSTM/sLSTM blocks (xlstm)
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- normalization / activation / projections ---
    norm: str = "rms"                 # 'rms' | 'ln'
    act: str = "swiglu"               # 'swiglu' | 'gelu' | 'geglu'
    qkv_bias: bool = False
    attn_out_bias: bool = False
    mlp_bias: bool = False
    tie_embeddings: bool = False

    # --- rope ---
    rope_style: str = "full"          # 'full' | 'partial' | 'mrope' | 'none'
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0        # 'partial': fraction of head_dim rotated
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl: (t, h, w) half-dim sections

    # --- attention ---
    sliding_window: int = 0           # >0: SWA (mixtral / rglru local attn)
    attn_chunk: int = 1024            # chunked-attention block for long seq
    attn_logit_softcap: float = 0.0

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                 # per-expert hidden
    first_dense_layers: int = 0       # deepseek: first k layers use dense FFN
    routed_scale: float = 1.0
    capacity_factor: float = 1.25

    # --- MLA (deepseek) ---
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- enc-dec (whisper) ---
    n_encoder_layers: int = 0
    encoder_seq: int = 0              # precomputed frame embeddings length

    # --- hybrid (recurrentgemma) ---
    block_pattern: Tuple[str, ...] = ()   # e.g. ('rec','rec','attn')
    d_rnn: int = 0
    conv_width: int = 4

    # --- xlstm ---
    slstm_every: int = 0              # every Nth block is sLSTM (0 = none)
    mlstm_proj_factor: float = 2.0
    slstm_heads: int = 4

    # --- numerics ---
    dtype: str = "bfloat16"

    # --- modality frontend stub ---
    frontend: str = "none"            # 'none' | 'vision' | 'audio'

    # --- deployment padding accounting (set by pad_for_tp) ---
    orig_n_heads: int = 0
    orig_n_kv_heads: int = 0
    orig_vocab_size: int = 0

    # ------------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        if self.family == "mla_moe":
            return self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "xlstm"

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports 500k-token decode with bounded state."""
        if self.family in ("rglru", "xlstm"):
            return True
        if self.sliding_window > 0:
            return True
        return False

    @property
    def has_decode(self) -> bool:
        return True   # all assigned archs have a decoder

    def n_params(self) -> int:
        """Total parameter count (exact, from the param spec tree)."""
        from repro.models.registry import get_model
        from repro.models.common import ParamSpec
        import numpy as np
        import jax
        specs = get_model(self).param_specs()
        leaves = jax.tree.leaves(specs,
                                 is_leaf=lambda x: isinstance(x, ParamSpec))
        return int(sum(int(np.prod(s.shape)) for s in leaves))

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE: shared + top_k experts only)."""
        if self.n_experts == 0:
            return self.n_params()
        total = self.n_params()
        from repro.models.registry import get_model
        from repro.models.common import ParamSpec
        import numpy as np, jax
        specs = get_model(self).param_specs()
        flat = jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, ParamSpec))[0]
        inactive = 0
        for path, s in flat:
            keys = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
            if any("experts" == k for k in keys):
                n = int(np.prod(s.shape))
                inactive += n - (n * self.top_k) // self.n_experts
        return total - inactive


# ----------------------------------------------------------------------
# Shape cells (assigned): every LM arch gets these four shapes.
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # 'train' | 'prefill' | 'decode'


SHAPE_CELLS = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> Tuple[bool, str]:
    """Whether a shape cell applies to the arch; reason when skipped."""
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k decode skipped (see DESIGN.md §4)"
    return True, ""


# ----------------------------------------------------------------------
# Function-preserving TP padding.
#
# Padding head counts / vocab with zero-initialized rows keeps the network
# function identical while making dims divisible by the model axis. The
# original dims are recorded so roofline can account for the pad waste.
# ----------------------------------------------------------------------
def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pad_for_tp(cfg: ModelConfig, tp: int) -> ModelConfig:
    upd = {}
    if cfg.orig_n_heads == 0:
        upd["orig_n_heads"] = cfg.n_heads
        upd["orig_n_kv_heads"] = cfg.n_kv_heads
        upd["orig_vocab_size"] = cfg.vocab_size
    # q heads: always pad to multiple of tp (sharded over 'model')
    if cfg.family not in ("xlstm",):          # xlstm shards value dim, not heads
        if cfg.n_heads % tp != 0:
            upd["n_heads"] = _round_up(cfg.n_heads, tp)
        # kv heads: shard only when already divisible; if smaller than tp,
        # replicate instead of padding (cache replication is cheaper than
        # kv-head inflation for GQA kv<=8 — see DESIGN.md §5).
        if cfg.n_kv_heads >= tp and cfg.n_kv_heads % tp != 0:
            upd["n_kv_heads"] = _round_up(cfg.n_kv_heads, tp)
    if cfg.vocab_size % tp != 0:
        upd["vocab_size"] = _round_up(cfg.vocab_size, tp)
    if not upd:
        return cfg
    return replace(cfg, **upd)


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    upd = dict(
        n_layers=min(cfg.n_layers, 4 if not cfg.block_pattern else 2 * max(1, len(cfg.block_pattern) // 1)),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        attn_chunk=64,
    )
    if cfg.block_pattern:
        upd["n_layers"] = len(cfg.block_pattern)  # one full pattern group
        upd["d_rnn"] = 128
    if cfg.n_experts:
        upd["n_experts"] = min(cfg.n_experts, 4)
        upd["top_k"] = min(cfg.top_k, 2)
        upd["moe_d_ff"] = 64
        upd["first_dense_layers"] = min(cfg.first_dense_layers, 1)
        upd["n_shared_experts"] = min(cfg.n_shared_experts, 1)
    if cfg.family == "mla_moe":
        upd["kv_lora_rank"] = 64
        upd["qk_nope_dim"] = 32
        upd["qk_rope_dim"] = 16
        upd["v_head_dim"] = 32
        upd["head_dim"] = 32
    if cfg.family == "encdec":
        upd["n_encoder_layers"] = 2
        upd["n_layers"] = 2
        upd["encoder_seq"] = 32
    if cfg.sliding_window:
        upd["sliding_window"] = 32
    if cfg.family == "xlstm":
        upd["n_layers"] = 4
        upd["slstm_every"] = 4
        upd["n_heads"] = 2
        upd["n_kv_heads"] = 2
        upd["head_dim"] = 0   # derived in model
        upd["d_ff"] = 0
    if cfg.mrope_sections:
        upd["mrope_sections"] = (4, 6, 6)   # sums to half of head_dim 32
    if cfg.rope_fraction < 1.0:
        upd["rope_fraction"] = 0.5
    return replace(cfg, name=cfg.name + "-reduced", **upd)
