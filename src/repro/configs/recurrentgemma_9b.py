"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427]. RG-LRU + local attn, 1 attn : 2 rec."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="rglru",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,             # MQA for the local-attention blocks
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    norm="rms",
    act="geglu",
    rope_style="full",
    rope_theta=10000.0,
    rope_fraction=0.5,        # griffin rotates half the head dim
    sliding_window=2048,      # local attention window
    block_pattern=("rec", "rec", "attn"),
    d_rnn=4096,
    conv_width=4,
)
