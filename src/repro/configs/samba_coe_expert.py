"""The paper's own expert/router base: Llama2-7B-class (SN40L §II).

Samba-CoE derives its router and all 150 experts from Llama2-7B; this config
is the in-framework equivalent used by the CoE examples and benchmarks.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="samba-coe-expert-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=32000,
    norm="rms",
    act="swiglu",
    rope_style="full",
    rope_theta=10000.0,
)
