"""DeepSeek-V2-Lite (16B total / 2.4B active) [arXiv:2405.04434; hf].

MLA: kv_lora_rank=512, qk_nope=128, qk_rope=64, v_head=128. MoE: 64 routed
experts top-6 + 2 shared, expert hidden 1408; layer 0 uses a dense FFN
(hidden 10944). The assignment line also mentions "160 routed" which is the
non-lite DeepSeek-V2; we follow the lite config stated first (64e top-6).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="mla_moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,            # MLA: per-head K/V reconstructed from c_kv
    head_dim=192,             # qk_nope + qk_rope (reference only)
    d_ff=10944,               # dense FFN used for first_dense_layers
    vocab_size=102400,
    norm="rms",
    act="swiglu",
    rope_style="full",        # applied to the rope sub-dim of MLA
    rope_theta=10000.0,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
)
