"""xLSTM-1.3B [arXiv:2405.04517]. mLSTM blocks with sLSTM every 8th block.

d_ff=0 per the assignment: blocks carry their own up/down projections
(mLSTM proj factor 2). 48 blocks = 6 groups of (7 mLSTM + 1 sLSTM).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="xlstm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    head_dim=0,               # derived: (proj_factor * d_model) / n_heads
    d_ff=0,
    vocab_size=50304,
    norm="ln",
    act="gelu",
    rope_style="none",
    slstm_every=8,
    mlstm_proj_factor=2.0,
    slstm_heads=4,
)
