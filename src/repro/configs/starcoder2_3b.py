"""StarCoder2-3B [arXiv:2402.19173; hf]. GQA kv=2, RoPE, LN+bias, gelu."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    norm="ln",
    act="gelu",
    qkv_bias=True,
    mlp_bias=True,
    attn_out_bias=True,
    tie_embeddings=True,
    rope_style="full",
    rope_theta=100000.0,
)
