"""ChatGLM3-6B [arXiv:2406.12793; hf]. Partial ("2d") rotary 0.5, GQA kv=2."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    norm="rms",
    act="swiglu",
    qkv_bias=True,
    rope_style="partial",
    rope_fraction=0.5,
    rope_theta=10000.0,
)
