"""Mixtral-8x7B [arXiv:2401.04088; hf]. 8 experts top-2, SWA window 4096."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    norm="rms",
    act="swiglu",
    rope_style="full",
    rope_theta=1_000_000.0,
    sliding_window=4096,
    n_experts=8,
    top_k=2,
    moe_d_ff=14336,
)
