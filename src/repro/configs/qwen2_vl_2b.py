"""Qwen2-VL-2B backbone [arXiv:2409.12191; hf]. Vision frontend stubbed (patch embeds)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    norm="rms",
    act="swiglu",
    qkv_bias=True,
    tie_embeddings=True,
    rope_style="mrope",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),   # temporal/height/width half-dim sections
    frontend="vision",
)
