"""Architecture config registry. ``get_config(arch_id)`` accepts the assigned ids."""
from __future__ import annotations

from repro.configs.base import (
    ModelConfig,
    ShapeCell,
    SHAPE_CELLS,
    cell_applicable,
    pad_for_tp,
    reduced,
)

from repro.configs.qwen2_vl_2b import CONFIG as _qwen2_vl_2b
from repro.configs.whisper_small import CONFIG as _whisper_small
from repro.configs.deepseek_v2_lite_16b import CONFIG as _deepseek_v2_lite_16b
from repro.configs.mixtral_8x7b import CONFIG as _mixtral_8x7b
from repro.configs.starcoder2_3b import CONFIG as _starcoder2_3b
from repro.configs.qwen2_5_32b import CONFIG as _qwen2_5_32b
from repro.configs.granite_8b import CONFIG as _granite_8b
from repro.configs.chatglm3_6b import CONFIG as _chatglm3_6b
from repro.configs.recurrentgemma_9b import CONFIG as _recurrentgemma_9b
from repro.configs.xlstm_1_3b import CONFIG as _xlstm_1_3b
from repro.configs.samba_coe_expert import CONFIG as _samba_coe_expert

CONFIGS = {
    "qwen2-vl-2b": _qwen2_vl_2b,
    "whisper-small": _whisper_small,
    "deepseek-v2-lite-16b": _deepseek_v2_lite_16b,
    "mixtral-8x7b": _mixtral_8x7b,
    "starcoder2-3b": _starcoder2_3b,
    "qwen2.5-32b": _qwen2_5_32b,
    "granite-8b": _granite_8b,
    "chatglm3-6b": _chatglm3_6b,
    "recurrentgemma-9b": _recurrentgemma_9b,
    "xlstm-1.3b": _xlstm_1_3b,
    # the paper's own expert/router base (Llama2-7B-class, §II)
    "samba-coe-expert-7b": _samba_coe_expert,
}

ARCH_IDS = tuple(k for k in CONFIGS if k != "samba-coe-expert-7b")


def get_config(name: str) -> ModelConfig:
    key = name.replace("_", "-").lower()
    if key not in CONFIGS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(CONFIGS)}")
    return CONFIGS[key]


__all__ = [
    "ModelConfig", "ShapeCell", "SHAPE_CELLS", "cell_applicable",
    "pad_for_tp", "reduced", "CONFIGS", "ARCH_IDS", "get_config",
]
