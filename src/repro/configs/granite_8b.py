"""Granite-8B-Code [arXiv:2405.04324; hf]. Llama-arch."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
    norm="rms",
    act="swiglu",
    rope_style="full",
    rope_theta=10_000_000.0,
)
