"""Whisper-small backbone [arXiv:2212.04356]. Conv/audio frontend stubbed.

The assignment line says 12L; whisper-small is 12 encoder + 12 decoder layers,
which is what we build (noted in DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,              # decoder layers
    n_encoder_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    norm="ln",
    act="gelu",
    qkv_bias=True,
    mlp_bias=True,
    attn_out_bias=True,
    rope_style="none",        # learned absolute positions
    encoder_seq=1500,
    frontend="audio",
    tie_embeddings=True,
)
