"""Capacity-tier expert store interface (paper §III-B "DDR", §V-B).

The SN40L's third tier is a terabyte-class DDR store that holds every
expert of the composition; the HBM tier (``core.switching.HBMWeightCache``)
caches the active few. This module defines the storage contract the cache
and the CoE runtime program against:

  * ``put(name, tree)``   — persist one expert's host pytree;
  * ``get(name)``         — read it back as a host pytree (numpy leaves);
  * ``nbytes(name)``      — logical bytes as loaded into HBM (the cache's
    accounting unit — dequantized size for compressed backends);
  * ``stored_bytes(name)``— bytes the backend actually occupies on the
    capacity tier (< ``nbytes`` for the int8 backend: that gap IS the
    paper's "host more experts than DDR naively fits" lever).

Backends: ``HostMemoryStore`` (host DRAM, zero-copy), ``MmapFileStore``
(raw tensor file + JSON manifest per expert, mmap-backed reads) and
``Int8BlockQuantizedStore`` (block-quantized int8 + per-block scales,
dequant-on-load). All are safe for concurrent ``get`` from the prefetch
executor; ``put``/``delete`` are caller-thread operations.
"""
from __future__ import annotations

import abc
import threading
from dataclasses import dataclass
from typing import Any, Dict, List

import numpy as np

from repro.obs.stats import as_dict as _shared_as_dict


@dataclass
class StoreStats:
    reads: int = 0
    writes: int = 0
    bytes_read: int = 0
    bytes_written: int = 0

    def as_dict(self):
        return _shared_as_dict(self)


def host_tree_bytes(tree) -> int:
    """Logical bytes of a host pytree (numpy or jax leaves)."""
    import jax
    return int(sum(np.asarray(x).nbytes for x in jax.tree.leaves(tree)))


class ExpertStore(abc.ABC):
    """One expert-per-key blob store over host pytrees."""

    # True when nbytes() answers from metadata without reading the blob —
    # the prefetch pipeline only pre-reserves HBM for such stores
    cheap_nbytes = True

    def __init__(self):
        self.stats = StoreStats()
        self._stats_lock = threading.Lock()

    def _note_read(self, nbytes: int):
        """Stat accounting for ``get`` — reads run concurrently on the
        prefetch executor, so the += must not interleave."""
        with self._stats_lock:
            self.stats.reads += 1
            self.stats.bytes_read += nbytes

    def _note_write(self, nbytes: int):
        with self._stats_lock:
            self.stats.writes += 1
            self.stats.bytes_written += nbytes

    @abc.abstractmethod
    def put(self, name: str, tree: Any) -> None:
        ...

    @abc.abstractmethod
    def get(self, name: str) -> Any:
        ...

    @abc.abstractmethod
    def contains(self, name: str) -> bool:
        ...

    @abc.abstractmethod
    def delete(self, name: str) -> None:
        ...

    @abc.abstractmethod
    def keys(self) -> List[str]:
        ...

    @abc.abstractmethod
    def nbytes(self, name: str) -> int:
        """Bytes of the pytree as ``get`` returns it (HBM-side size)."""
        ...

    def stored_bytes(self, name: str) -> int:
        """Bytes occupied on the capacity tier; defaults to ``nbytes``."""
        return self.nbytes(name)

    # -- conveniences shared by every backend ---------------------------
    def total_stored_bytes(self) -> int:
        return sum(self.stored_bytes(n) for n in self.keys())

    def __contains__(self, name: str) -> bool:
        return self.contains(name)

    def __len__(self) -> int:
        return len(self.keys())


class HostMemoryStore(ExpertStore):
    """In-memory backend: the host-DRAM capacity tier. ``get`` returns the
    stored tree without copying — the DDR "read" cost is then just the
    H2D copy, the regime of the paper's own deployment (§VI-C)."""

    def __init__(self):
        super().__init__()
        self._trees: Dict[str, Any] = {}
        self._nbytes: Dict[str, int] = {}

    def put(self, name, tree):
        self._trees[name] = tree
        self._nbytes[name] = host_tree_bytes(tree)
        self._note_write(self._nbytes[name])

    def get(self, name):
        tree = self._trees[name]
        self._note_read(self._nbytes[name])
        return tree

    def contains(self, name):
        return name in self._trees

    def delete(self, name):
        del self._trees[name]
        del self._nbytes[name]

    def keys(self):
        return list(self._trees.keys())

    def nbytes(self, name):
        return self._nbytes[name]
