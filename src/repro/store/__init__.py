"""Capacity-tier expert store (the paper's DDR tier, §III-B/§V-B).

``ExpertStore`` is the storage contract; three backends ship:

  * ``HostMemoryStore``          — host DRAM, zero-copy reads;
  * ``MmapFileStore``            — raw tensor file + JSON manifest per
    expert, mmap-backed demand-paged reads;
  * ``Int8BlockQuantizedStore``  — int8 absmax block quantization,
    dequant-on-load, ~2-4x effective capacity.

``core.switching.HBMWeightCache`` runs its double-buffered async prefetch
pipeline against any of them; ``make_store`` builds one from a CLI-style
spec string ("host", "mmap:/path", "int8", "int8:32").
"""
from repro.store.base import (ExpertStore, HostMemoryStore, StoreStats,
                              host_tree_bytes)
from repro.store.disk import MmapFileStore
from repro.store.quantized import Int8BlockQuantizedStore


def make_store(spec: str = "host", *, root=None) -> ExpertStore:
    """Build a backend from a spec string.

    ``host`` | ``mmap[:root]`` | ``int8[:block_size]``. ``root`` is the
    directory for ``mmap`` when the spec does not embed one.
    """
    kind, _, arg = spec.partition(":")
    if kind == "host":
        return HostMemoryStore()
    if kind == "mmap":
        path = arg or root
        if path is None:
            raise ValueError("mmap store needs a directory: 'mmap:/path'")
        return MmapFileStore(path)
    if kind == "int8":
        return Int8BlockQuantizedStore(int(arg) if arg else 64)
    raise ValueError(f"unknown store spec {spec!r}")


__all__ = ["ExpertStore", "HostMemoryStore", "MmapFileStore",
           "Int8BlockQuantizedStore", "StoreStats", "host_tree_bytes",
           "make_store"]
