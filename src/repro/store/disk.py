"""On-disk expert store: one raw tensor file + one JSON manifest per expert.

The file layout is deliberately dumb — every leaf's bytes are appended to
``<name>.bin`` at a 64-byte-aligned offset and the manifest mirrors the
pytree structure (nested dicts/lists/tuples) with a tensor record at each
leaf. ``get`` maps the blob with ``np.memmap`` and returns zero-copy views
by default, so the actual disk read is demand-paged and overlaps the
H2D copy the prefetch pipeline issues right after (``eager=True`` forces
the read up front, which attributes it to the store-read phase timer
instead of the copy phase).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, List

import numpy as np

from repro.store.base import ExpertStore

_ALIGN = 64
_LEAF_KEY = "__tensor__"


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes                     # bfloat16 & friends
        return np.dtype(getattr(ml_dtypes, name))


def _flatten(node, blob: List[bytes], offset: int):
    """Returns (manifest_node, next_offset), appending leaf bytes to blob."""
    if isinstance(node, dict):
        man = {}
        for k in sorted(node):
            man[k], offset = _flatten(node[k], blob, offset)
        return man, offset
    if isinstance(node, (list, tuple)):
        items = []
        for x in node:
            m, offset = _flatten(x, blob, offset)
            items.append(m)
        return {"__list__" if isinstance(node, list) else "__tuple__":
                items}, offset
    arr = np.asarray(node)
    pad = (-offset) % _ALIGN
    if pad:
        blob.append(b"\0" * pad)
        offset += pad
    raw = np.ascontiguousarray(arr).tobytes()
    blob.append(raw)
    rec = {_LEAF_KEY: {"offset": offset, "shape": list(arr.shape),
                       "dtype": arr.dtype.name}}
    return rec, offset + len(raw)


def _unflatten(man, buf: np.ndarray):
    if _LEAF_KEY in man:
        rec = man[_LEAF_KEY]
        dt = _np_dtype(rec["dtype"])
        n = int(np.prod(rec["shape"])) if rec["shape"] else 1
        start = rec["offset"]
        view = buf[start:start + n * dt.itemsize].view(dt)
        return view.reshape(rec["shape"])
    if "__list__" in man:
        return [_unflatten(m, buf) for m in man["__list__"]]
    if "__tuple__" in man:
        return tuple(_unflatten(m, buf) for m in man["__tuple__"])
    return {k: _unflatten(v, buf) for k, v in man.items()}


class MmapFileStore(ExpertStore):
    """Raw-file capacity tier. Supports nested dict/list/tuple pytrees with
    array leaves — exactly the shape of this repo's model params."""

    def __init__(self, root, *, eager: bool = False):
        super().__init__()
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.eager = eager
        self._meta: Dict[str, dict] = {}     # manifest cache
        for mf in self.root.glob("*.json"):
            self._meta[mf.stem] = json.loads(mf.read_text())

    def _paths(self, name: str):
        return self.root / f"{name}.bin", self.root / f"{name}.json"

    def put(self, name, tree):
        blob: List[bytes] = []
        man, total = _flatten(tree, blob, 0)
        bin_path, man_path = self._paths(name)
        with open(bin_path, "wb") as f:
            for chunk in blob:
                f.write(chunk)
        doc = {"manifest": man, "total_bytes": total,
               "nbytes": _manifest_nbytes(man)}
        man_path.write_text(json.dumps(doc))
        self._meta[name] = doc
        self._note_write(total)

    def get(self, name):
        doc = self._meta[name]
        bin_path, _ = self._paths(name)
        buf = np.memmap(bin_path, dtype=np.uint8, mode="r")
        tree = _unflatten(doc["manifest"], buf)
        if self.eager:
            import jax
            tree = jax.tree.map(np.array, tree)
        self._note_read(doc["nbytes"])
        return tree

    def contains(self, name):
        return name in self._meta

    def delete(self, name):
        bin_path, man_path = self._paths(name)
        bin_path.unlink(missing_ok=True)
        man_path.unlink(missing_ok=True)
        self._meta.pop(name, None)

    def keys(self):
        return list(self._meta.keys())

    def nbytes(self, name):
        return self._meta[name]["nbytes"]

    def stored_bytes(self, name):
        return self._meta[name]["total_bytes"]


def _manifest_nbytes(man) -> int:
    if _LEAF_KEY in man:
        rec = man[_LEAF_KEY]
        n = int(np.prod(rec["shape"])) if rec["shape"] else 1
        return n * _np_dtype(rec["dtype"]).itemsize
    if "__list__" in man or "__tuple__" in man:
        return sum(_manifest_nbytes(m)
                   for m in man.get("__list__", man.get("__tuple__")))
    return sum(_manifest_nbytes(v) for v in man.values())
