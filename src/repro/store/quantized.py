"""Int8 block-quantized capacity tier: ~2-4x effective DDR capacity.

Each float leaf is flattened, split into fixed-size blocks, and stored as
int8 codes plus one float32 scale per block (absmax quantization). ``get``
dequantizes back to the original dtype — the decompress-on-load analogue
of hosting more experts than DDR naively fits (paper §V-B's capacity
argument, CoServe's placement-under-limited-memory regime). Non-float
leaves (embedding tables are float too, but e.g. int position tables)
pass through verbatim.

Per-element cost: 1 byte of code + 4/block_size bytes of scale, vs 4
(fp32) or 2 (bf16) uncompressed — report via ``stored_bytes`` vs
``nbytes``. Reconstruction error is bounded by scale/2 = absmax/254 per
block (asserted in tests/test_store.py).

Caveat: ``put`` always quantizes, so a dirty-state writeback from the
weight cache round-trips lossily — each evict/writeback/reload cycle can
add up to absmax/254 per block. Read-only expert weights (the CoE case)
quantize exactly once; keep *mutable* state on the host or mmap backend.
"""
from __future__ import annotations

from typing import Any, Dict

import numpy as np

from repro.obs import trace
from repro.store.base import ExpertStore, host_tree_bytes


class _QLeaf:
    __slots__ = ("codes", "scales", "n", "shape", "dtype")

    def __init__(self, codes, scales, n, shape, dtype):
        self.codes = codes          # (n_blocks, block) int8
        self.scales = scales        # (n_blocks, 1) float32
        self.n = n                  # valid element count
        self.shape = shape
        self.dtype = dtype

    @property
    def stored(self) -> int:
        return self.codes.nbytes + self.scales.nbytes


def _is_float(dt: np.dtype) -> bool:
    # bfloat16/float8 register as void-kind custom dtypes; match by name
    return np.issubdtype(dt, np.floating) or dt.name.startswith(
        ("bfloat", "float8"))


def _quantize(arr: np.ndarray, block: int) -> _QLeaf:
    flat = np.asarray(arr, np.float32).reshape(-1)
    n = flat.size
    pad = (-n) % block
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, np.float32)])
    blocks = flat.reshape(-1, block)
    scales = np.abs(blocks).max(axis=1, keepdims=True) / 127.0
    scales = np.where(scales == 0.0, 1.0, scales).astype(np.float32)
    codes = np.clip(np.rint(blocks / scales), -127, 127).astype(np.int8)
    return _QLeaf(codes, scales, n, arr.shape, arr.dtype)


def _dequantize(q: _QLeaf) -> np.ndarray:
    flat = (q.codes.astype(np.float32) * q.scales).reshape(-1)[: q.n]
    return flat.reshape(q.shape).astype(q.dtype)


class Int8BlockQuantizedStore(ExpertStore):
    """Host-memory backend holding int8-quantized expert pytrees."""

    def __init__(self, block_size: int = 64):
        super().__init__()
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.block = block_size
        self._trees: Dict[str, Any] = {}
        self._nbytes: Dict[str, int] = {}
        self._stored: Dict[str, int] = {}

    def put(self, name, tree):
        import jax
        stored = 0

        def enc(x):
            nonlocal stored
            arr = np.asarray(x)
            if not _is_float(arr.dtype):
                stored += arr.nbytes
                return arr
            q = _quantize(arr, self.block)
            stored += q.stored
            return q

        qtree = jax.tree.map(enc, tree)
        self._trees[name] = qtree
        self._nbytes[name] = host_tree_bytes(tree)
        self._stored[name] = stored
        self._note_write(stored)

    def get(self, name):
        import jax
        qtree = self._trees[name]
        with trace.span("dequant", cat="store", expert=name,
                        stored_bytes=self._stored[name]):
            tree = jax.tree.map(
                lambda x: _dequantize(x) if isinstance(x, _QLeaf) else x,
                qtree, is_leaf=lambda x: isinstance(x, _QLeaf))
        self._note_read(self._stored[name])
        return tree

    def contains(self, name):
        return name in self._trees

    def delete(self, name):
        del self._trees[name]
        del self._nbytes[name]
        del self._stored[name]

    def keys(self):
        return list(self._trees.keys())

    def nbytes(self, name):
        return self._nbytes[name]

    def stored_bytes(self, name):
        return self._stored[name]

    def compression_ratio(self, name: str) -> float:
        return self._nbytes[name] / max(self._stored[name], 1)
