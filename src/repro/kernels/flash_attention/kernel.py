"""Flash-attention Pallas kernels (prefill + decode).

TPU adaptation notes (vs the paper's spatially-fused RDU pipeline):
  * Online-softmax streaming over KV blocks — KV tiles stream HBM->VMEM, the
    running (m, l, acc) state lives in VMEM (the RDU's PMU stage buffers).
  * Causal/SWA block skipping: the kv loop bound is computed from the grid
    position, so masked-out tiles are never fetched or computed — the same
    useful-FLOPs-only property as the model-level ``block_attention``.
  * Block shapes are (128, head_dim)-aligned for the MXU.

``flash_prefill``: grid (B, Hq, nq). KV for the matching kv-head is resident;
the fori loop streams kv blocks with masking only on the diagonal block.
``flash_decode``:  grid (B, ns) with VMEM scratch accumulators carried across
the sequential last grid axis; masked by runtime ``length``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


NEG_INF = -1e30


# ----------------------------------------------------------------------
# Prefill
# ----------------------------------------------------------------------

def _prefill_kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bk, causal, window, scale):
    iq = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale            # (bq, dh)
    S = k_ref.shape[2]
    nk = S // bk
    q_start = iq * bq

    if causal:
        hi = jax.lax.div(q_start + bq + bk - 1, bk)
        hi = jnp.minimum(hi, nk)
    else:
        hi = nk
    if window:
        lo = jnp.maximum((q_start - window + 1) // bk, 0)
    else:
        lo = 0

    def body(j, carry):
        acc, m, l = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0, 0], j * bk, bk, 0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0, 0], j * bk, bk, 0)
        s = jnp.dot(q, k.astype(jnp.float32).T)            # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1)
        acc = acc * alpha[:, None] + jnp.dot(p.astype(v.dtype), v,
                                             preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    dh = q_ref.shape[-1]
    acc0 = jnp.zeros((bq, dh), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(lo, hi, body, (acc0, m0, l0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_prefill(q, k, v, *, causal=True, window=0, block_q=128, block_k=128,
                  interpret=False):
    """q (B,Hq,S,dh), k/v (B,Hkv,S,dh) -> (B,Hq,S,dh)."""
    B, Hq, S, dh = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0
    grid = (B, Hq, S // bq)
    kernel = functools.partial(_prefill_kernel, bq=bq, bk=bk, causal=causal,
                               window=window, scale=1.0 / math.sqrt(dh))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, S, dh), lambda b, h, i: (b, h // G, 0, 0)),
            pl.BlockSpec((1, 1, S, dh), lambda b, h, i: (b, h // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)


# ----------------------------------------------------------------------
# Decode
# ----------------------------------------------------------------------

def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                   *, bk, scale):
    j = pl.program_id(1)
    ns = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[0]
    q = q_ref[0].astype(jnp.float32) * scale               # (Hq, dh)
    k = k_ref[0, :, 0]                                     # (bk, dh) one kv head
    v = v_ref[0, :, 0]
    Hq = q.shape[0]
    s = jnp.dot(q, k.astype(jnp.float32).T)                # (Hq, bk)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (Hq, bk), 1)
    mask = kpos < length
    s = jnp.where(mask, s, NEG_INF)

    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, s.max(-1))
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_old - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == ns - 1)
    def _done():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def _decode_paged_kernel(tables_ref, len1_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, block, scale):
    """One (lane, kv-head) pair streams its block table sequentially over the
    innermost grid axis; (m, l, acc) online-softmax state persists in VMEM
    across the blocks (same scheme as ``_decode_kernel``, but the KV tile for
    step ``j`` is pool row ``tables[b, j]`` — gathered by the BlockSpec index
    map off the scalar-prefetched table, never materialized contiguously)."""
    b = pl.program_id(0)
    j = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len1_ref[b]

    # blocks past the lane's length are fully masked: skip the math (their
    # tile DMA still happens — tables are padded with the scratch row, so the
    # fetch is cheap and always in-bounds)
    @pl.when(j * block < length)
    def _accumulate():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (G, dh)
        k = k_ref[0, :, 0]                                 # (block, dh)
        v = v_ref[0, :, 0]
        G = q.shape[0]
        s = jnp.dot(q, k.astype(jnp.float32).T)            # (G, block)
        kpos = j * block + jax.lax.broadcasted_iota(jnp.int32, (G, block), 1)
        mask = kpos < length
        s = jnp.where(mask, s, NEG_INF)
        m_old = m_ref[...]
        m_new = jnp.maximum(m_old, s.max(-1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_old - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nb - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_decode_paged(q, k_pool, v_pool, tables, len1, *, interpret=False):
    """Paged-native flash decode: gather K/V straight from the block pool.

    q       (B, Hkv, G, dh)  — GQA-grouped queries (G = Hq // Hkv)
    pools   (rows, block, Hkv, dh) — the ``PagedKVCache`` k/v arrays
    tables  (B, maxb) int32  — per-lane block tables, padded with the pool's
            scratch row (every entry must be a valid row index)
    len1    (B,) int32       — valid cache positions per lane, INCLUSIVE of
            the token scattered this step (lengths + 1 for live lanes; >= 1
            always — empty/inactive lanes attend their padding rows and
            produce finite garbage the caller ignores, exactly like the XLA
            paged-extend reference)
    Returns (B, Hkv, G, dh).

    ``tables``/``len1`` ride the scalar-prefetch channel
    (``PrefetchScalarGridSpec``) so the KV BlockSpec index map resolves
    ``tables[b, j]`` BEFORE the tile DMA is issued — the vLLM-style
    block-sparse gather, expressed as a data-dependent index map.
    """
    B, Hkv, G, dh = q.shape
    rows, block, Hkv_p, _ = k_pool.shape
    assert Hkv_p == Hkv, (Hkv_p, Hkv)
    maxb = tables.shape[1]
    kernel = functools.partial(_decode_paged_kernel, block=block,
                               scale=1.0 / math.sqrt(dh))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, maxb),
        in_specs=[
            pl.BlockSpec((1, 1, G, dh),
                         lambda b, h, j, tr, lr: (b, h, 0, 0)),
            pl.BlockSpec((1, block, 1, dh),
                         lambda b, h, j, tr, lr: (tr[b, j], 0, h, 0)),
            pl.BlockSpec((1, block, 1, dh),
                         lambda b, h, j, tr, lr: (tr[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dh),
                               lambda b, h, j, tr, lr: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, dh), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, dh), q.dtype),
        interpret=interpret,
    )(jnp.asarray(tables, jnp.int32), jnp.asarray(len1, jnp.int32),
      q, k_pool, v_pool)


def flash_decode(q, k_cache, v_cache, length, *, block_k=512, interpret=False):
    """q (B,Hq,dh); caches (B,S,Hkv,dh); length (1,) int32 -> (B,Hq,dh).

    One kv-head variant per call keeps blocks MXU-aligned; GQA is handled by
    the ops wrapper (vmap over kv heads with the matching q-head group).
    """
    B, Hq, dh = q.shape
    _, S, Hkv, _ = k_cache.shape
    assert Hkv == 1, "ops wrapper splits kv heads"
    bk = min(block_k, S)
    assert S % bk == 0
    grid = (B, S // bk)
    kernel = functools.partial(_decode_kernel, bk=bk, scale=1.0 / math.sqrt(dh))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, j: (0,)),
            pl.BlockSpec((1, Hq, dh), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bk, 1, dh), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, bk, 1, dh), lambda b, j: (b, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Hq, dh), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Hq, dh), jnp.float32),
            pltpu.VMEM((Hq,), jnp.float32),
            pltpu.VMEM((Hq,), jnp.float32),
        ],
        interpret=interpret,
    )(length, q, k_cache, v_cache)
