"""Pure-jnp oracle for flash attention: quadratic masked softmax attention."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers import naive_attention


def attention_ref(q, k, v, *, causal=True, window=0):
    """q (B,S,Hq,dh), k/v (B,S,Hkv,dh) -> (B,S,Hq,dh)."""
    return naive_attention(q, k, v, causal=causal, window=window)


def decode_attention_ref(q, k_cache, v_cache, length):
    """q (B,Hq,dh), caches (B,S,Hkv,dh), length scalar -> (B,Hq,dh)."""
    valid = jnp.arange(k_cache.shape[1])[None, :] < length
    out = naive_attention(q[:, None], k_cache, v_cache, causal=False)
    # recompute with explicit mask (naive_attention lacks a length arg)
    import math
    B, S, Hkv, dh = k_cache.shape
    Hq = q.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, dh)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache).astype(jnp.float32)
    s = s / math.sqrt(dh)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    import jax
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, Hq, dh).astype(q.dtype)
