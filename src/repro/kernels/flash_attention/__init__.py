from repro.kernels.flash_attention.ops import attention, decode
from repro.kernels.flash_attention import ref

__all__ = ["attention", "decode", "ref"]
