"""Jit'd wrappers for flash attention kernels (BSHD layout in/out)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (flash_prefill, flash_decode,
                                                  flash_decode_paged)
from repro.kernels.runtime import resolve_interpret as _interp


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def attention(q, k, v, *, causal=True, window=0, block_q=128, block_k=128,
              interpret=None):
    """q (B,S,Hq,dh), k/v (B,S,Hkv,dh) -> (B,S,Hq,dh)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_prefill(qt, kt, vt, causal=causal, window=window,
                      block_q=block_q, block_k=block_k,
                      interpret=_interp(interpret))
    return o.transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode(q, k_cache, v_cache, length, *, block_k=512, interpret=None):
    """q (B,Hq,dh), caches (B,S,Hkv,dh), length scalar -> (B,Hq,dh).

    GQA: kv heads are mapped over with their q-head group.
    """
    B, Hq, dh = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, dh).transpose(1, 0, 2, 3)        # (Hkv,B,G,dh)
    kg = k_cache.transpose(2, 0, 1, 3)[:, :, :, None]          # (Hkv,B,S,1,dh)
    vg = v_cache.transpose(2, 0, 1, 3)[:, :, :, None]
    lv = jnp.asarray(length, jnp.int32).reshape(1)
    fn = lambda qq, kk, vv: flash_decode(qq, kk, vv, lv, block_k=block_k,
                                         interpret=_interp(interpret))
    o = jax.vmap(fn)(qg, kg, vg)                               # (Hkv,B,G,dh)
    return o.transpose(1, 0, 2, 3).reshape(B, Hq, dh)


@partial(jax.jit, static_argnames=("interpret",))
def decode_paged(q, k_pool, v_pool, tables, len1, *, interpret=None):
    """Paged-native GQA decode: q (B,Hq,dh) against the block pool.

    k_pool/v_pool (rows, block, Hkv, dh) — ``PagedKVCache`` arrays;
    tables (B, maxb) int32 padded with the scratch row; len1 (B,) int32 =
    per-lane valid positions (length + 1 after this step's scatter).
    Returns (B, Hq, dh). Queries are grouped (B, Hkv, G, dh) so each kv
    head's tile serves its whole q-head group from one gather.
    """
    B, Hq, dh = q.shape
    Hkv = k_pool.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, dh)
    o = flash_decode_paged(qg, k_pool, v_pool, tables, len1,
                           interpret=_interp(interpret))
    return o.reshape(B, Hq, dh)
