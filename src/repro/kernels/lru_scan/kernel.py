"""RG-LRU linear-recurrence Pallas kernel (recurrentgemma's hot loop).

Streaming-dataflow design (paper §III-A applied to a recurrence):
  * grid (B, D/blk_d, S/blk_s) — the time axis is the LAST (sequential on
    TPU) grid dimension, so the running state h lives in VMEM scratch
    across time blocks: the recurrence never round-trips to HBM.
  * each step streams one (blk_s, blk_d) tile of the a/b coefficient
    tensors from HBM exactly once — the kernel is memory-bound at the
    theoretical minimum traffic (read a,b once; write h once).
  * within a tile the recurrence runs as blk_s VPU-width elementwise fmas
    over the (blk_d,) state vector.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lru_kernel(a_ref, b_ref, o_ref, h_ref, *, blk_s):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)         # (blk_s, blk_d)
    b = b_ref[0].astype(jnp.float32)

    def body(t, h):
        h = a[t] * h + b[t]
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, blk_s, body, h_ref[...])


def lru_scan(a, b, *, block_s: int = 256, block_d: int = 256,
             interpret: bool = False):
    """a, b (B, S, D) -> h (B, S, D) with h_t = a_t h_{t-1} + b_t, h_{-1}=0."""
    B, S, D = a.shape
    bs = min(block_s, S)
    bd = min(block_d, D)
    assert S % bs == 0 and D % bd == 0
    grid = (B, D // bd, S // bs)
    kernel = functools.partial(_lru_kernel, blk_s=bs)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, bd), lambda b_, d, s: (b_, s, d)),
            pl.BlockSpec((1, bs, bd), lambda b_, d, s: (b_, s, d)),
        ],
        out_specs=pl.BlockSpec((1, bs, bd), lambda b_, d, s: (b_, s, d)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), a.dtype),
        scratch_shapes=[pltpu.VMEM((bd,), jnp.float32)],
        interpret=interpret,
    )(a, b)
