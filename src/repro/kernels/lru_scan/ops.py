"""Jit'd wrapper for the RG-LRU recurrence kernel."""
from functools import partial

import jax

from repro.kernels.lru_scan.kernel import lru_scan as _lru_scan


def _interp(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


@partial(jax.jit, static_argnames=("block_s", "block_d", "interpret"))
def lru_scan(a, b, *, block_s=256, block_d=256, interpret=None):
    return _lru_scan(a, b, block_s=block_s, block_d=block_d,
                     interpret=_interp(interpret))
