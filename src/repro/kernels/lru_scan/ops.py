"""Jit'd wrapper for the RG-LRU recurrence kernel."""
from functools import partial

import jax

from repro.kernels.lru_scan.kernel import lru_scan as _lru_scan
from repro.kernels.runtime import resolve_interpret as _interp


@partial(jax.jit, static_argnames=("block_s", "block_d", "interpret"))
def lru_scan(a, b, *, block_s=256, block_d=256, interpret=None):
    return _lru_scan(a, b, block_s=block_s, block_d=block_d,
                     interpret=_interp(interpret))
