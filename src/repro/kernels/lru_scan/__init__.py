from repro.kernels.lru_scan.ops import lru_scan
from repro.kernels.lru_scan import ref

__all__ = ["lru_scan", "ref"]
