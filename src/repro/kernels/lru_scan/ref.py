"""Oracle for the RG-LRU linear recurrence: h_t = a_t * h_{t-1} + b_t."""
import jax
import jax.numpy as jnp


def lru_scan_ref(a, b, h0=None):
    """a, b (B, S, D) -> h (B, S, D); optional initial state h0 (B, D)."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)
    return jax.lax.associative_scan(
        lambda c1, c2: (c1[0] * c2[0], c2[0] * c1[1] + c2[1]), (a, b),
        axis=1)[1]
