"""Pallas TPU kernels for the perf-critical compute paths.

Each kernel package ships kernel.py (pl.pallas_call + BlockSpec), ops.py
(jit'd wrapper, auto-interpret on CPU), and ref.py (pure-jnp oracle).

  flash_attention — prefill flash attention (causal/SWA block skipping) and
                    flash-decode (cache streaming at HBM bandwidth).
  fused_decode    — norm+QKV+RoPE and norm+SwiGLU+residual decode kernels;
                    ops.decoder_layer_step composes the paper's fused
                    decoder-layer decode claim on TPU.
  monarch_fft     — the paper's Fig-3 fusion showcase (FlashFFTConv):
                    Gemm0 -> Mul -> Transpose -> Gemm1 in one kernel, plus
                    the fully-fused FFT-conv variant.
  lru_scan        — RG-LRU linear recurrence (recurrentgemma's hot loop):
                    state lives in VMEM scratch across time blocks,
                    coefficients stream from HBM exactly once.
"""
