"""Fused decode-step Pallas kernels: the paper's headline dataflow claim.

SN40L fuses an *entire decoder layer* into one kernel for autoregressive
decode (paper §VI-B: >85% HBM bandwidth, near-zero launch overhead). On TPU
the equivalent is a minimal-HBM-traffic schedule: every weight byte is read
exactly once per token, and all intermediate activations stay in VMEM.

Kernels:
  * ``qkv_rope``:  RMSNorm + QKV projection + RoPE in one pass. Grid streams
    one head-column block of the fused [Wq|Wk|Wv] matrix per step; the
    normalized activation vector lives in VMEM, rotary phases are computed
    in-kernel from the position scalar. V-heads skip rotation by flag.
  * ``ffn_swiglu``: RMSNorm + SwiGLU MLP + residual for decode. Grid streams
    (gate, up, down) column/row blocks; the f32 output accumulator persists
    in VMEM scratch across the sequential grid axis — one pass over all FFN
    weights, the theoretical HBM minimum.

The attention itself is ``kernels/flash_attention.flash_decode`` (cache
streaming at HBM bandwidth).

Paged-native variants (what ``serving.backends.FusedPagedBackend`` runs —
these take the engine's layouts directly, no weight concat / cache copy):
  * ``qkv_rope_paged``: per-lane positions (decode lanes sit at ragged
    depths) and the native separate wq/wk/wv (D,H,dh) weights, streamed one
    head per grid step via clamped per-segment index maps.
  * ``oproj_ffn_swiglu``: the whole layer epilogue — attention out-proj +
    residual + RMSNorm + SwiGLU + residual — with the post-attention
    activation pinned in VMEM between the two residual adds.
  * ``ffn_swiglu(residual=False)``: the tensor-parallel partial form (down-
    proj partials psum'd across shards before the residual).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)


# ----------------------------------------------------------------------
# norm + qkv + rope
# ----------------------------------------------------------------------

def _qkv_kernel(pos_ref, x_ref, scale_ref, w_ref, o_ref, *, dh, n_q, n_kv,
                theta, rope_frac):
    h = pl.program_id(0)
    xn = _rms(x_ref[...], scale_ref[...])                  # (B, D) f32
    y = jnp.dot(xn, w_ref[:, 0, :].astype(jnp.float32))    # (B, dh)

    rot = int(dh * rope_frac) - int(dh * rope_frac) % 2
    pos = pos_ref[0].astype(jnp.float32)
    di = jax.lax.iota(jnp.float32, rot // 2)
    inv = jnp.exp(-jnp.log(theta) * (2.0 * di / rot))
    ang = pos * inv
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    y1, y2, yp = y[:, : rot // 2], y[:, rot // 2: rot], y[:, rot:]
    yr = jnp.concatenate([y1 * cos - y2 * sin, y2 * cos + y1 * sin, yp], axis=-1)
    is_v = h >= (n_q + n_kv)
    o_ref[0] = jnp.where(is_v, y, yr).astype(o_ref.dtype)


def qkv_rope(x, norm_scale, w_qkv, pos, *, n_q, n_kv, dh, theta=10000.0,
             rope_frac=1.0, interpret=False):
    """x (B,D); w_qkv (D, (n_q+2*n_kv)*dh), column-blocked one head per step.

    Returns (H_total, B, dh) with RoPE applied to q and k heads (v skipped).
    """
    B, D = x.shape
    H = n_q + 2 * n_kv
    assert w_qkv.shape == (D, H * dh)
    kernel = functools.partial(_qkv_kernel, dh=dh, n_q=n_q, n_kv=n_kv,
                               theta=theta, rope_frac=rope_frac)
    return pl.pallas_call(
        kernel,
        grid=(H,),
        in_specs=[
            pl.BlockSpec((1,), lambda h: (0,)),
            pl.BlockSpec((B, D), lambda h: (0, 0)),
            pl.BlockSpec((D,), lambda h: (0,)),
            pl.BlockSpec((D, 1, dh), lambda h: (0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, B, dh), lambda h: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((H, B, dh), x.dtype),
        interpret=interpret,
    )(jnp.asarray(pos, jnp.int32).reshape(1), x, norm_scale,
      w_qkv.reshape(D, H, dh))


# ----------------------------------------------------------------------
# paged-native norm + qkv + rope (per-lane positions, unconcatenated weights)
# ----------------------------------------------------------------------

def _qkv_paged_kernel(pos_ref, x_ref, scale_ref, inv_ref, wq_ref, wk_ref,
                      wv_ref, o_ref, *, n_q, n_kv):
    h = pl.program_id(0)
    xn = _rms(x_ref[...], scale_ref[...])                  # (B, D) f32
    # all three weight blocks are VMEM-resident each step, but their index
    # maps clamp outside their own segment — Pallas only re-DMAs a block
    # when its mapped index CHANGES, so each weight byte streams exactly once
    wq = wq_ref[:, 0, :].astype(jnp.float32)
    wk = wk_ref[:, 0, :].astype(jnp.float32)
    wv = wv_ref[:, 0, :].astype(jnp.float32)
    w = jnp.where(h < n_q, wq, jnp.where(h < n_q + n_kv, wk, wv))
    y = jnp.dot(xn, w)                                     # (B, dh)

    # per-lane rotary: angles from each lane's own position (decode lanes sit
    # at ragged depths in the paged pool — there is no shared position scalar)
    inv = inv_ref[...]                                     # (rot/2,) f32
    rot = 2 * inv.shape[0]
    ang = pos_ref[...].astype(jnp.float32)[:, None] * inv[None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)                  # (B, rot/2)
    y1, y2, yp = y[:, : rot // 2], y[:, rot // 2: rot], y[:, rot:]
    yr = jnp.concatenate([y1 * cos - y2 * sin, y2 * cos + y1 * sin, yp],
                         axis=-1)
    is_v = h >= (n_q + n_kv)
    o_ref[0] = jnp.where(is_v, y, yr).astype(o_ref.dtype)


def qkv_rope_paged(x, norm_scale, wq, wk, wv, pos, *, theta=10000.0,
                   rope_frac=1.0, interpret=False):
    """RMSNorm + QKV + per-lane RoPE for the paged decode step.

    x (B,D); wq (D,n_q,dh), wk/wv (D,n_kv,dh) — the engine's NATIVE attention
    param layout, streamed per head without materializing a fused [Wq|Wk|Wv]
    concat; pos (B,) int32 per-lane positions. Returns (q (B,n_q,dh),
    k (B,n_kv,dh), v (B,n_kv,dh)) with RoPE applied to q and k.
    """
    import numpy as np
    B, D = x.shape
    _, n_q, dh = wq.shape
    n_kv = wk.shape[1]
    H = n_q + 2 * n_kv
    rot = int(dh * rope_frac)
    rot -= rot % 2
    # host-side inv_freq with the exact numpy arithmetic of
    # models.layers._rope_angles, so fused and XLA paths agree bit-for-bit
    inv_freq = (1.0 / (theta ** (np.arange(0, rot, 2) / rot))
                ).astype(np.float32)
    kernel = functools.partial(_qkv_paged_kernel, n_q=n_q, n_kv=n_kv)
    out = pl.pallas_call(
        kernel,
        grid=(H,),
        in_specs=[
            pl.BlockSpec((B,), lambda h: (0,)),
            pl.BlockSpec((B, D), lambda h: (0, 0)),
            pl.BlockSpec((D,), lambda h: (0,)),
            pl.BlockSpec((rot // 2,), lambda h: (0,)),
            pl.BlockSpec((D, 1, dh),
                         lambda h: (0, jnp.minimum(h, n_q - 1), 0)),
            pl.BlockSpec((D, 1, dh),
                         lambda h: (0, jnp.clip(h - n_q, 0, n_kv - 1), 0)),
            pl.BlockSpec((D, 1, dh),
                         lambda h: (0, jnp.clip(h - n_q - n_kv, 0, n_kv - 1),
                                    0)),
        ],
        out_specs=pl.BlockSpec((1, B, dh), lambda h: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((H, B, dh), x.dtype),
        interpret=interpret,
    )(jnp.asarray(pos, jnp.int32), x, norm_scale, jnp.asarray(inv_freq),
      wq, wk, wv)
    q = out[:n_q].transpose(1, 0, 2)
    k = out[n_q:n_q + n_kv].transpose(1, 0, 2)
    v = out[n_q + n_kv:].transpose(1, 0, 2)
    return q, k, v


# ----------------------------------------------------------------------
# norm + SwiGLU FFN + residual
# ----------------------------------------------------------------------

def _ffn_kernel(x_ref, scale_ref, wg_ref, wu_ref, wo_ref, o_ref, acc_ref,
                *, nf, residual):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xn = _rms(x_ref[...], scale_ref[...])                   # (B, D) f32
    g = jnp.dot(xn, wg_ref[...].astype(jnp.float32))        # (B, bf)
    u = jnp.dot(xn, wu_ref[...].astype(jnp.float32))
    hidden = g * jax.nn.sigmoid(g) * u                      # silu(g)*u
    acc_ref[...] += jnp.dot(hidden, wo_ref[...].astype(jnp.float32))

    @pl.when(j == nf - 1)
    def _done():
        out = acc_ref[...]
        if residual:
            out = x_ref[...].astype(jnp.float32) + out
        o_ref[...] = out.astype(o_ref.dtype)


def ffn_swiglu(x, norm_scale, w_gate, w_up, w_down, *, block_f=512,
               residual=True, interpret=False):
    """x (B,D) -> x + SwiGLU(RMSNorm(x)); single pass over FFN weights.

    ``residual=False`` returns just SwiGLU(RMSNorm(x)) — the tensor-parallel
    partial form, where the down-projection output must be psum'd across the
    shards BEFORE the residual add (node/execution.py fused TP path).
    """
    B, D = x.shape
    F = w_gate.shape[1]
    bf = min(block_f, F)
    assert F % bf == 0
    nf = F // bf
    kernel = functools.partial(_ffn_kernel, nf=nf, residual=residual)
    return pl.pallas_call(
        kernel,
        grid=(nf,),
        in_specs=[
            pl.BlockSpec((B, D), lambda j: (0, 0)),
            pl.BlockSpec((D,), lambda j: (0,)),
            pl.BlockSpec((D, bf), lambda j: (0, j)),
            pl.BlockSpec((D, bf), lambda j: (0, j)),
            pl.BlockSpec((bf, D), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((B, D), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((B, D), jnp.float32)],
        interpret=interpret,
    )(x, norm_scale, w_gate, w_up, w_down)


# ----------------------------------------------------------------------
# out-proj + residual + norm + SwiGLU FFN + residual (the layer epilogue)
# ----------------------------------------------------------------------

def _oproj_ffn_kernel(x_ref, attn_ref, wo_ref, scale_ref, wg_ref, wu_ref,
                      wd_ref, o_ref, y_ref, acc_ref, *, nf):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        # attention epilogue once: y = x + attn @ Wo, then y persists in
        # VMEM as both the FFN-norm input and the final residual base
        y_ref[...] = x_ref[...].astype(jnp.float32) + jnp.dot(
            attn_ref[...].astype(jnp.float32),
            wo_ref[...].astype(jnp.float32))
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xn = y_ref[...] * jax.lax.rsqrt(
        jnp.mean(y_ref[...] * y_ref[...], axis=-1, keepdims=True) + 1e-6
    ) * scale_ref[...].astype(jnp.float32)
    g = jnp.dot(xn, wg_ref[...].astype(jnp.float32))        # (B, bf)
    u = jnp.dot(xn, wu_ref[...].astype(jnp.float32))
    hidden = g * jax.nn.sigmoid(g) * u
    acc_ref[...] += jnp.dot(hidden, wd_ref[...].astype(jnp.float32))

    @pl.when(j == nf - 1)
    def _done():
        o_ref[...] = (y_ref[...] + acc_ref[...]).astype(o_ref.dtype)


def oproj_ffn_swiglu(x, attn_out, w_o, norm_scale, w_gate, w_up, w_down, *,
                     block_f=512, interpret=False):
    """The whole decoder-layer epilogue in one kernel:

        y = x + attn_out @ w_o                 (attention out-proj + residual)
        return y + SwiGLU(RMSNorm(y))          (FFN + residual)

    x (B,D); attn_out (B, Hq*dh); w_o (Hq*dh, D) — the engine's native
    (Hq,dh,D) ``wo`` reshaped (contiguous, no copy). Wo's constant index map
    keeps it VMEM-resident across the FFN grid, so it streams from HBM once;
    ``y`` never round-trips to HBM between out-proj and FFN. (At full model
    scale Wo would also be grid-tiled; the reduced configs this repo measures
    fit it in VMEM whole.)
    """
    B, D = x.shape
    HD = attn_out.shape[1]
    F = w_gate.shape[1]
    bf = min(block_f, F)
    assert F % bf == 0
    nf = F // bf
    kernel = functools.partial(_oproj_ffn_kernel, nf=nf)
    return pl.pallas_call(
        kernel,
        grid=(nf,),
        in_specs=[
            pl.BlockSpec((B, D), lambda j: (0, 0)),
            pl.BlockSpec((B, HD), lambda j: (0, 0)),
            pl.BlockSpec((HD, D), lambda j: (0, 0)),
            pl.BlockSpec((D,), lambda j: (0,)),
            pl.BlockSpec((D, bf), lambda j: (0, j)),
            pl.BlockSpec((D, bf), lambda j: (0, j)),
            pl.BlockSpec((bf, D), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((B, D), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((B, D), jnp.float32),
            pltpu.VMEM((B, D), jnp.float32),
        ],
        interpret=interpret,
    )(x, attn_out, w_o, norm_scale, w_gate, w_up, w_down)
