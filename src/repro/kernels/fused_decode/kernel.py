"""Fused decode-step Pallas kernels: the paper's headline dataflow claim.

SN40L fuses an *entire decoder layer* into one kernel for autoregressive
decode (paper §VI-B: >85% HBM bandwidth, near-zero launch overhead). On TPU
the equivalent is a minimal-HBM-traffic schedule: every weight byte is read
exactly once per token, and all intermediate activations stay in VMEM.

Kernels:
  * ``qkv_rope``:  RMSNorm + QKV projection + RoPE in one pass. Grid streams
    one head-column block of the fused [Wq|Wk|Wv] matrix per step; the
    normalized activation vector lives in VMEM, rotary phases are computed
    in-kernel from the position scalar. V-heads skip rotation by flag.
  * ``ffn_swiglu``: RMSNorm + SwiGLU MLP + residual for decode. Grid streams
    (gate, up, down) column/row blocks; the f32 output accumulator persists
    in VMEM scratch across the sequential grid axis — one pass over all FFN
    weights, the theoretical HBM minimum.

The attention itself is ``kernels/flash_attention.flash_decode`` (cache
streaming at HBM bandwidth). The output projection is left to XLA: its cost
is one read of Wo — already optimal, fusion buys nothing there.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)


# ----------------------------------------------------------------------
# norm + qkv + rope
# ----------------------------------------------------------------------

def _qkv_kernel(pos_ref, x_ref, scale_ref, w_ref, o_ref, *, dh, n_q, n_kv,
                theta, rope_frac):
    h = pl.program_id(0)
    xn = _rms(x_ref[...], scale_ref[...])                  # (B, D) f32
    y = jnp.dot(xn, w_ref[:, 0, :].astype(jnp.float32))    # (B, dh)

    rot = int(dh * rope_frac) - int(dh * rope_frac) % 2
    pos = pos_ref[0].astype(jnp.float32)
    di = jax.lax.iota(jnp.float32, rot // 2)
    inv = jnp.exp(-jnp.log(theta) * (2.0 * di / rot))
    ang = pos * inv
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    y1, y2, yp = y[:, : rot // 2], y[:, rot // 2: rot], y[:, rot:]
    yr = jnp.concatenate([y1 * cos - y2 * sin, y2 * cos + y1 * sin, yp], axis=-1)
    is_v = h >= (n_q + n_kv)
    o_ref[0] = jnp.where(is_v, y, yr).astype(o_ref.dtype)


def qkv_rope(x, norm_scale, w_qkv, pos, *, n_q, n_kv, dh, theta=10000.0,
             rope_frac=1.0, interpret=False):
    """x (B,D); w_qkv (D, (n_q+2*n_kv)*dh), column-blocked one head per step.

    Returns (H_total, B, dh) with RoPE applied to q and k heads (v skipped).
    """
    B, D = x.shape
    H = n_q + 2 * n_kv
    assert w_qkv.shape == (D, H * dh)
    kernel = functools.partial(_qkv_kernel, dh=dh, n_q=n_q, n_kv=n_kv,
                               theta=theta, rope_frac=rope_frac)
    return pl.pallas_call(
        kernel,
        grid=(H,),
        in_specs=[
            pl.BlockSpec((1,), lambda h: (0,)),
            pl.BlockSpec((B, D), lambda h: (0, 0)),
            pl.BlockSpec((D,), lambda h: (0,)),
            pl.BlockSpec((D, 1, dh), lambda h: (0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, B, dh), lambda h: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((H, B, dh), x.dtype),
        interpret=interpret,
    )(jnp.asarray(pos, jnp.int32).reshape(1), x, norm_scale,
      w_qkv.reshape(D, H, dh))


# ----------------------------------------------------------------------
# norm + SwiGLU FFN + residual
# ----------------------------------------------------------------------

def _ffn_kernel(x_ref, scale_ref, wg_ref, wu_ref, wo_ref, o_ref, acc_ref,
                *, nf):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xn = _rms(x_ref[...], scale_ref[...])                   # (B, D) f32
    g = jnp.dot(xn, wg_ref[...].astype(jnp.float32))        # (B, bf)
    u = jnp.dot(xn, wu_ref[...].astype(jnp.float32))
    hidden = g * jax.nn.sigmoid(g) * u                      # silu(g)*u
    acc_ref[...] += jnp.dot(hidden, wo_ref[...].astype(jnp.float32))

    @pl.when(j == nf - 1)
    def _done():
        o_ref[...] = (x_ref[...].astype(jnp.float32) + acc_ref[...]).astype(
            o_ref.dtype)


def ffn_swiglu(x, norm_scale, w_gate, w_up, w_down, *, block_f=512,
               interpret=False):
    """x (B,D) -> x + SwiGLU(RMSNorm(x)); single pass over FFN weights."""
    B, D = x.shape
    F = w_gate.shape[1]
    bf = min(block_f, F)
    assert F % bf == 0
    nf = F // bf
    kernel = functools.partial(_ffn_kernel, nf=nf)
    return pl.pallas_call(
        kernel,
        grid=(nf,),
        in_specs=[
            pl.BlockSpec((B, D), lambda j: (0, 0)),
            pl.BlockSpec((D,), lambda j: (0,)),
            pl.BlockSpec((D, bf), lambda j: (0, j)),
            pl.BlockSpec((D, bf), lambda j: (0, j)),
            pl.BlockSpec((bf, D), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((B, D), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((B, D), jnp.float32)],
        interpret=interpret,
    )(x, norm_scale, w_gate, w_up, w_down)
