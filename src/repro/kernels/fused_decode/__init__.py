from repro.kernels.fused_decode.ops import decoder_layer_step
from repro.kernels.fused_decode.kernel import qkv_rope, ffn_swiglu
from repro.kernels.fused_decode import ref

__all__ = ["decoder_layer_step", "qkv_rope", "ffn_swiglu", "ref"]
