"""Pure-jnp oracle: one dense decoder-layer decode step (llama-style)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def rms_ref(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)


def rope_ref(y, pos, theta, rope_frac=1.0):
    """y (..., dh), pos scalar."""
    dh = y.shape[-1]
    rot = int(dh * rope_frac) - int(dh * rope_frac) % 2
    yr, yp = y[..., :rot], y[..., rot:]
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2) / rot))
    ang = pos.astype(jnp.float32) * inv
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    y1, y2 = yr[..., : rot // 2], yr[..., rot // 2:]
    out = jnp.concatenate([y1 * cos - y2 * sin, y2 * cos + y1 * sin], axis=-1)
    return jnp.concatenate([out, yp], axis=-1) if yp.shape[-1] else out


def qkv_rope_ref(x, norm_scale, w_qkv, pos, *, n_q, n_kv, dh, theta=10000.0,
                 rope_frac=1.0):
    B, D = x.shape
    H = n_q + 2 * n_kv
    xn = rms_ref(x, norm_scale)
    y = (xn @ w_qkv.astype(jnp.float32)).reshape(B, H, dh)
    rot = rope_ref(y, pos, theta, rope_frac)
    is_v = jnp.arange(H) >= (n_q + n_kv)
    out = jnp.where(is_v[None, :, None], y, rot)
    return out.transpose(1, 0, 2).astype(x.dtype)         # (H, B, dh)


def ffn_swiglu_ref(x, norm_scale, w_gate, w_up, w_down):
    xn = rms_ref(x, norm_scale)
    g = xn @ w_gate.astype(jnp.float32)
    u = xn @ w_up.astype(jnp.float32)
    h = jax.nn.silu(g) * u
    return (x.astype(jnp.float32) + h @ w_down.astype(jnp.float32)).astype(x.dtype)


def decoder_layer_step_ref(x, p, k_cache, v_cache, pos, *, n_q, n_kv, dh,
                           theta=10000.0):
    """Full decode step for one layer. x (B,D). Returns (y, k_cache, v_cache)."""
    B, D = x.shape
    qkv = qkv_rope_ref(x, p["attn_norm"], p["w_qkv"], pos,
                       n_q=n_q, n_kv=n_kv, dh=dh, theta=theta)
    q = qkv[:n_q].transpose(1, 0, 2)                       # (B,n_q,dh)
    k = qkv[n_q:n_q + n_kv].transpose(1, 0, 2)
    v = qkv[n_q + n_kv:].transpose(1, 0, 2)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k[:, None], pos, 1)[0] \
        if False else jax.lax.dynamic_update_slice_in_dim(
            k_cache, k[:, None, :, :].reshape(B, 1, n_kv, dh), pos, 1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v[:, None, :, :].reshape(B, 1, n_kv, dh), pos, 1)
    from repro.kernels.flash_attention.ref import decode_attention_ref
    o = decode_attention_ref(q, k_cache, v_cache, pos + 1)  # (B,n_q,dh)
    y = x + (o.reshape(B, n_q * dh) @ p["w_o"]).astype(x.dtype)
    y = ffn_swiglu_ref(y, p["mlp_norm"], p["w_gate"], p["w_up"], p["w_down"])
    return y, k_cache, v_cache
