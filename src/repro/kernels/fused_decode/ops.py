"""Jit'd wrapper: one fused decoder-layer decode step built from the Pallas
kernels (qkv_rope -> cache append -> flash_decode -> out-proj -> ffn_swiglu).

This is the TPU realization of the paper's "entire decoder layer in one
kernel call": weight bytes are each read once; activations never round-trip
to HBM between fused ops (see kernel.py header for the adaptation argument).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.fused_decode.kernel import (qkv_rope, qkv_rope_paged,
                                               ffn_swiglu, oproj_ffn_swiglu)
from repro.kernels.flash_attention.ops import decode as flash_decode_op
from repro.kernels.runtime import resolve_interpret as _interp


@partial(jax.jit, static_argnames=("n_q", "n_kv", "dh", "theta", "interpret"),
         donate_argnums=(2, 3))
def decoder_layer_step(x, p, k_cache, v_cache, pos, *, n_q, n_kv, dh,
                       theta=10000.0, interpret=None):
    """x (B,D), p dict of layer params, caches (B,S,n_kv,dh), pos scalar.

    Returns (y (B,D), k_cache, v_cache).
    """
    it = _interp(interpret)
    B, D = x.shape
    qkv = qkv_rope(x, p["attn_norm"], p["w_qkv"], pos, n_q=n_q, n_kv=n_kv,
                   dh=dh, theta=theta, interpret=it)       # (H,B,dh)
    q = qkv[:n_q].transpose(1, 0, 2)
    k = qkv[n_q:n_q + n_kv].transpose(1, 0, 2)
    v = qkv[n_q + n_kv:].transpose(1, 0, 2)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k[:, None], pos, 1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v[:, None], pos, 1)
    o = flash_decode_op(q, k_cache, v_cache, pos + 1, interpret=it)
    y = x + (o.reshape(B, n_q * dh) @ p["w_o"]).astype(x.dtype)
    y = ffn_swiglu(y, p["mlp_norm"], p["w_gate"], p["w_up"], p["w_down"],
                   interpret=it)
    return y, k_cache, v_cache
