"""Shared kernel-runtime knobs for every Pallas package.

Interpret-mode selection used to be a copy-pasted ``_interp`` helper in each
``kernels/*/ops.py``; it is now ONE documented knob:

  * ``interpret=None`` (the default everywhere) auto-detects: compiled via
    Mosaic on TPU, interpreter on every other backend (this container's CPU
    CI runs every kernel — including the paged decode path — through the
    interpreter).
  * ``interpret=True/False`` forces the mode for one call.
  * ``REPRO_PALLAS_INTERPRET=0/1`` (env var) overrides the auto-detection
    process-wide — e.g. ``=1`` to debug a Mosaic miscompile on TPU with the
    interpreter, ``=0`` to assert nothing silently falls back. An explicit
    per-call ``interpret=`` still wins over the env var.
"""
from __future__ import annotations

import os

import jax

_ENV = "REPRO_PALLAS_INTERPRET"


def resolve_interpret(interpret=None) -> bool:
    """The single interpret-mode decision for all kernel ops wrappers."""
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get(_ENV)
    if env is not None and env != "":
        return env.lower() not in ("0", "false", "no")
    return jax.default_backend() != "tpu"
