from repro.kernels.monarch_fft.ops import monarch, monarch_conv, operational_intensity
from repro.kernels.monarch_fft import ref

__all__ = ["monarch", "monarch_conv", "operational_intensity", "ref"]
