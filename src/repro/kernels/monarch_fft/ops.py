"""Jit'd public wrappers for the Monarch-FFT kernels.

On CPU (this container) the kernels run in interpret mode; on TPU they lower
through Mosaic. ``interpret=None`` auto-detects.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.monarch_fft.kernel import monarch_fused, monarch_conv_fused
from repro.kernels.monarch_fft import ref
from repro.kernels.runtime import resolve_interpret as _interp


@partial(jax.jit, static_argnames=("block_n1", "interpret"))
def monarch(x, w0, tw, w1, *, block_n1: int = 128, interpret=None):
    return monarch_fused(x, w0, tw, w1, block_n1=block_n1,
                         interpret=_interp(interpret))


@partial(jax.jit, static_argnames=("interpret",))
def monarch_conv(x, w0, tw, w1, filt, w0i, twi, w1i, *, interpret=None):
    return monarch_conv_fused(x, w0, tw, w1, filt, w0i, twi, w1i,
                              interpret=_interp(interpret))


# analytic roofline terms for the paper's Table I (operational intensity)
def operational_intensity(B, N1, N2, dtype_bytes=2, fusion="full"):
    """FLOPs/byte for the Fig-3 pipeline at a given fusion level.

    fusion levels match Table I rows: 'none' (every op materializes to HBM),
    'gemm0_mul_t' (first three ops fused), 'full' (everything fused).
    """
    flops = 2 * B * N1 * N1 * N2 + B * N1 * N2 + 2 * B * N2 * N2 * N1
    x_b = B * N1 * N2 * dtype_bytes
    w_b = (N1 * N1 + N1 * N2 + N2 * N2) * dtype_bytes
    out_b = B * N2 * N1 * dtype_bytes
    inter = B * N1 * N2 * dtype_bytes       # one intermediate tensor
    if fusion == "none":
        # gemm0: x+w0 in, a out; mul: a+tw in, a out; transpose: a in/out;
        # gemm1: a+w1 in, z out
        bytes_ = (x_b + N1 * N1 * dtype_bytes + inter) + \
                 (inter + N1 * N2 * dtype_bytes + inter) + \
                 (2 * inter) + (inter + N2 * N2 * dtype_bytes + out_b)
    elif fusion == "gemm0_mul_t":
        bytes_ = (x_b + (N1 * N1 + N1 * N2) * dtype_bytes + inter) + \
                 (inter + N2 * N2 * dtype_bytes + out_b)
    else:
        bytes_ = x_b + w_b + out_b
    return flops / bytes_
