"""Pure-jnp oracle for the Monarch-FFT pipeline (paper Fig. 3).

The simplified Monarch decomposition from the paper:
    Gemm0 -> Mul(twiddle) -> Transpose -> Gemm1
x: (B, N1, N2), w0: (N1, N1), tw: (N1, N2), w1: (N2, N2) -> out (B, N2, N1).

``monarch_conv_ref`` composes two passes around a pointwise filter — the
FlashFFTConv structure (FFT -> filter -> iFFT) the paper benchmarks.
"""
from __future__ import annotations

import jax.numpy as jnp


def monarch_ref(x, w0, tw, w1):
    a = jnp.einsum("ij,bjk->bik", w0, x, preferred_element_type=jnp.float32)
    a = a * tw
    at = a.transpose(0, 2, 1)                       # (B, N2, N1)
    out = jnp.einsum("ij,bjk->bik", w1, at.astype(w1.dtype),
                     preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def monarch_unfused_ref(x, w0, tw, w1):
    """Same math, op-by-op with materialization between each step (the
    paper's unfused baseline). Numerically identical to monarch_ref."""
    a = jnp.einsum("ij,bjk->bik", w0, x, preferred_element_type=jnp.float32)
    a = a.astype(x.dtype)                           # materialize
    a = (a * tw).astype(x.dtype)                    # materialize
    at = a.transpose(0, 2, 1)                       # materialize
    out = jnp.einsum("ij,bjk->bik", w1, at, preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def monarch_conv_ref(x, w0, tw, w1, filt, w0i, twi, w1i):
    """FFT-conv structure: monarch -> pointwise filter -> inverse monarch."""
    f = monarch_ref(x, w0, tw, w1)                  # (B, N2, N1)
    f = f * filt                                    # pointwise filter (N2, N1)
    return monarch_ref(f, w0i, twi, w1i)            # (B, N1, N2) back
