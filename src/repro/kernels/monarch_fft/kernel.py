"""Fused Monarch-FFT Pallas kernel (paper Fig. 3/4, Table I).

Pipeline fused into ONE kernel: Gemm0 -> Mul(twiddle) -> Transpose -> Gemm1.

TPU adaptation of the SN40L spatial fusion:
  * The transpose is fused "as an access pattern" (paper §IV-B): the second
    GEMM contracts over the first GEMM's output rows via ``dot_general``
    dimension numbers — A^T is never materialized (the PMU diagonal-stripe
    trick maps to MXU-native contraction-axis choice).
  * Grid = (B, N1/blk): each step streams a row-block of W0/tw from HBM into
    VMEM, computes A_blk = (W0[blk] @ x) * tw[blk], and immediately consumes
    it: Z[:, blk] = W1 @ A_blk^T. Stage buffers (paper's PMU buffers) are the
    VMEM blocks; the MXU sees (blk x N1)@(N1 x N2) and (N2 x N2)@(N2 x blk).
  * Block sizes are multiples of 128 to keep MXU tiles aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _monarch_kernel(x_ref, w0_ref, tw_ref, w1_ref, o_ref):
    # squeeze the leading batch-block dim of x/o
    a = jnp.dot(w0_ref[...], x_ref[0],
                preferred_element_type=jnp.float32)
    a = a * tw_ref[...].astype(jnp.float32)
    z = jax.lax.dot_general(
        w1_ref[...].astype(jnp.float32), a,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[0] = z.astype(o_ref.dtype)


def monarch_fused(x, w0, tw, w1, *, block_n1: int = 128,
                       interpret: bool = False):
    B, N1, N2 = x.shape
    blk = min(block_n1, N1)
    assert N1 % blk == 0
    return pl.pallas_call(
        _monarch_kernel,
        grid=(B, N1 // blk),
        in_specs=[
            pl.BlockSpec((1, N1, N2), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((blk, N1), lambda b, i: (i, 0)),
            pl.BlockSpec((blk, N2), lambda b, i: (i, 0)),
            pl.BlockSpec((N2, N2), lambda b, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, N2, blk), lambda b, i: (b, 0, i)),
        out_shape=jax.ShapeDtypeStruct((B, N2, N1), x.dtype),
        interpret=interpret,
    )(x, w0, tw, w1)


def _monarch_conv_kernel(x_ref, w0_ref, tw_ref, w1_ref, f_ref,
                         w0i_ref, twi_ref, w1i_ref, o_ref):
    """Whole FFT-conv for one batch row in VMEM: the paper's 'entire
    FlashFFTConv in a single kernel call' (13x claim)."""
    x = x_ref[0]
    a = jnp.dot(w0_ref[...], x, preferred_element_type=jnp.float32)
    a = a * tw_ref[...].astype(jnp.float32)
    f = jax.lax.dot_general(w1_ref[...].astype(jnp.float32), a,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (N2, N1)
    f = f * f_ref[...].astype(jnp.float32)                        # filter
    b = jnp.dot(w0i_ref[...].astype(jnp.float32), f,
                preferred_element_type=jnp.float32)
    b = b * twi_ref[...].astype(jnp.float32)
    z = jax.lax.dot_general(w1i_ref[...].astype(jnp.float32), b,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (N1, N2)
    o_ref[0] = z.astype(o_ref.dtype)


def monarch_conv_fused(x, w0, tw, w1, filt, w0i, twi, w1i, *,
                       interpret: bool = False):
    """Fused FFT-conv: monarch -> pointwise filter -> inverse monarch.
    x (B, N1, N2) -> (B, N1, N2). One kernel call for the whole pipeline."""
    B, N1, N2 = x.shape
    full = lambda *shape: pl.BlockSpec(shape, lambda b: tuple(0 for _ in shape))
    return pl.pallas_call(
        _monarch_conv_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, N1, N2), lambda b: (b, 0, 0)),
            full(N1, N1), full(N1, N2), full(N2, N2),
            full(N2, N1),
            full(N2, N2), full(N2, N1), full(N1, N1),
        ],
        out_specs=pl.BlockSpec((1, N1, N2), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N1, N2), x.dtype),
        interpret=interpret,
    )(x, w0, tw, w1, filt, w0i, twi, w1i)
