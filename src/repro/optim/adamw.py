"""AdamW + schedules + gradient clipping + accumulation (pure JAX, pytree).

Optimizer state mirrors the param tree, so one PartitionSpec tree shards
params, grads, and both moments identically (MaxText-style).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr * jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    t = jnp.clip((step - cfg.warmup_steps) /
                 max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / bc1
        vhat = nu / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(opt_state["mu"])
    flat_nu = jax.tree.leaves(opt_state["nu"])
    new = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(tdef, [x[0] for x in new])
    new_mu = jax.tree.unflatten(tdef, [x[1] for x in new])
    new_nu = jax.tree.unflatten(tdef, [x[2] for x in new])
    new_state = {"mu": new_mu, "nu": new_nu, "step": step + 1}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
