"""Node front-end: router-driven dispatch over socket groups (paper §V-B).

One ``RDUNode`` owns:

  * the §II Samba-CoE router (``LMRouter`` / ``HashRouter``): untagged
    requests are routed exactly once, at node arrival; caller-tagged
    requests keep their tag;
  * a shared ``ExpertStore`` — the node-wide DDR capacity tier every socket
    group streams experts from;
  * one ``CompositionOfExperts`` + tensor-parallel ``ServingEngine`` per
    socket group: each group's ``HBMWeightCache`` is its private HBM working
    set (TP-sharded over the group mesh), its paged KV pool lives sharded on
    the group's devices;
  * a ``Placement`` (``node/placement.py``) mapping experts to owning
    groups, recomputable online from observed demand (``rebalance``).

Dispatch: route -> owning groups from the placement -> least-loaded owner
(queue depth + busy slots). Per-group fairness (starvation aging, resident-
preferred group selection, prefetch) is the engine's own machinery —
unchanged from the single-device path.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.coe import CompositionOfExperts, ExpertHandle
from repro.core.memory_tiers import MachineTiers, TPU_V5E_NODE
from repro.node.execution import PrefillWorker, make_group_engine
from repro.node.placement import (ExpertProfile, Placement,
                                  plan_expert_placement)
from repro.node.topology import NodeTopology, SocketGroup
from repro.obs import trace
from repro.obs.metrics import MetricsRegistry
from repro.obs.stats import StatsView, counter_field
from repro.serving.engine import Request, ServingEngine
from repro.store import ExpertStore, HostMemoryStore


@dataclass
class GroupState:
    group: SocketGroup
    coe: CompositionOfExperts
    engine: ServingEngine
    submitted: int = 0

    @property
    def load(self) -> int:
        """Outstanding work: queued requests + busy decode slots."""
        return (len(self.engine.queue)
                + sum(s is not None for s in self.engine.slots))


class NodeStats(StatsView):
    """Node-level counters as a view over the metrics registry (``node.*``
    series). ``per_group`` — the per-socket-group breakdown list — is not a
    scalar metric and rides along as a plain attribute (the per-group
    numbers themselves live in the registry under ``group=<gid>`` labels
    when the node publishes into a shared registry)."""

    PREFIX = "node"
    DERIVED = ("imbalance",)

    requests = counter_field()
    tokens_out = counter_field()
    route_s = counter_field(0.0)
    switch_stall_s = counter_field(0.0)    # Σ per-group engine switch stalls
    starvation_overrides = counter_field()

    def __init__(self, registry=None, labels=None,
                 per_group: Optional[List[Dict[str, Any]]] = None,
                 prefill_groups: Optional[List[Dict[str, Any]]] = None,
                 **values):
        super().__init__(registry, labels, **values)
        self.per_group = list(per_group or [])
        self.prefill_groups = list(prefill_groups or [])

    @property
    def imbalance(self) -> float:
        """Inter-group load spread: (max - min) / mean of per-group tokens
        (0 = perfectly balanced). The Table-V analogue sweep reports this
        next to throughput."""
        toks = [g["tokens_out"] for g in self.per_group]
        mean = sum(toks) / max(len(toks), 1)
        return (max(toks) - min(toks)) / mean if mean else 0.0

    def tokens_per_second(self, wall_s: float) -> float:
        return self.tokens_out / wall_s if wall_s > 0 else 0.0

    def as_dict(self) -> Dict[str, Any]:
        d = super().as_dict()
        d["per_group"] = self.per_group
        if self.prefill_groups:
            d["prefill_groups"] = self.prefill_groups
        return d


class RDUNode:
    """A multi-socket serving node emulated over the host's JAX devices."""

    def __init__(self, topology: NodeTopology, cfg: ModelConfig, router,
                 router_params=None, *,
                 group_hbm_bytes: int, group_kv_reserve_bytes: int = 0,
                 store: Optional[ExpertStore] = None,
                 machine: MachineTiers = TPU_V5E_NODE,
                 avg_tokens: int = 16, replicate_share: float = 0.5,
                 registry: Optional[MetricsRegistry] = None,
                 prefill_groups: int = 0, prefill_pack: Optional[int] = None,
                 **engine_kwargs):
        """``group_hbm_bytes`` is one socket group's pooled HBM tier (its
        ``tp`` sockets' HBM behaves as one software-managed cache, the way
        the paper's compiler treats a TP domain); ``group_kv_reserve_bytes``
        carves each group's paged KV pool out of it. ``engine_kwargs`` pass
        through to every group's ``ServingEngine`` (n_slots, block_size,
        max_len, ...).

        ``prefill_groups=N`` enables DISAGGREGATED mode: the first N
        topology groups become dedicated ``PrefillWorker``s (compute-bound
        phase) and only the remaining groups run decode engines
        (bandwidth-bound phase). Requests are prefilled on a worker, then
        their KV blocks are handed off to a decode group's cache
        (``Request.handoff``) — decode engines never run a prefill forward.
        ``prefill_pack`` caps prompts per packed prefill call (default: the
        engines' ``n_slots``)."""
        if not 0 <= prefill_groups < len(topology.groups):
            raise ValueError(
                f"prefill_groups={prefill_groups} must leave at least one "
                f"decode group (topology has {len(topology.groups)})")
        self.topology = topology
        self.cfg = cfg
        self.router = router
        self.router_params = router_params
        self.store = store if store is not None else HostMemoryStore()
        self.machine = machine
        self.avg_tokens = avg_tokens
        self.replicate_share = replicate_share
        # one node-wide registry: every group's engine/cache/ledger series
        # lands here under a group=<gid> label, so the --metrics-port
        # endpoint and registry snapshots see the whole node at once
        self.registry = registry if registry is not None else MetricsRegistry()
        self.workers: List[PrefillWorker] = []
        for g in topology.groups[:prefill_groups]:
            glabels = {"group": g.gid, "role": "prefill"}
            coe = CompositionOfExperts(
                router, router_params, group_hbm_bytes,
                kv_reserve_bytes=group_kv_reserve_bytes, store=self.store,
                registry=self.registry, obs_labels=glabels)
            self.workers.append(PrefillWorker(
                g, coe, cfg,
                max_len=engine_kwargs.get("max_len", 4096),
                block_size=engine_kwargs.get("block_size", 16),
                n_pack=prefill_pack or engine_kwargs.get("n_slots", 8),
                buckets=engine_kwargs.get("prefill_buckets"),
                kv_dtype=engine_kwargs.get("kv_dtype", jnp.bfloat16),
                registry=self.registry, labels=glabels))
        self.groups: List[GroupState] = []
        for g in topology.groups[prefill_groups:]:
            glabels = {"group": g.gid}
            coe = CompositionOfExperts(
                router, router_params, group_hbm_bytes,
                kv_reserve_bytes=group_kv_reserve_bytes, store=self.store,
                registry=self.registry, obs_labels=glabels)
            eng = make_group_engine(coe, cfg, g.mesh,
                                    registry=self.registry,
                                    obs_labels=glabels, **engine_kwargs)
            self.groups.append(GroupState(group=g, coe=coe, engine=eng))
        self.placement: Optional[Placement] = None
        self.demand: Dict[str, int] = {}
        self.route_s = 0.0
        self.requests_in = 0
        # session affinity: a session's retained KV pages live in ONE
        # group's pool, so later turns must land on that group to adopt
        # them (prefix_sharing engines); maps session id -> groups index
        self._session_groups: Dict[str, int] = {}

    # -- registry ---------------------------------------------------------
    def register_expert(self, name: str, host_params, domain: str = "general"):
        """Register one expert node-wide: the first group's registration
        persists the params into the shared store; every other group
        (prefill workers included) links the store-resident copy (no extra
        DRAM)."""
        coes = ([w.coe for w in self.workers]
                + [gs.coe for gs in self.groups])
        for i, coe in enumerate(coes):
            coe.register(ExpertHandle(
                name, self.cfg, host_params if i == 0 else None,
                domain=domain))
        self.placement = None              # registry changed: replan lazily

    def expert_names(self) -> List[str]:
        return self.groups[0].coe.expert_names()

    # -- placement --------------------------------------------------------
    def plan(self, demand: Optional[Dict[str, float]] = None) -> Placement:
        """(Re)compute the expert -> group placement from a demand map
        (requests per expert; omitted experts weigh 0, an empty/None map
        plans uniform demand)."""
        coe0 = self.groups[0].coe
        demand = demand or {}
        profiles = [ExpertProfile(n, coe0.experts[n].nbytes,
                                  float(demand.get(n, 0.0)))
                    for n in coe0.expert_names()]
        with trace.span("plan_placement", cat="node",
                        experts=len(profiles)) as sp:
            self.placement = plan_expert_placement(
                profiles,
                [gs.coe.hbm_budget.weights_bytes for gs in self.groups],
                machine=self.machine, tp=self.topology.tp,
                avg_tokens=self.avg_tokens,
                replicate_share=self.replicate_share)
            sp.add(resident={g: list(v) for g, v in
                             self.placement.resident.items()})
        trace.instant("placement", cat="node",
                      groups=len(self.groups), experts=len(profiles))
        return self.placement

    def rebalance(self) -> Placement:
        """Replan from the demand observed so far and prewarm each group's
        cache with one planned-resident expert (async prefetch — never
        blocks decode)."""
        with trace.span("rebalance", cat="node",
                        demand_experts=len(self.demand)):
            placement = self.plan(dict(self.demand))
            for gs in self.groups:
                for name in placement.resident.get(gs.group.gid, ()):
                    if not gs.coe.cache.resident(name):
                        gs.coe.cache.prefetch(name)
                        trace.instant("prewarm", cat="node",
                                      group=gs.group.gid, expert=name)
                        break
        return placement

    # -- serving ----------------------------------------------------------
    def submit(self, req: Request) -> int:
        """Route (if untagged) and enqueue. Colocated mode: straight to the
        least-loaded owning decode group. Disaggregated mode: to the
        least-loaded prefill worker first — the request reaches a decode
        group later, carrying its KV handoff. Returns the chosen group's
        topology gid."""
        if self.placement is None:
            self.plan(dict(self.demand))
        with trace.span("dispatch", cat="node", request_id=req.rid) as sp:
            if req.expert is None:
                coe0 = (self.workers[0].coe if self.workers
                        else self.groups[0].coe)
                req.expert, dt = coe0.route_request(req.tokens)
                self.route_s += dt
            elif req.expert not in self.groups[0].coe.experts:
                raise KeyError(
                    f"request {req.rid}: unknown expert {req.expert!r}")
            self.demand[req.expert] = self.demand.get(req.expert, 0) + 1
            self.requests_in += 1
            if self.workers and req.handoff is None:
                w = min(self.workers, key=lambda w: w.load)
                sp.add(expert=req.expert, prefill_group=w.group.gid)
                w.submit(req)
                return w.group.gid
            gid = self._dispatch_decode(req)
            sp.add(expert=req.expert, group=gid)
        return gid

    def _dispatch_decode(self, req: Request) -> int:
        """Least-loaded owning decode group — unless the request belongs to
        a session seen before, which sticks to the group holding its
        retained KV pages (any group can execute any expert; affinity only
        overrides the load heuristic). Returns the topology gid."""
        gi = (self._session_groups.get(req.session_id)
              if req.session_id is not None else None)
        if gi is None:
            owners = self.placement.owners(req.expert) or tuple(
                range(len(self.groups)))
            gi = min(owners, key=lambda g: self.groups[g].load)
            if req.session_id is not None:
                self._session_groups[req.session_id] = gi
        self.groups[gi].engine.submit(req)
        self.groups[gi].submitted += 1
        return self.groups[gi].group.gid

    @property
    def has_work(self) -> bool:
        return (any(w.has_work for w in self.workers)
                or any(gs.engine.has_work for gs in self.groups))

    def step(self) -> List[Request]:
        """One node iteration: run every prefill worker's packed batch and
        hand the finished requests (KV attached) to decode groups, then
        step every decode engine with work; returns requests completed
        across the node."""
        done: List[Request] = []
        for w in self.workers:
            for req in w.step():
                self._dispatch_decode(req)
        for gs in self.groups:
            if gs.engine.has_work:
                done.extend(gs.engine.step())
        return done

    def drain(self, max_steps: int = 1_000_000) -> List[Request]:
        out: List[Request] = []
        steps = 0
        while self.has_work:
            out.extend(self.step())
            steps += 1
            if steps >= max_steps:
                raise RuntimeError("node drain: exceeded max_steps")
        return out

    def warmup(self, expert: Optional[str] = None):
        """AOT-compile every group's serving hot path (prefill buckets +
        scatters + decode extend) before traffic arrives."""
        for w in self.workers:
            w.warmup(expert)
        for gs in self.groups:
            gs.engine.warmup(expert)

    @property
    def warmed(self) -> bool:
        """True once every decode engine finished ``warmup()`` — the node's
        ``/readyz`` signal."""
        return all(gs.engine.warmed for gs in self.groups)

    def engines(self) -> List[Any]:
        """The decode engines, for the obs watchdog."""
        return [gs.engine for gs in self.groups]

    def debug_placement(self) -> Dict[str, Any]:
        """Current expert->group placement (``/debug/placement``)."""
        if self.placement is None:
            return {"planned": False, "groups": len(self.groups)}
        return {"planned": True, "groups": len(self.groups),
                "resident": {name: list(gids) for name, gids in
                             self.placement.resident.items()}}

    def debug_providers(self) -> Dict[str, Any]:
        """Node-wide debug snapshot map: ``placement`` plus every group's
        engine providers namespaced ``g<gid>.<name>`` (multi-group nodes
        keep one httpd)."""
        provs: Dict[str, Any] = {"placement": self.debug_placement}
        for gs in self.groups:
            for name, fn in gs.engine.debug_providers().items():
                provs[f"g{gs.group.gid}.{name}"] = fn
        return provs

    # -- accounting -------------------------------------------------------
    def hbm_within_budget(self) -> bool:
        """Every group's weight cache and KV pool inside its HBM shares
        (prefill workers' staging pools included)."""
        for gs in self.groups:
            cache, budget = gs.coe.cache, gs.coe.hbm_budget
            if cache.used_bytes > cache.capacity:
                return False
            if budget.kv_bytes and (gs.engine.pool.capacity_bytes()
                                    > budget.kv_bytes):
                return False
        for w in self.workers:
            cache, budget = w.coe.cache, w.coe.hbm_budget
            if cache.used_bytes > cache.capacity:
                return False
            if budget.kv_bytes and w.pool.capacity_bytes() > budget.kv_bytes:
                return False
        return True

    def stats(self) -> NodeStats:
        per_group = []
        for gs in self.groups:
            st, cs = gs.engine.stats, gs.coe.cache.stats
            per_group.append({
                "gid": gs.group.gid, "tp": gs.group.tp,
                "submitted": gs.submitted,
                "requests": st.requests, "tokens_out": st.tokens_out,
                "decode_rounds": st.decode_rounds,
                "occupancy": st.mean_occupancy,
                "switches": st.switches,
                "switch_stall_s": st.switch_s,
                "starvation_overrides": st.starvation_overrides,
                "cache_hits": cs.hits, "cache_misses": cs.misses,
                "prefetch_hits": cs.prefetch_hits,
                "hbm_used_bytes": gs.coe.cache.used_bytes,
            })
        prefill_groups = []
        for w in self.workers:
            cs = w.coe.cache.stats
            prefill_groups.append({
                "gid": w.group.gid, "tp": w.group.tp,
                "queued": len(w.queue), "prefilled": w.prefilled,
                "cache_hits": cs.hits, "cache_misses": cs.misses,
                "hbm_used_bytes": w.coe.cache.used_bytes,
            })
        return NodeStats(
            registry=self.registry,
            requests=sum(g["requests"] for g in per_group),
            tokens_out=sum(g["tokens_out"] for g in per_group),
            route_s=self.route_s,
            switch_stall_s=sum(g["switch_stall_s"] for g in per_group),
            starvation_overrides=sum(g["starvation_overrides"]
                                     for g in per_group),
            per_group=per_group, prefill_groups=prefill_groups)

    def close(self):
        for w in self.workers:
            w.coe.cache.close()
        for gs in self.groups:
            gs.coe.cache.close()
