"""Tensor-parallel expert execution for one socket group (paper §VI-C).

``TPPagedDecodeRunner`` is a drop-in ``PagedDecodeRunner``: the same
``prefill_kv`` / ``extend`` surface the ``ServingEngine`` drives, so
continuous batching, speculative admission logic and the ``HBMWeightCache``
prefetch pipeline all work unchanged per group. The difference is *where*
the math runs:

  * expert weights are sharded over the group mesh's ``model`` axis using
    the same ``distributed/partitioning.py`` rules the training stack uses
    (q/kv heads, FFN hidden, vocab — kv heads replicate when GQA kv < tp);
  * the paged KV pool is sharded over its kv-head dim
    (``partitioning.paged_pool_pspec``) so each socket holds only its KV
    shard;
  * one ``shard_map`` paged-extend step runs the whole decoder on local
    shards with exactly two ``psum`` reductions per layer (attention output
    projection + FFN down projection — the Megatron pattern the paper's
    inter-RDU network serves) plus one for the vocab-sharded embedding
    lookup.

Prefill goes through the inherited jitted forward: with sharded params GSPMD
partitions it along the same axes automatically — only the steady-state
decode step, where collective latency dominates, is hand-mapped.

TP=1 groups skip ``shard_map`` entirely; sharded-on-one-device params pin
the group to its own socket.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed import partitioning as part
from repro.distributed.ctx import shard_map
from repro.obs import trace
from repro.serving.engine import PagedDecodeRunner, ServingEngine
from repro.serving.prefill import record_compile


def _tp_paged_extend(cfg: ModelConfig, tp: int, kv_sharded: bool,
                     vocab_sharded: bool, params, pk, pv, tables, lengths,
                     active, tokens, scratch_row: int):
    """Per-device body of the TP paged-extend step (runs under shard_map).

    Mirrors ``serving.engine._paged_extend`` on local shards: ``params`` are
    the device-local parameter shards, ``pk/pv`` the local KV pool shard
    (kv-head dim), everything else replicated. Activations stay replicated;
    per-layer partial outputs are psum'd over ``'model'``.
    """
    from repro.models import layers as L

    B, g = tokens.shape
    block = pk.shape[2]
    maxb = tables.shape[1]
    S = maxb * block
    Hq, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    Hq_l = Hq // tp
    Hkv_l = Hkv // tp if kv_sharded else Hkv
    didx = jax.lax.axis_index("model")

    tok_tab = params["embed"]["tok"]
    if vocab_sharded:
        # vocab-sharded embedding: exactly one shard contributes a non-zero
        # row per token, so the psum is a bit-exact select, not a reduction
        Vl = tok_tab.shape[0]
        loc = tokens - didx.astype(jnp.int32) * Vl
        ok = (loc >= 0) & (loc < Vl)
        h = jnp.where(ok[..., None],
                      tok_tab[jnp.clip(loc, 0, Vl - 1)],
                      jnp.zeros((), tok_tab.dtype))
        h = jax.lax.psum(h, "model")
    else:
        h = tok_tab[tokens]

    positions = lengths[:, None] + jnp.arange(g, dtype=jnp.int32)[None]
    blk_idx = jnp.minimum(positions // block, maxb - 1)
    rows = jnp.take_along_axis(tables, blk_idx, axis=1)
    rows = jnp.where(active[:, None], rows, jnp.int32(scratch_row))
    off = positions % block
    kpos = jnp.arange(S, dtype=jnp.int32)
    mask = kpos[None, None, :] <= positions[:, :, None]           # (B,g,S)

    # kv head feeding each LOCAL q head (GQA): global q index -> global kv
    # index, shifted into the local shard when the pool is kv-sharded
    q_glob = didx * Hq_l + jnp.arange(Hq_l)
    kv_glob = q_glob * Hkv // Hq
    kv_idx = kv_glob - didx * Hkv_l if kv_sharded else kv_glob

    def body(hh, xs):
        lp, kp, vp = xs                    # kp (rows, block, Hkv_l, dh)
        p = lp["attn"]
        hn = L.apply_norm(cfg, p["norm"], hh)
        q = jnp.einsum("bsd,dhk->bshk", hn, p["wq"])              # local heads
        k = jnp.einsum("bsd,dhk->bshk", hn, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", hn, p["wv"])
        if cfg.qkv_bias:                   # head-sharded biases: local adds
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
        q = L.apply_rope(cfg, q, positions)
        k = L.apply_rope(cfg, k, positions)
        kp = kp.at[rows, off].set(k.astype(kp.dtype))
        vp = vp.at[rows, off].set(v.astype(vp.dtype))
        kc = kp[tables].reshape(B, S, *kp.shape[2:])              # (B,S,Hkv_l,dh)
        vc = vp[tables].reshape(B, S, *vp.shape[2:])
        k_sel = kc[:, :, kv_idx]                                  # (B,S,Hq_l,dh)
        v_sel = vc[:, :, kv_idx]
        s = jnp.einsum("bqhd,bshd->bhqs", q, k_sel,
                       preferred_element_type=jnp.float32) / math.sqrt(dh)
        s = jnp.where(mask[:, None], s, -jnp.inf)
        pa = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqs,bshd->bqhd", pa.astype(v_sel.dtype), v_sel,
                       preferred_element_type=jnp.float32)
        o = o.astype(hh.dtype)
        y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])               # partial
        y = jax.lax.psum(y, "model")                              # reduce #1
        if cfg.attn_out_bias:
            y = y + p["bo"]                # replicated bias: add once, post-psum
        hh = hh + y

        mp = lp["mlp"]
        hn = L.apply_norm(cfg, lp["mlp_norm"], hh)
        if cfg.act in ("swiglu", "geglu"):
            gate = hn @ mp["wi_gate"]
            up = hn @ mp["wi_up"]
            if cfg.mlp_bias:
                gate = gate + mp["bi_gate"]
                up = up + mp["bi_up"]
            hf = L._act(cfg, gate) * up
        else:
            hf = hn @ mp["wi"]
            if cfg.mlp_bias:
                hf = hf + mp["bi"]
            hf = L._act(cfg, hf)
        y = hf @ mp["wo"]                                         # partial
        y = jax.lax.psum(y, "model")                              # reduce #2
        if cfg.mlp_bias:
            y = y + mp["bo"]
        hh = hh + y
        return hh, (kp, vp)

    h, (pk, pv) = jax.lax.scan(body, h, (params["layers"], pk, pv))
    h = L.apply_norm(cfg, params["final_norm"], h)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, tok_tab)
    else:
        logits = h @ params["lm_head"]
    return logits, pk, pv                  # logits vocab-local when sharded


def _tp_fused_paged_extend(cfg: ModelConfig, tp: int, vocab_sharded: bool,
                           params, pk, pv, tables, lengths, active, tokens,
                           scratch_row: int, interpret=None):
    """Fused-backend per-device body for the single-token TP extend step.

    Requires a kv-sharded pool (``Hkv % tp == 0``): then each device's local
    q heads map contiguously onto its local kv heads with the global GQA
    group size, so the Pallas prologue + paged flash-decode run unchanged on
    local head counts. Only the two Megatron reductions (attention out-proj,
    FFN down-proj) and the K/V scatter stay in XLA — the FFN runs the fused
    SwiGLU kernel in residual-free form so its partial output can be psum'd
    before the residual add.
    """
    from repro.kernels.flash_attention.ops import decode_paged
    from repro.kernels.fused_decode.kernel import ffn_swiglu, qkv_rope_paged
    from repro.kernels.runtime import resolve_interpret
    from repro.models import layers as L

    B, g = tokens.shape
    assert g == 1
    block = pk.shape[2]
    maxb = tables.shape[1]
    it = resolve_interpret(interpret)
    didx = jax.lax.axis_index("model")

    tok_tab = params["embed"]["tok"]
    if vocab_sharded:
        # bit-exact psum-select (see _tp_paged_extend)
        Vl = tok_tab.shape[0]
        loc = tokens - didx.astype(jnp.int32) * Vl
        ok = (loc >= 0) & (loc < Vl)
        h = jnp.where(ok[..., None],
                      tok_tab[jnp.clip(loc, 0, Vl - 1)],
                      jnp.zeros((), tok_tab.dtype))
        h = jax.lax.psum(h, "model")
    else:
        h = tok_tab[tokens]
    h = h[:, 0]                                                   # (B, D)

    pos = lengths
    blk_idx = jnp.minimum(pos // block, maxb - 1)
    rows = jnp.take_along_axis(tables, blk_idx[:, None], axis=1)[:, 0]
    rows = jnp.where(active, rows, jnp.int32(scratch_row))
    off = pos % block
    len1 = lengths + 1

    def body(hh, xs):
        lp, kp, vp = xs                    # kp (rows, block, Hkv_l, dh)
        p = lp["attn"]
        q, k, v = qkv_rope_paged(hh, p["norm"]["scale"], p["wq"], p["wk"],
                                 p["wv"], pos, theta=cfg.rope_theta,
                                 interpret=it)
        kp = kp.at[rows, off].set(k.astype(kp.dtype))
        vp = vp.at[rows, off].set(v.astype(vp.dtype))
        o = decode_paged(q, kp, vp, tables, len1, interpret=it)   # (B,Hq_l,dh)
        y = jnp.einsum("bhk,hkd->bd", o.astype(hh.dtype), p["wo"])  # partial
        hh = hh + jax.lax.psum(y, "model")                        # reduce #1
        mp = lp["mlp"]
        y = ffn_swiglu(hh, lp["mlp_norm"]["scale"], mp["wi_gate"],
                       mp["wi_up"], mp["wo"], residual=False,
                       block_f=math.gcd(mp["wi_gate"].shape[1], 512),
                       interpret=it)                              # partial
        hh = hh + jax.lax.psum(y, "model")                        # reduce #2
        return hh, (kp, vp)

    h, (pk, pv) = jax.lax.scan(body, h, (params["layers"], pk, pv))
    h = L.apply_norm(cfg, params["final_norm"], h)[:, None]       # (B,1,D)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, tok_tab)
    else:
        logits = h @ params["lm_head"]
    return logits, pk, pv                  # logits vocab-local when sharded


class TPPagedDecodeRunner(PagedDecodeRunner):
    """Paged prefill/extend for one socket group's mesh.

    Requires q heads and the FFN hidden dim divisible by the TP degree (use
    ``configs.pad_for_tp``); kv heads and vocab shard when divisible and
    replicate otherwise (the same decisions ``partitioning.leaf_pspec``
    encodes — the in_specs are read off the pspec tree, never re-derived).
    """

    def __init__(self, cfg: ModelConfig, scratch_row: int, mesh: Mesh,
                 backend: str = "xla"):
        super().__init__(cfg, scratch_row, backend=backend)
        if "model" not in mesh.axis_names:
            raise ValueError("socket-group mesh must carry a 'model' axis")
        from repro.models import get_model
        self.mesh = mesh
        self.tp = int(mesh.shape["model"])
        specs = get_model(cfg).param_specs()
        self.param_pspecs = part.param_pspecs(specs, mesh)
        self.param_shardings = part.param_shardings(specs, mesh)
        self.pool_pspec = part.paged_pool_pspec(cfg, mesh)
        if self.tp == 1:
            self.kv_sharded = self.vocab_sharded = False
            return
        if cfg.n_experts > 0:
            raise ValueError("TP paged extend supports dense FFN only")
        if cfg.n_heads % cfg.n_kv_heads:
            raise ValueError("TP paged extend needs n_heads % n_kv_heads == 0")
        attn = self.param_pspecs["layers"]["attn"]
        mlp = self.param_pspecs["layers"]["mlp"]
        if attn["wq"][2] != "model" or mlp["wo"][1] != "model":
            raise ValueError(
                f"n_heads={cfg.n_heads} / d_ff={cfg.d_ff} do not shard over "
                f"tp={self.tp} — pad the config with configs.pad_for_tp")
        self.kv_sharded = attn["wk"][2] == "model"
        self.vocab_sharded = (
            self.param_pspecs["embed"]["tok"][0] == "model")
        if self.backend.name == "fused" and not self.kv_sharded:
            raise ValueError(
                "backend='fused' TP extend needs a kv-sharded pool "
                f"(n_kv_heads={cfg.n_kv_heads} does not shard over "
                f"tp={self.tp}) — use backend='xla' for this group shape")

    def place_params(self, host_tree):
        """Host pytree -> TP-sharded device pytree on the group mesh (what
        the group's ``HBMWeightCache`` uses as its ``sharding=``)."""
        return jax.device_put(host_tree, self.param_shardings)

    def _tp_body(self, g: int):
        """Per-device extend body for one group size: the fused Pallas body
        for single-token steps on the fused backend, else the XLA body
        (multi-token verify steps always take the XLA body, mirroring the
        single-device ``FusedPagedBackend`` dispatch)."""
        cfg, scratch = self.cfg, self.scratch_row
        tp, kvs, vs = self.tp, self.kv_sharded, self.vocab_sharded
        if self.backend.name == "fused" and g == 1:
            it = self.backend.interpret
            return lambda p, k, v, tb, ln, ac, tk: _tp_fused_paged_extend(
                cfg, tp, vs, p, k, v, tb, ln, ac, tk, scratch, interpret=it)
        return lambda p, k, v, tb, ln, ac, tk: _tp_paged_extend(
            cfg, tp, kvs, vs, p, k, v, tb, ln, ac, tk, scratch)

    def extend(self, params, pk, pv, tables, lengths, active, tokens):
        if self.tp == 1:
            return super().extend(params, pk, pv, tables, lengths, active,
                                  tokens)
        key = tokens.shape
        if key not in self._extend:
            record_compile("tp_extend")
            logits_spec = P(None, None, "model") if self.vocab_sharded else P()
            mapped = shard_map(
                self._tp_body(key[1]),
                mesh=self.mesh,
                in_specs=(self.param_pspecs, self.pool_pspec, self.pool_pspec,
                          P(), P(), P(), P()),
                out_specs=(logits_spec, self.pool_pspec, self.pool_pspec),
                check_vma=False)
            self._extend[key] = jax.jit(mapped, donate_argnums=(1, 2))
        args = (params, pk, pv, jnp.asarray(tables), jnp.asarray(lengths),
                jnp.asarray(active), jnp.asarray(tokens))
        if key not in self._abstract:
            self._abstract[key] = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(jnp.shape(x),
                                               jnp.asarray(x).dtype), args)
        self._last_key = key
        with trace.span("decode_kernel", cat="kernel",
                        backend=self.backend.name, tp=self.tp,
                        batch=key[0], g=key[1]):
            return self._extend[key](*args)


class PrefillWorker:
    """A socket group dedicated to prefill (disaggregated serving).

    Prefill is compute-bound, decode bandwidth-bound — colocating them makes
    every admit a head-of-line stall for the decode batch. A node in
    disaggregated mode (``RDUNode(prefill_groups=N)``) dedicates socket
    groups to prefill: each worker owns its own ``CompositionOfExperts``
    cache over the node's shared store, a ``PackedPrefillRunner`` (bucketed
    AOT forwards, TP via GSPMD on the group mesh), and a small TP-sharded
    paged pool that holds K/V only between the packed scatter and the
    block handoff. ``step()`` packs the FIFO queue's same-expert requests
    into one bucketed call, then gathers each request's blocks out of the
    group cache and attaches them as a ``PrefillHandoff`` — the node
    forwards the request to a decode group, whose engine adopts the blocks
    into its own cache without re-running the forward.
    """

    def __init__(self, group, coe, cfg: ModelConfig, *,
                 max_len: int = 4096, block_size: int = 16,
                 n_pack: int = 8, buckets=None, kv_dtype=jnp.bfloat16,
                 registry=None, labels=None):
        from repro.obs.metrics import MetricsRegistry
        from repro.serving.kvcache import PagedKVCache
        from repro.serving.prefill import PackedPrefillRunner, default_buckets

        self.group = group
        self.coe = coe
        self.cfg = cfg
        self.block = block_size
        self.registry = registry if registry is not None else MetricsRegistry()
        self.labels = dict(labels or {})
        buckets = tuple(buckets) if buckets else default_buckets(max_len)
        self.runner = PackedPrefillRunner(cfg, buckets=buckets,
                                          max_segments=n_pack)
        # staging pool: one packed bucket in flight at a time, so the cap
        # is the largest bucket's blocks + per-request rounding + scratch
        n_blocks = -(-buckets[-1] // block_size) + n_pack
        self.pool = PagedKVCache(n_blocks, block_size, cfg.n_layers,
                                 cfg.n_kv_heads, cfg.head_dim, kv_dtype,
                                 scratch=True, registry=self.registry,
                                 labels=self.labels)
        # TP placement mirrors make_group_engine: params shard over the
        # group mesh via the partitioning rules, the staging pool over its
        # kv-head dim; the packed forward is plain jit, GSPMD does the rest
        from repro.models import get_model
        specs = get_model(cfg).param_specs()
        self.param_shardings = part.param_shardings(specs, mesh=group.mesh)
        sh = NamedSharding(group.mesh, part.paged_pool_pspec(cfg, group.mesh))
        self.pool.k = jax.device_put(self.pool.k, sh)
        self.pool.v = jax.device_put(self.pool.v, sh)
        coe.cache.sharding = self.param_shardings
        self.queue = []
        self.prefilled = 0
        self._ttft_hist = self.registry.histogram("serve.ttft_s",
                                                  labels=self.labels)
        self._handoff_bytes = self.registry.counter("node.kv_handoff_bytes",
                                                    labels=self.labels)
        self._handoffs = self.registry.counter("node.kv_handoffs",
                                               labels=self.labels)

    @property
    def load(self) -> int:
        return len(self.queue)

    @property
    def has_work(self) -> bool:
        return bool(self.queue)

    def submit(self, req):
        if len(req.tokens) > self.runner.buckets[-1]:
            raise ValueError(
                f"request {req.rid}: {len(req.tokens)} prompt tokens exceed "
                f"the prefill group's largest bucket "
                f"{self.runner.buckets[-1]}")
        self.queue.append(req)

    def warmup(self, expert=None):
        """AOT-compile every bucket forward + scatter against this group's
        sharded params/pool."""
        names = self.coe.expert_names()
        if not names:
            raise RuntimeError("prefill worker warmup: no experts registered")
        params = self.coe.cache.activate(expert or names[0])
        self.runner.warmup(params, self.pool)

    def step(self):
        """Prefill one packed batch: the queue head's expert, same-expert
        requests packed FIFO until the largest bucket (or ``n_pack``) fills.
        Returns the completed requests, each carrying a ``PrefillHandoff``.
        """
        import time

        from repro.serving.prefill import PrefillHandoff

        if not self.queue:
            return []
        expert = self.queue[0].expert
        cap = self.runner.buckets[-1]
        picked, rest, total = [], [], 0
        for r in self.queue:
            n = len(r.tokens)
            if (r.expert == expert and len(picked) < self.runner.max_segments
                    and total + n <= cap):
                picked.append(r)
                total += n
            else:
                rest.append(r)
        self.queue = rest
        params = self.coe.cache.activate(expert)
        with trace.span("prefill", cat="node", group=self.group.gid,
                        expert=expert, prompt_tokens=total,
                        **{"prefill.packed": len(picked)}) as sp:
            res = self.runner(params, [r.tokens for r in picked])
            sp.add(**{"prefill.bucket": res.bucket})
            firsts = np.asarray(
                jnp.argmax(res.logits[:len(picked)], axis=-1), np.int32)
            self.runner.scatter_into(self.pool, res,
                                     [r.rid for r in picked])
        out = []
        for i, r in enumerate(picked):
            with trace.span("kv_handoff", cat="node", group=self.group.gid,
                            request_id=r.rid):
                k, v = self.pool.gather(r.rid)
                # the handoff crosses the inter-socket fabric: materialize
                # on host, then release the staging blocks
                hk, hv = np.asarray(k), np.asarray(v)
                self.pool.free(r.rid)
            r.handoff = PrefillHandoff(first_token=int(firsts[i]),
                                       k=hk, v=hv)
            now = time.perf_counter()
            r.prefill_done_s = now
            r.first_token_s = now
            self._ttft_hist.observe(now - r.arrival_s)
            self._handoff_bytes.inc(hk.nbytes + hv.nbytes)
            self._handoffs.inc()
            self.prefilled += 1
            out.append(r)
        return out


def make_group_engine(coe, cfg: ModelConfig, mesh: Mesh,
                      **engine_kwargs) -> ServingEngine:
    """A ``ServingEngine`` whose runner executes tensor-parallel on one
    socket group's mesh and whose paged KV pool lives sharded on that
    group's devices (per-socket KV shards)."""
    eng = ServingEngine(
        coe, cfg,
        runner_factory=lambda c, s, **kw: TPPagedDecodeRunner(c, s, mesh,
                                                              **kw),
        **engine_kwargs)
    sh = NamedSharding(mesh, eng.runner.pool_pspec)
    eng.pool.k = jax.device_put(eng.pool.k, sh)
    eng.pool.v = jax.device_put(eng.pool.v, sh)
    # the group's weight cache must install TP-sharded params on this mesh
    coe.cache.sharding = eng.runner.param_shardings
    return eng
