"""Socket-group topology for one multi-socket RDU node (paper §III / §VI-C).

The paper's 8-socket node runs each expert tensor-parallel over a dedicated
inter-RDU network; the node may also be carved into several independent
TP groups, each serving its own expert working set. We model that carve as
``TP degree x replica count`` over the host's device list: a node of 8
devices can run as one TP=8 group (``8x1``), four TP=2 groups (``2x4``),
eight TP=1 groups (``1x8``), and so on. Each group gets its own one-axis
``('model',)`` JAX mesh over a disjoint device subset — the inter-RDU TP
domain — while the shared ``ExpertStore`` plays the node-wide DDR tier.

Emulation: run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
to get 8 CPU "sockets" on one host.
"""
from __future__ import annotations

import os
import re
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh

from repro.launch.mesh import make_device_mesh


def ensure_emulated_sockets(n: int):
    """Make ``n`` emulated CPU sockets visible. The
    ``--xla_force_host_platform_device_count`` flag only works before the
    JAX backend initializes, so call this before anything touches devices;
    if the backend beat us to it, fail with the exact flag to relaunch
    with. Node drivers (``launch/serve.py --node-shape``,
    ``benchmarks/run.py --sweep-node``) share this bootstrap."""
    flags = os.environ.get("XLA_FLAGS", "")
    flag_re = r"--xla_force_host_platform_device_count=(\d+)"
    m = re.search(flag_re, flags)
    if m is None:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip())
    elif int(m.group(1)) < n:
        # a stale smaller count (e.g. exported by an earlier run) still
        # works if the backend has not initialized yet — raise it in place
        os.environ["XLA_FLAGS"] = re.sub(
            flag_re, f"--xla_force_host_platform_device_count={n}", flags)
    if len(jax.devices()) < n:
        raise SystemExit(
            f"{n} emulated sockets requested but the JAX backend already "
            f"initialized with {len(jax.devices())} device(s); launch with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n}")


@dataclass(frozen=True)
class SocketGroup:
    """One TP domain: ``tp`` sockets behind a single serving engine."""
    gid: int
    tp: int
    mesh: Mesh

    @property
    def devices(self) -> Tuple:
        return tuple(self.mesh.devices.flat)


@dataclass(frozen=True)
class NodeTopology:
    tp: int
    n_groups: int
    groups: Tuple[SocketGroup, ...]

    @property
    def n_sockets(self) -> int:
        return self.tp * self.n_groups

    @property
    def name(self) -> str:
        return f"{self.tp}x{self.n_groups}"


def make_node_topology(tp: int, n_groups: Optional[int] = None,
                       devices: Optional[Sequence] = None) -> NodeTopology:
    """Carve the device list into ``n_groups`` disjoint TP-``tp`` socket
    groups (default: as many groups as the devices allow). Group ``g`` owns
    devices ``[g*tp, (g+1)*tp)`` — contiguous, like the paper's pairs of
    sockets sharing a DDR channel group."""
    devs = list(devices if devices is not None else jax.devices())
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    if n_groups is None:
        n_groups = len(devs) // tp
    if n_groups < 1:
        raise ValueError(f"n_groups must be >= 1, got {n_groups}")
    need = tp * n_groups
    if need > len(devs):
        raise ValueError(
            f"topology {tp}x{n_groups} needs {need} devices but only "
            f"{len(devs)} are visible — emulate more sockets with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")
    groups = tuple(
        SocketGroup(g, tp, make_device_mesh((tp,), ("model",),
                                            devs[g * tp:(g + 1) * tp]))
        for g in range(n_groups))
    return NodeTopology(tp=tp, n_groups=n_groups, groups=groups)
