"""Multi-socket RDU-node serving (paper §III, §V-B, §VI-C).

Turns the single-device ``ServingEngine`` into an 8-socket node, emulated
on CPU with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``:

  * ``topology``  — carve the device set into TP x replica socket groups;
  * ``execution`` — shard_map tensor-parallel paged decode per group;
  * ``placement`` — bandwidth-driven expert -> group assignment under
    per-group HBM budgets;
  * ``scheduler`` — router-driven dispatch + node-level statistics.
"""
from repro.node.topology import (NodeTopology, SocketGroup,
                                 ensure_emulated_sockets, make_node_topology)
from repro.node.execution import TPPagedDecodeRunner, make_group_engine
from repro.node.placement import (ExpertProfile, Placement,
                                  plan_expert_placement)
from repro.node.scheduler import GroupState, NodeStats, RDUNode

__all__ = [
    "NodeTopology", "SocketGroup", "ensure_emulated_sockets",
    "make_node_topology",
    "TPPagedDecodeRunner", "make_group_engine",
    "ExpertProfile", "Placement", "plan_expert_placement",
    "GroupState", "NodeStats", "RDUNode",
]
