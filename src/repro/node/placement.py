"""Expert -> socket-group placement under per-group HBM budgets.

CoServe (arXiv 2503.02354) shows expert placement under limited memory
dominates CoE throughput; "AI and Memory Wall" (arXiv 2403.14123) argues
bandwidth, not FLOPs, should drive it. This planner follows both:

  * each expert's cost is ``bandwidth_model.expert_service_cost`` — the
    memory-bound decode step model at the group's TP degree, plus the
    DDR->HBM copy per activation when the expert cannot stay resident;
  * experts are assigned greedily, hottest first, to the least-loaded group
    whose remaining *weights* budget (``HBMBudget.weights_bytes``) still
    fits them — the planned-resident set per group can never exceed its HBM
    share by construction;
  * hot experts (demand share >= ``replicate_share``) are replicated across
    several groups so one group is never the bottleneck;
  * experts that fit in no group's remaining HBM spill: they still get an
    owning group (dispatch target) but stream from the shared ``ExpertStore``
    on every activation, and their cost is charged accordingly.

The plan is pure data in / data out — the node scheduler recomputes it
online from observed demand (``RDUNode.rebalance``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.bandwidth_model import expert_service_cost
from repro.core.memory_tiers import MachineTiers, TPU_V5E_NODE


@dataclass(frozen=True)
class ExpertProfile:
    """What the planner knows about one expert ahead of time: its AOT size
    contract and its (observed or forecast) demand weight."""
    name: str
    nbytes: int
    demand: float = 1.0


@dataclass(frozen=True)
class Placement:
    assignment: Dict[str, Tuple[int, ...]]   # expert -> owning group ids
    resident: Dict[int, Tuple[str, ...]]     # group -> planned-resident set
    spilled: Tuple[str, ...]                 # stream-from-store experts
    loads: Dict[int, float]                  # planned service seconds / group

    def owners(self, name: str) -> Tuple[int, ...]:
        return self.assignment.get(name, ())

    def resident_bytes(self, gid: int,
                       sizes: Mapping[str, int]) -> int:
        return sum(sizes[n] for n in self.resident.get(gid, ()))


def plan_expert_placement(profiles: Sequence[ExpertProfile],
                          group_weight_budgets: Sequence[int], *,
                          machine: MachineTiers = TPU_V5E_NODE,
                          tp: int = 1, avg_tokens: int = 16,
                          replicate_share: float = 0.5) -> Placement:
    """Greedy bandwidth-balanced assignment of experts to socket groups.

    ``group_weight_budgets[g]`` is group g's HBM weights share in bytes
    (``coe.hbm_budget.weights_bytes``). Returns a :class:`Placement` whose
    per-group resident bytes never exceed the budgets.
    """
    n_groups = len(group_weight_budgets)
    if n_groups < 1:
        raise ValueError("need at least one socket group")
    total = sum(max(p.demand, 0.0) for p in profiles)
    if total <= 0:                       # no signal yet: plan uniform demand
        profiles = [ExpertProfile(p.name, p.nbytes, 1.0) for p in profiles]
        total = float(len(profiles))

    budgets = [int(b) for b in group_weight_budgets]
    loads = {g: 0.0 for g in range(n_groups)}
    assignment: Dict[str, Tuple[int, ...]] = {}
    resident: Dict[int, List[str]] = {g: [] for g in range(n_groups)}
    spilled: List[str] = []

    def cost(p: ExpertProfile, share_of_demand: float, is_resident: bool):
        return expert_service_cost(
            p.nbytes, p.demand * share_of_demand, machine, tp=tp,
            avg_tokens=avg_tokens, resident=is_resident)

    order = sorted(profiles,
                   key=lambda p: (cost(p, 1.0, True), p.nbytes),
                   reverse=True)
    for p in order:
        share = max(p.demand, 0.0) / total
        replicas = min(n_groups, max(1, math.ceil(share / replicate_share)))
        owners: List[int] = []
        for _ in range(replicas):
            candidates = sorted((g for g in range(n_groups)
                                 if g not in owners),
                                key=lambda g: loads[g])
            fit = next((g for g in candidates if budgets[g] >= p.nbytes),
                       None)
            if fit is None:
                break
            owners.append(fit)
            budgets[fit] -= p.nbytes
            resident[fit].append(p.name)
        if owners:
            per_owner = cost(p, 1.0 / len(owners), True)
            for g in owners:
                loads[g] += per_owner
        else:
            # fits nowhere: stream from the shared store via the least
            # loaded group; every activation pays the DDR->HBM copy
            g = min(range(n_groups), key=lambda g: loads[g])
            owners = [g]
            loads[g] += cost(p, 1.0, False)
            spilled.append(p.name)
        assignment[p.name] = tuple(owners)

    return Placement(assignment=assignment,
                     resident={g: tuple(v) for g, v in resident.items()},
                     spilled=tuple(spilled),
                     loads=loads)
