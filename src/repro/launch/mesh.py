"""Production mesh definitions (see MULTI-POD DRY-RUN spec).

``make_production_mesh`` is a function — importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:                                    # jax >= 0.5 explicit-axis-types API
    from jax.sharding import AxisType
except ImportError:                     # jax 0.4.x: Auto is the only behaviour
    AxisType = None


def _mk(shape, axes) -> Mesh:
    if AxisType is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh for tests/examples (e.g. (1,1) on a laptop)."""
    return _mk(shape, axes)


def make_device_mesh(shape, axes, devices) -> Mesh:
    """Mesh over an explicit device subset (unlike ``jax.make_mesh``, which
    always grabs the whole process device list). The node topology uses this
    to carve one host's devices into independent socket-group meshes."""
    import numpy as np

    arr = np.asarray(devices, dtype=object).reshape(tuple(shape))
    return Mesh(arr, tuple(axes))


def single_device_mesh() -> Mesh:
    return make_mesh((1, 1), ("data", "model"))
