import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the REAL step function (the same one train.py /
serve.py run) against ShapeDtypeStruct stand-ins on the production mesh,
compiles it, and records memory_analysis / cost_analysis / collective bytes
into results/dryrun.json for the roofline analysis.

Usage:
    python -m repro.launch.dryrun --arch mixtral-8x7b --cell decode_32k
    python -m repro.launch.dryrun --all                  # every cell, both meshes
    python -m repro.launch.dryrun --arch ... --multi-pod-only
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_IDS, SHAPE_CELLS, cell_applicable, get_config,
                           pad_for_tp)
from repro.distributed import stepfn
from repro.distributed.ctx import activation_sharding
from repro.distributed import partitioning as part
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (RooflineTerms, collective_bytes_from_hlo,
                                   model_flops_cell)

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun.json"


def _load():
    if RESULTS.exists():
        return json.loads(RESULTS.read_text())
    return {}


def _save(d):
    RESULTS.parent.mkdir(parents=True, exist_ok=True)
    RESULTS.write_text(json.dumps(d, indent=1, default=str))


def _lower_for(cfg, cell, mesh):
    """Lower the cell's step function for this cfg on this mesh."""
    import contextlib
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed import ctx as _c
    from repro.models import get_model
    model = get_model(cfg)
    B, S = cell.global_batch, cell.seq_len
    dp = part.data_axes(mesh)
    named = {}
    if (_c.perf().moe_dispatch_constraint and cfg.n_experts
            and cfg.n_experts % mesh.shape["model"] == 0):
        named["moe_dispatch"] = P("model", None, None)
    named_cm = _c.named_shardings(**named) if named else contextlib.nullcontext()
    if cell.kind == "train":
        fn, state_sh, batch_sh_fn = stepfn.make_train_step(cfg, mesh, jit=False)
        state = stepfn.abstract_train_state(cfg, mesh)
        batch = _abstract_batch(model.input_specs(cell), mesh)
        jfn = jax.jit(fn, in_shardings=(state_sh, None),
                      out_shardings=(state_sh, None), donate_argnums=(0,))
        act_ps = P(dp, "model", None) if _c.perf().activation_sp else None
        with mesh, named_cm, _c.mesh_ctx(mesh):
            with activation_sharding(act_ps):
                return jfn.lower(state, batch)
    elif cell.kind == "prefill":
        fn, param_sh, cache_sh = stepfn.make_prefill_step(cfg, mesh, S + 128,
                                                          batch=B, jit=False)
        params = _abstract_sharded_params(cfg, mesh)
        batch = _abstract_batch(model.input_specs(cell), mesh)
        logits_sh = NamedSharding(
            mesh, part.fit_pspec((B, cfg.vocab_size), P(dp, None), mesh))
        jfn = jax.jit(fn, in_shardings=(param_sh, None),
                      out_shardings=(logits_sh, cache_sh))
        with mesh, named_cm, _c.mesh_ctx(mesh):
            return jfn.lower(params, batch)
    else:  # decode
        fn, param_sh, cache_sh = stepfn.make_decode_step(cfg, mesh, batch=B,
                                                         max_len=S, jit=False)
        params = _abstract_sharded_params(cfg, mesh)
        cache = stepfn.abstract_cache(cfg, mesh, B, S)
        tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        logits_sh = NamedSharding(
            mesh, part.fit_pspec((B, cfg.vocab_size), P(dp, None), mesh))
        jfn = jax.jit(fn, in_shardings=(param_sh, cache_sh, None, None),
                      out_shardings=(logits_sh, cache_sh), donate_argnums=(1,))
        with mesh, named_cm, _c.mesh_ctx(mesh):
            return jfn.lower(params, cache, tokens, pos)


def lower_cell(arch: str, cell_name: str, multi_pod: bool, *,
               verbose: bool = True):
    from repro.configs.base import SHAPE_CELLS as CELLS
    cell = next(c for c in CELLS if c.name == cell_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    tp = mesh.shape["model"]
    cfg = pad_for_tp(get_config(arch), tp)
    ok, why = cell_applicable(cfg, cell)
    if not ok:
        return {"status": "skipped", "reason": why}
    from jax.sharding import PartitionSpec as P

    t0 = time.time()
    lowered = _lower_for(cfg, cell, mesh)
    lower_s = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)

    chips = int(__import__("numpy").prod(list(mesh.shape.values())))
    # cost_analysis and the HLO module are the per-device SPMD program;
    # globalize so the spec's formulas (X / (chips * peak)) apply directly.
    terms = RooflineTerms(
        arch=arch, cell=cell_name,
        mesh="multi-pod(2,16,16)" if multi_pod else "single-pod(16,16)",
        chips=chips,
        hlo_flops=float(cost.get("flops", 0.0)) * chips,
        hlo_bytes=float(cost.get("bytes accessed", 0.0)) * chips,
        collective_bytes=float(sum(coll.values())) * chips,
        collective_breakdown=coll,
        model_flops=model_flops_cell(cfg, cell),
    )
    rec = {
        "status": "ok",
        "lower_s": round(lower_s, 1),
        "compile_s": round(compile_s, 1),
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "alias_bytes_per_device": mem.alias_size_in_bytes,
        },
        "roofline": terms.as_dict(),
    }
    if verbose:
        print(f"[{arch} x {cell_name} x {terms.mesh}] compile={compile_s:.0f}s")
        print(f"  memory_analysis: args={mem.argument_size_in_bytes/2**30:.2f}GiB"
              f" temp={mem.temp_size_in_bytes/2**30:.2f}GiB"
              f" out={mem.output_size_in_bytes/2**30:.2f}GiB /device")
        print(f"  cost_analysis: flops={terms.hlo_flops:.3e}"
              f" bytes={terms.hlo_bytes:.3e} coll_bytes={terms.collective_bytes:.3e}")
        print(f"  roofline: compute={terms.compute_s*1e3:.2f}ms"
              f" memory={terms.memory_s*1e3:.2f}ms"
              f" collective={terms.collective_s*1e3:.2f}ms"
              f" -> {terms.bottleneck}-bound"
              f" useful={terms.useful_flops_ratio:.2f}"
              f" roofline_frac={terms.roofline_fraction:.3f}")
    return rec


def _abstract_batch(batch_specs, mesh):
    """Attach (pod,data)-sharded batch-dim shardings where divisible."""
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = part.data_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1

    def f(s):
        lead = dp if (dp and s.shape[0] % total == 0) else None
        sp = P(lead, *([None] * (len(s.shape) - 1)))
        return jax.ShapeDtypeStruct(s.shape, s.dtype,
                                    sharding=NamedSharding(mesh, sp))
    return jax.tree.map(f, batch_specs)


def _abstract_sharded_params(cfg, mesh):
    from jax.sharding import NamedSharding
    from repro.models import get_model
    from repro.models.common import ParamSpec
    model = get_model(cfg)
    specs = model.param_specs()
    pspecs = part.param_pspecs(specs, mesh)
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                          sharding=NamedSharding(mesh, p)),
        specs, pspecs, is_leaf=lambda x: isinstance(x, ParamSpec))


def compile_cost(cfg, cell, multi_pod, unroll_layers):
    """Per-device (flops, bytes, coll) for a (possibly reduced-depth) cfg,
    with layer scans optionally unrolled — used by the loop corrector."""
    from repro.distributed.ctx import unrolled_layer_scans
    import contextlib
    mesh = make_production_mesh(multi_pod=multi_pod)
    cm = unrolled_layer_scans() if unroll_layers else contextlib.nullcontext()
    with cm:
        lowered = _lower_for(cfg, cell, mesh)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = collective_bytes_from_hlo(compiled.as_text())
    chips = int(__import__("numpy").prod(list(mesh.shape.values())))
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(sum(coll.values())),
            "coll_breakdown": coll,
            "chips": chips}


def correct_cell(arch: str, cell_name: str, multi_pod: bool, rec: dict,
                 verbose=True):
    """Attach loop-corrected roofline terms to an existing 'ok' record."""
    from repro.launch.loopfix import corrected_cell_costs
    from repro.configs.base import SHAPE_CELLS as CELLS
    cell = next(c for c in CELLS if c.name == cell_name)
    cfg = pad_for_tp(get_config(arch), 16)
    out = corrected_cell_costs(arch, cell_name, multi_pod, compile_cost)
    chips = rec["roofline"]["chips"]
    terms = RooflineTerms(
        arch=arch, cell=cell_name, mesh=rec["roofline"]["mesh"], chips=chips,
        hlo_flops=out["flops"] * chips,
        hlo_bytes=out["bytes"] * chips,
        collective_bytes=out["coll"] * chips,
        collective_breakdown=rec["roofline"]["collective_breakdown"],
        model_flops=model_flops_cell(cfg, cell),
    )
    rec["roofline_raw"] = rec.get("roofline_raw", rec["roofline"])
    rec["roofline"] = terms.as_dict()
    rec["loopfix"] = {k: out[k] for k in
                      ("flops_body", "bytes_body", "coll_body", "units",
                       "inner_flops_global", "inner_bytes_global")}
    if verbose:
        print(f"[corrected {arch} x {cell_name} x {terms.mesh}] "
              f"compute={terms.compute_s*1e3:.2f}ms "
              f"memory={terms.memory_s*1e3:.2f}ms "
              f"collective={terms.collective_s*1e3:.2f}ms "
              f"-> {terms.bottleneck} useful={terms.useful_flops_ratio:.2f} "
              f"frac={terms.roofline_fraction:.4f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--correct", action="store_true",
                    help="add loop-corrected roofline terms to ok cells")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    results = _load()
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    cells = [args.cell] if args.cell else [c.name for c in SHAPE_CELLS]
    meshes = [False, True]
    if args.multi_pod_only:
        meshes = [True]
    if args.single_pod_only:
        meshes = [False]

    failures = []
    for arch in archs:
        for cell in cells:
            for mp in meshes:
                key = f"{arch}|{cell}|{'multi' if mp else 'single'}"
                cached = key in results and \
                    results[key].get("status") in ("ok", "skipped")
                if cached and not args.force and not args.correct:
                    print(f"[cached] {key}: {results[key]['status']}")
                    continue
                if args.correct:
                    rec = results.get(key)
                    if not rec or rec.get("status") != "ok":
                        continue
                    if "loopfix" in rec and not args.force:
                        print(f"[corrected-cached] {key}")
                        continue
                    try:
                        rec = correct_cell(arch, cell, mp, rec)
                    except Exception as e:
                        traceback.print_exc()
                        failures.append(key)
                    results[key] = rec
                    _save(results)
                    continue
                try:
                    rec = lower_cell(arch, cell, mp)
                except Exception as e:
                    traceback.print_exc()
                    rec = {"status": "error", "error": f"{type(e).__name__}: {e}"}
                    failures.append(key)
                results[key] = rec
                _save(results)
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print("dry-run complete:", len(results), "cells recorded")


if __name__ == "__main__":
    main()
