"""Launchers: production mesh, multi-pod dry-run, training and CoE serving
drivers.

Deliberately empty of imports: ``python -m repro.launch.dryrun`` executes
this package __init__ BEFORE dryrun's first two lines set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` — importing jax
here would lock the device count at 1 and break the multi-pod dry-run.
Import submodules directly (repro.launch.mesh, .dryrun, .train, .serve).
"""
