"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch, shape, mesh), in seconds (see EXPERIMENTS.md §Roofline):
    compute    = HLO_FLOPs / (chips x 197e12)         [bf16 peak / chip]
    memory     = HLO_bytes / (chips x 819e9)          [HBM bw / chip]
    collective = collective_bytes / (chips x 50e9)    [ICI bw / link]

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``; collective
bytes are parsed from the optimized HLO text (result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, asdict
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e-class)
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9_\[\],{} ]+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _line_result_bytes(line: str) -> int:
    """Sum bytes of the result shapes on an HLO op line (before the op name)."""
    lhs = line.split("=", 1)
    if len(lhs) != 2:
        return 0
    # result type annotation sits between '=' and the op name
    m = re.search(r"=\s*(.*?)\s(all-gather|all-reduce|reduce-scatter|"
                  r"all-to-all|collective-permute)", line)
    if not m:
        return 0
    seg = m.group(1)
    total = 0
    for dt, dims in _SHAPE_RE.findall(seg):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes, summed over the module. ``-start``
    variants are counted; ``-done`` ops are skipped (same tensor)."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        out[kind] = out.get(kind, 0) + _line_result_bytes(line)
    return out


@dataclass
class RooflineTerms:
    arch: str
    cell: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collective_breakdown: Dict[str, int]
    model_flops: float
    per_device_peak_bytes: Optional[float] = None

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        vals = {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}
        return max(vals, key=vals.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful model FLOPs achieved vs chip peak, assuming the step runs
        at the max of the three terms (MFU-style score for compute;
        bandwidth-utilization analogue when memory/collective bound)."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        return ideal / self.step_s if self.step_s else 0.0

    def as_dict(self):
        d = asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 collective_s=self.collective_s, bottleneck=self.bottleneck,
                 step_s=self.step_s, useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def min_traffic_bytes(cfg, cell) -> float:
    """Analytic lower bound on global HBM traffic per step: every live byte
    moves once. This is the floor the §Perf loop pushes the HLO-bytes term
    toward (HLO 'bytes accessed' is an upper bound that counts per-op I/O)."""
    import numpy as np
    N = cfg.n_params()
    Na = cfg.n_active_params()
    B, S = cell.global_batch, cell.seq_len
    D = cfg.d_model
    L = cfg.n_layers
    act_token_bytes = 2 * D * L  # one residual read+write per layer, bf16
    if cell.kind == "train":
        tokens = B * S
        # params read (fwd+bwd) + grad write + adam moments r/w (f32) +
        # master update; activations: residuals once + remat recompute
        return (N * 2 * 3) + (N * 4 * 4) + tokens * act_token_bytes * 3
    if cell.kind == "prefill":
        tokens = B * S
        kv = _cache_bytes(cfg, B, S)
        return Na * 2 + tokens * act_token_bytes * 2 + kv
    # decode
    kv = _cache_bytes(cfg, B, min(S, cfg.sliding_window or S))
    return Na * 2 + kv + B * act_token_bytes


def _cache_bytes(cfg, B, S) -> float:
    if cfg.family == "mla_moe":
        return 2.0 * cfg.n_layers * B * S * (cfg.kv_lora_rank + cfg.qk_rope_dim)
    if cfg.family == "rglru":
        W = min(S, cfg.sliding_window or S)
        n_attn = max(1, cfg.n_layers // len(cfg.block_pattern))
        rec = (cfg.n_layers - n_attn) * B * cfg.d_rnn * 4
        return 2.0 * n_attn * B * W * cfg.n_kv_heads * cfg.head_dim * 2 + rec
    if cfg.family == "xlstm":
        from repro.models.xlstm import _dims
        Dm, Di, H, dh, _ = _dims(cfg)
        return cfg.n_layers * B * H * dh * dh * 4.0
    return 2.0 * cfg.n_layers * B * S * cfg.n_kv_heads * cfg.head_dim * 2


def model_flops_cell(cfg, cell) -> float:
    """Analytic useful FLOPs for the cell (6ND train / 2ND decode + attn)."""
    N = cfg.n_active_params()
    B, S = cell.global_batch, cell.seq_len
    dh = cfg.head_dim or 0
    kv = cfg.n_kv_heads
    if cell.kind == "train":
        tokens = B * S
        base = 6.0 * N * tokens
        # attention score/value flops (forward 2x2, backward x2 => x3 of fwd)
        attn = 0.0
        if cfg.family in ("dense", "moe", "encdec"):
            ctx = min(S, cfg.sliding_window) if cfg.sliding_window else S
            attn = 3 * 2 * 2 * cfg.n_layers * B * S * (ctx / 2) * cfg.n_heads * dh
        return base + attn
    if cell.kind == "prefill":
        tokens = B * S
        base = 2.0 * N * tokens
        attn = 0.0
        if cfg.family in ("dense", "moe", "encdec", "mla_moe"):
            ctx = min(S, cfg.sliding_window) if cfg.sliding_window else S
            hd = dh if cfg.family != "mla_moe" else (cfg.qk_nope_dim +
                                                     cfg.qk_rope_dim)
            attn = 2 * 2 * cfg.n_layers * B * S * (ctx / 2) * cfg.n_heads * hd
        return base + attn
    # decode: one token, context S
    base = 2.0 * N * B
    attn = 0.0
    if cfg.family in ("dense", "moe", "encdec"):
        ctx = min(S, cfg.sliding_window) if cfg.sliding_window else S
        attn = 2 * 2 * cfg.n_layers * B * ctx * cfg.n_kv_heads * \
            (cfg.n_heads // max(cfg.n_kv_heads, 1)) * dh
    elif cfg.family == "mla_moe":
        attn = 2 * 2 * cfg.n_layers * B * S * cfg.n_heads * \
            (cfg.kv_lora_rank + cfg.qk_rope_dim) / 2
    return base + attn
