"""Loop-corrected roofline costs.

XLA's HloCostAnalysis counts a while-loop body ONCE (verified empirically:
scan(length=N) reports 1/N of the unrolled flops), so the raw
cost_analysis / HLO-collective numbers undercount everything inside scans.

Correction strategy (exact for the dominant layer loop):
  1. compile the SAME cell twice at reduced depth (1 and 2 layer-units) with
     the layer scan fully UNROLLED (ctx.unrolled_layer_scans) — costs are
     then exact and linear in depth: cost(u) = outside + u * body;
  2. body = cost(2) - cost(1); corrected = cost(1) + (U_true - 1) * body;
  3. loops INSIDE a layer (streaming-attention block pairs, mLSTM chunk
     scan, sLSTM time scan) are still while-loops counted once — add
     analytic per-layer corrections (flops + bytes), x4 for training
     (forward + remat recompute + backward), x1 otherwise.

Collective bytes follow the same two-point extrapolation (inner loops carry
no collectives: attention tiles and recurrences are shard-local).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.models.layers import _block_pairs


# ----------------------------------------------------------------------
# reduced-depth configs (one/two "layer units" per family)
# ----------------------------------------------------------------------

def unit_counts(cfg: ModelConfig) -> float:
    """True number of layer-units the scan iterates (per-family)."""
    if cfg.family in ("dense", "moe"):
        return cfg.n_layers
    if cfg.family == "mla_moe":
        return cfg.n_layers - cfg.first_dense_layers   # dense layer0 is in 'outside'
    if cfg.family == "encdec":
        return cfg.n_layers                             # enc+dec scale together
    if cfg.family == "rglru":
        plen = len(cfg.block_pattern)
        groups = cfg.n_layers // plen
        tail = cfg.n_layers % plen
        return groups + (tail / plen)                   # tail ~ fraction of a group
    if cfg.family == "xlstm":
        return cfg.n_layers // cfg.slstm_every
    raise KeyError(cfg.family)


def reduced_depth_cfg(cfg: ModelConfig, units: int) -> ModelConfig:
    if cfg.family in ("dense", "moe"):
        return dataclasses.replace(cfg, n_layers=units)
    if cfg.family == "mla_moe":
        return dataclasses.replace(cfg,
                                   n_layers=cfg.first_dense_layers + units)
    if cfg.family == "encdec":
        return dataclasses.replace(cfg, n_layers=units,
                                   n_encoder_layers=units)
    if cfg.family == "rglru":
        return dataclasses.replace(cfg,
                                   n_layers=len(cfg.block_pattern) * units)
    if cfg.family == "xlstm":
        return dataclasses.replace(cfg, n_layers=cfg.slstm_every * units)
    raise KeyError(cfg.family)


# ----------------------------------------------------------------------
# analytic inner-loop corrections (per layer-unit, missing portion)
# ----------------------------------------------------------------------

def _attn_pairs_missing(cfg, B, S, window) -> Tuple[float, float]:
    """(flops, bytes) missed per attention layer by the once-counted
    block-pair scan. Zero when the naive (loop-free) path runs."""
    blk = cfg.attn_chunk
    if S <= 2 * blk or S % blk:
        return 0.0, 0.0
    nq = S // blk
    pairs = len(_block_pairs(nq, nq, blk, causal=True, window=window))
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    dh = cfg.head_dim
    dv = cfg.v_head_dim or dh
    if cfg.family == "mla_moe":
        dh = cfg.qk_nope_dim + cfg.qk_rope_dim
        dv = cfg.v_head_dim
    f_pair = (2 * B * blk * blk * Hq * dh          # scores
              + 2 * B * blk * blk * Hq * dv        # values
              + 8 * B * blk * blk * Hq)            # mask/exp/sum/max
    b_pair = (2 * B * blk * (Hq * dh + 2 * Hkv * dh)      # q/k/v tiles bf16
              + 6 * B * blk * blk * Hq * 4                # score-chain f32
              + 4 * B * blk * Hq * dv * 4)                # acc slice r/w f32
    return (pairs - 1) * f_pair, (pairs - 1) * b_pair


def _mlstm_chunks_missing(cfg, B, S) -> Tuple[float, float]:
    from repro.models.xlstm import _dims, _CHUNK
    D, Di, H, dh, _ = _dims(cfg)
    c = min(_CHUNK, S)
    nc = S // c
    if nc <= 1:
        return 0.0, 0.0
    f_chunk = (2 * B * c * c * H * dh * 2          # qk^T and @v
               + 2 * B * c * c * H * dh            # n_intra
               + 8 * B * c * c * H                 # wmat/exp/mask
               + 2 * 2 * B * c * H * dh * dh       # state update + h_inter
               )
    b_chunk = (12 * B * c * c * H * 4              # (B,c,c,H) chains f32
               + 4 * B * H * dh * dh * 4           # C state r/w f32
               + 6 * B * c * H * dh * 4)
    return (nc - 1) * f_chunk, (nc - 1) * b_chunk


def _slstm_steps_missing(cfg, B, S) -> Tuple[float, float]:
    D = cfg.d_model
    H = cfg.slstm_heads
    dh = D // H
    if S <= 1:
        return 0.0, 0.0
    f_step = 4 * (2 * B * D * D + 2 * B * H * dh * dh) + 20 * B * D
    # weights re-read per step (VMEM residency would remove this — see
    # EXPERIMENTS.md §Perf notes)
    b_step = 4 * (D * D + H * dh * dh) * 2 + 10 * B * D * 4
    return (S - 1) * f_step, (S - 1) * b_step


def inner_corrections(cfg: ModelConfig, cell: ShapeCell) -> Tuple[float, float]:
    """Total (flops, bytes) to ADD on top of the layer-extrapolated cost.
    Scaled x4 for training (fwd + remat recompute + 2x bwd), x1 otherwise.
    Decode cells have no inner loops (single-token einsums)."""
    if cell.kind == "decode":
        return 0.0, 0.0
    B, S = cell.global_batch, cell.seq_len
    scale = 4.0 if cell.kind == "train" else 1.0
    f = b = 0.0
    if cfg.family in ("dense", "moe", "mla_moe"):
        pf, pb = _attn_pairs_missing(cfg, B, S, cfg.sliding_window)
        f += pf * cfg.n_layers
        b += pb * cfg.n_layers
    elif cfg.family == "encdec":
        pf, pb = _attn_pairs_missing(cfg, B, S, 0)      # decoder self-attn
        f += pf * cfg.n_layers
        b += pb * cfg.n_layers
        # encoder attn is naive at 1500 frames (loop-free): no correction
    elif cfg.family == "rglru":
        pf, pb = _attn_pairs_missing(cfg, B, S, cfg.sliding_window)
        n_attn = sum(1 for x in cfg.block_pattern if x == "attn") * (
            cfg.n_layers // len(cfg.block_pattern))
        f += pf * n_attn
        b += pb * n_attn
    elif cfg.family == "xlstm":
        mf, mb = _mlstm_chunks_missing(cfg, B, S)
        n_m = cfg.n_layers - cfg.n_layers // cfg.slstm_every
        sf, sb = _slstm_steps_missing(cfg, B, S)
        n_s = cfg.n_layers // cfg.slstm_every
        f += mf * n_m + sf * n_s
        b += mb * n_m + sb * n_s
    return f * scale, b * scale


# ----------------------------------------------------------------------
# corrected cell costs
# ----------------------------------------------------------------------

def corrected_cell_costs(arch: str, cell_name: str, multi_pod: bool,
                         compile_fn) -> Dict[str, float]:
    """compile_fn(cfg, cell, multi_pod, unroll_layers) -> dict with
    per-device 'flops', 'bytes', 'coll' (raw, NOT globalized).

    Returns corrected per-device totals + diagnostics."""
    from repro.configs import get_config, pad_for_tp
    from repro.configs.base import SHAPE_CELLS
    cell = next(c for c in SHAPE_CELLS if c.name == cell_name)
    cfg = pad_for_tp(get_config(arch), 16)

    c1 = compile_fn(reduced_depth_cfg(cfg, 1), cell, multi_pod, True)
    c2 = compile_fn(reduced_depth_cfg(cfg, 2), cell, multi_pod, True)
    U = unit_counts(cfg)
    out = {}
    for k in ("flops", "bytes", "coll"):
        body = max(c2[k] - c1[k], 0.0)
        outside = max(c1[k] - body, 0.0)
        out[k] = outside + U * body
        out[f"{k}_body"] = body
        out[f"{k}_outside"] = outside
    fi, bi = inner_corrections(cfg, cell)
    # inner corrections are global; compile costs are per-device — convert
    chips = c1.get("chips", 1)
    out["flops"] += fi / chips
    out["bytes"] += bi / chips
    out["inner_flops_global"] = fi
    out["inner_bytes_global"] = bi
    out["units"] = U
    return out
