"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Production loop: deterministic resumable data, sharded train step,
checkpoint/restart (atomic, mesh-elastic), preemption-safe. On this CPU
container it runs reduced configs end-to-end; on a real pod the same code
runs the full configs (the mesh and shardings come from the same
make_production_mesh / partitioning the dry-run proves out).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, pad_for_tp, reduced
from repro.data import DataConfig, make_source
from repro.distributed import stepfn
from repro.distributed.ctx import activation_sharding
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.models import get_model
from repro.optim import AdamWConfig, init_opt_state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--mesh", default="1x1",
                    help="'1x1' | '16x16' | 'production' | 'production-multipod'")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    if args.mesh == "production":
        mesh = make_production_mesh()
    elif args.mesh == "production-multipod":
        mesh = make_production_mesh(multi_pod=True)
    else:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_mesh((d, m), ("data", "model"))

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    cfg = pad_for_tp(cfg, mesh.shape["model"])
    model = get_model(cfg)

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                          decay_steps=max(4, args.steps))
    step_fn, state_sh, batch_sh_fn = stepfn.make_train_step(cfg, mesh, opt_cfg)

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch)
    source = make_source(data_cfg)

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        state = {"params": params, "opt": init_opt_state(params)}
        state = jax.device_put(state, state_sh)
        if ckpt and args.resume:
            restored, start = ckpt.restore_state(state, state_sh)
            if restored is not None:
                state = restored
                print(f"resumed at step {start}")

        from jax.sharding import PartitionSpec as P
        from repro.distributed import partitioning as part
        dp = part.data_axes(mesh)
        act_ps = P(dp, "model" if mesh.shape.get("model", 1) > 1 else None,
                   None)
        losses = []
        for step in range(start, args.steps):
            batch = source.batch_at(step)
            if cfg.family == "encdec":
                batch = dict(batch)
                batch["enc_embeds"] = np.zeros(
                    (args.batch, cfg.encoder_seq, cfg.d_model), np.float32)
            jb = jax.tree.map(jnp.asarray, batch)
            t0 = time.perf_counter()
            with activation_sharding(act_ps):
                state, metrics = step_fn(state, jb)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.perf_counter() - t0
            print(f"step {step:5d} loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, state)
        if ckpt:
            ckpt.save(args.steps, state)
    if len(losses) >= 5:
        assert losses[-1] < losses[0], "loss did not decrease"
        print(f"loss {losses[0]:.4f} -> {losses[-1]:.4f} OK")
    return losses


if __name__ == "__main__":
    main()
