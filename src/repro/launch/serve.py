"""CoE serving driver: ``python -m repro.launch.serve [...]``.

Builds a Samba-CoE-style composition (router + N experts derived from one
backbone config), loads all experts on the capacity tier (host DRAM = the
paper's DDR), and serves batched requests through the continuous-batching
engine over the three-tier switching engine and paged KV pool. Reports the
paper's Fig-1 breakdown (switch vs execute) and cache statistics.

Requests demonstrate both routing paths: most arrive untagged
(``expert=None``) and are routed by the composition's router at submit;
a ``--tagged-fraction`` of them arrive caller-tagged and keep their tag.

``--node-shape TPxG`` serves through a multi-socket RDU node instead
(``repro.node``): TP x G socket groups emulated on CPU devices, e.g.

    python -m repro.launch.serve --node-shape 2x4 --reduced

Lifecycle plane (``repro.obs``): ``--metrics-port`` additionally mounts
``/readyz`` (503 until ``warmup()`` completes) and ``/debug/*`` snapshots;
``--watchdog`` starts the background invariant sampler; ``SIGUSR2`` (or a
watchdog anomaly) dumps the flight recorder's postmortem bundle to
``--flight-out``.
"""
from __future__ import annotations

import argparse
import signal
import time

import numpy as np


def install_flight_dump_signal(path, registry=None, signum=None):
    """Install a signal handler (default ``SIGUSR2``) that dumps the process
    flight recorder's postmortem bundle to ``path``. Returns the signal
    number installed, or ``None`` on platforms without SIGUSR2. Tests drive
    it in-process via ``signal.raise_signal``."""
    from repro.obs import flightrec, get_registry

    if signum is None:
        signum = getattr(signal, "SIGUSR2", None)
        if signum is None:                 # e.g. Windows
            return None
    reg = registry if registry is not None else get_registry()

    def _dump(_sig, _frame):
        out = flightrec.dump(path, reg, reason="signal")
        print(f"flight recorder: postmortem bundle -> {out}")

    signal.signal(signum, _dump)
    return signum


def _wire_obs(args, server, ready, providers, engines):
    """Hook one serve target into the lifecycle plane: mount its debug
    snapshots on the httpd and the flight recorder, flip ``/readyz`` to the
    target's warmed state, and (``--watchdog``) start the invariant
    sampler. Returns the watchdog (or None)."""
    from repro.obs import Watchdog, flightrec

    for name, fn in providers.items():
        flightrec.add_state_provider(name, fn)
        if server is not None:
            server.add_debug(name, fn)
    if ready is not None:
        ready["fn"] = lambda: all(getattr(e, "warmed", False)
                                  for e in engines)
    if not args.watchdog:
        return None
    wd = Watchdog(engines, interval_s=args.watchdog_interval,
                  dump_path=args.flight_out)
    wd.start()
    return wd


def build_coe(cfg, n_experts: int, hbm_experts: float, seed: int = 0,
              registry=None):
    """Create n_experts fine-tune-style variants of one backbone (the paper
    derives all 150 experts from Llama2-7B). ``hbm_experts`` is the HBM
    tier capacity in units of one expert. ``registry`` publishes the weight
    cache's metrics into a shared ``MetricsRegistry`` (``--metrics-port``)."""
    from repro.core import CompositionOfExperts, ExpertHandle, HashRouter

    hosts, nbytes = build_experts(cfg, n_experts, seed)
    coe = CompositionOfExperts(
        HashRouter(n_experts), None,
        hbm_capacity_bytes=int(max(1.0, hbm_experts) * nbytes),
        registry=registry)
    for name, host, domain in hosts:
        coe.register(ExpertHandle(name, cfg, host, domain=domain))
    return coe, nbytes


def build_experts(cfg, n_experts: int, seed: int = 0):
    """Host-side expert pytrees: cheap fine-tune stand-ins (per-expert
    perturbations of one base init)."""
    import jax
    from repro.models import get_model

    model = get_model(cfg)
    rng = jax.random.PRNGKey(seed)
    base = model.init(rng)
    host_base = jax.tree.map(np.asarray, base)
    nbytes = sum(x.nbytes for x in jax.tree.leaves(host_base))
    domains = ["code", "math", "translate", "chat", "legal", "medical"]
    out = []
    for i in range(n_experts):
        rs = np.random.RandomState(i)
        pert = jax.tree.map(
            lambda x: (x + (rs.standard_normal(x.shape) * 0.01).astype(x.dtype))
            if x.dtype in (np.float32, np.float16) or x.dtype.str == "<V2"
            else x, host_base)
        out.append((f"expert{i:03d}", pert, domains[i % len(domains)]))
    return out, nbytes


def _make_requests(args, cfg, expert_names):
    """Request list, ``--tagged-fraction`` of them caller-tagged round-robin
    (the rest routed by the composition at submit)."""
    from repro.serving import Request

    rs = np.random.RandomState(0)
    n_tagged = int(args.requests * args.tagged_fraction)
    # --shared-prefix: every prompt opens with the same system-prompt
    # tokens (what --prefix-sharing engines dedup via the PrefixIndex)
    shared = rs.randint(0, cfg.vocab_size,
                        (getattr(args, "shared_prefix", 0),)).astype(np.int32)
    reqs = []
    for i in range(args.requests):
        tag = expert_names[i % len(expert_names)] if i < n_tagged else None
        unique = max(1, args.prompt_len - len(shared))
        toks = np.concatenate([
            shared,
            rs.randint(0, cfg.vocab_size, (unique,)).astype(np.int32)])
        reqs.append(Request(
            rid=i, tokens=toks,
            max_new_tokens=args.new_tokens, expert=tag))
    return reqs, n_tagged


def _serve_single(args, cfg, server=None, ready=None):
    from repro.obs import get_registry
    from repro.serving import ServingEngine

    # publish every engine/cache/ledger series into the process default
    # registry — what --metrics-port serves
    coe, nbytes = build_coe(cfg, args.n_experts, args.hbm_experts,
                            registry=get_registry())
    engine = ServingEngine(coe, cfg,
                           max_len=args.prompt_len + args.new_tokens,
                           n_slots=args.n_slots, block_size=8,
                           scheduler=args.scheduler,
                           backend=args.backend,
                           prefill_mode=args.prefill_mode,
                           prefix_sharing=args.prefix_sharing,
                           registry=get_registry())
    wd = _wire_obs(args, server, ready, engine.debug_providers(), [engine])
    if args.warmup:
        engine.warmup()
    reqs, n_tagged = _make_requests(args, cfg, coe.expert_names())
    t0 = time.perf_counter()
    for r in reqs:
        engine.submit(r)
    done = engine.drain()
    wall = time.perf_counter() - t0
    st = engine.stats
    print(f"served {len(done)} requests in {wall:.2f}s "
          f"({st.tokens_out} tokens, {st.tokens_per_second:.1f} tok/s); "
          f"{n_tagged} caller-tagged, {len(done) - n_tagged} router-routed")
    print(f"breakdown: route={st.route_s:.3f}s switch={st.switch_s:.3f}s "
          f"prefill={st.prefill_s:.3f}s decode={st.exec_s:.3f}s "
          f"(paper Fig-1 split)")
    print(f"scheduler: {st.decode_rounds} rounds, "
          f"occupancy {st.mean_occupancy:.2f}, {st.switches} switches")
    print(f"weight cache: {coe.cache.stats}")
    print(f"kv pool: {engine.pool.stats}")
    if args.prefix_sharing:
        print(f"prefix sharing: {st.prefix_hit_tokens} prompt tokens "
              f"adopted from shared KV, "
              f"{engine.pool.stats.cow_splits} COW splits, "
              f"{len(engine.prefix_index)} indexed blocks")
        engine.release_shared()
    print(f"tier ledger: overlap={coe.cache.ledger.overlap_ratio:.2f} "
          f"store_read={coe.cache.ledger.bytes_moved('store_read')}B "
          f"h2d={coe.cache.ledger.bytes_moved('h2d')}B")
    if engine.slo.tenants():
        print(f"slo: attainment={engine.slo.attainment():.3f} "
              f"goodput={engine.slo.goodput():.1f} tok/s")
    if wd is not None:
        wd.stop()
    return engine


def _serve_node(args, cfg, server=None, ready=None):
    from repro.core import HashRouter
    from repro.node import make_node_topology, RDUNode
    from repro.obs import get_registry

    tp, n_groups = (int(x) for x in args.node_shape.split("x"))
    topo = make_node_topology(tp, n_groups)
    hosts, nbytes = build_experts(cfg, args.n_experts)
    node = RDUNode(topo, cfg, HashRouter(args.n_experts), None,
                   group_hbm_bytes=int(max(1.0, args.hbm_experts) * nbytes),
                   group_kv_reserve_bytes=int(0.8 * nbytes),
                   n_slots=max(1, args.n_slots // n_groups), block_size=8,
                   max_len=args.prompt_len + args.new_tokens,
                   scheduler=args.scheduler,
                   backend=args.backend,
                   prefill_mode=args.prefill_mode,
                   prefix_sharing=args.prefix_sharing,
                   prefill_groups=args.prefill_groups,
                   registry=get_registry())
    for name, host, domain in hosts:
        node.register_expert(name, host, domain=domain)
    placement = node.plan()
    wd = _wire_obs(args, server, ready, node.debug_providers(),
                   node.engines())
    if args.warmup:
        node.warmup()
    reqs, n_tagged = _make_requests(args, cfg, node.expert_names())
    t0 = time.perf_counter()
    for r in reqs:
        node.submit(r)
    done = node.drain()
    wall = time.perf_counter() - t0
    st = node.stats()
    print(f"[node {topo.name}] served {len(done)} requests in {wall:.2f}s "
          f"({st.tokens_out} tokens, {st.tokens_per_second(wall):.1f} tok/s);"
          f" {n_tagged} caller-tagged, {len(done) - n_tagged} router-routed")
    print(f"route={st.route_s:.3f}s switch_stall={st.switch_stall_s:.3f}s "
          f"imbalance={st.imbalance:.2f} "
          f"spilled_experts={len(placement.spilled)}")
    for g in st.per_group:
        print(f"  group {g['gid']} (tp={g['tp']}): {g['requests']} req / "
              f"{g['tokens_out']} tok, occupancy {g['occupancy']:.2f}, "
              f"{g['switches']} switches, cache h/m "
              f"{g['cache_hits']}/{g['cache_misses']}")
    if wd is not None:
        wd.stop()
    node.close()
    return node


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="samba-coe-expert-7b")
    ap.add_argument("--n-experts", type=int, default=8)
    ap.add_argument("--hbm-experts", type=float, default=2.5,
                    help="HBM tier capacity in units of one expert "
                    "(per socket group in --node-shape mode)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--n-slots", type=int, default=8,
                    help="decode slots (split across groups in node mode)")
    ap.add_argument("--scheduler", default="continuous",
                    choices=["continuous", "run_to_completion"])
    ap.add_argument("--backend", default="xla", choices=["xla", "fused"],
                    help="decode-step backend (serving/backends.py): 'xla' "
                    "is the reference paged extend, 'fused' runs each layer "
                    "as paged-native Pallas kernels (prologue / paged "
                    "flash-decode / epilogue)")
    ap.add_argument("--prefill-mode", default="packed",
                    choices=["packed", "sequential"],
                    help="'packed' admits pending requests through the "
                    "bucketed AOT packed-prefill path (serving/prefill.py; "
                    "zero recompiles after warmup); 'sequential' keeps the "
                    "one-forward-per-prompt reference path")
    ap.add_argument("--prefill-groups", type=int, default=0, metavar="N",
                    help="with --node-shape: dedicate the first N socket "
                    "groups to prefill (disaggregated serving) — their KV "
                    "blocks are handed off to the decode groups")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="enable copy-on-write prefix sharing + session "
                    "retention in the engine(s): shared prompt prefixes "
                    "prefill once and later requests adopt the KV blocks "
                    "read-only (serving/kvcache.py PrefixIndex)")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="open every generated prompt with the same N "
                    "system-prompt tokens (the workload --prefix-sharing "
                    "dedups)")
    ap.add_argument("--tagged-fraction", type=float, default=0.25,
                    help="fraction of requests submitted caller-tagged; "
                    "the rest are routed by the composition's router")
    ap.add_argument("--node-shape", default=None, metavar="TPxG",
                    help="serve through a TP x G socket-group RDU node "
                    "(e.g. 2x4) instead of the single-device engine")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve the metrics registry over HTTP while "
                    "running: /metrics (Prometheus text), /metrics.json "
                    "(flat snapshot), /healthz. PORT 0 binds an ephemeral "
                    "port (printed at startup)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record request-lifecycle spans and export a "
                    "Chrome-trace / Perfetto JSON to PATH on exit "
                    "(open at https://ui.perfetto.dev)")
    ap.add_argument("--flight-out", default="flight_dump.json",
                    metavar="PATH",
                    help="where SIGUSR2 (and watchdog anomalies) dump the "
                    "flight recorder's postmortem bundle")
    ap.add_argument("--watchdog", action="store_true",
                    help="start the background invariant sampler "
                    "(obs.watchdog): stuck requests, KV refcount leaks, "
                    "HBM budget, queue age -> obs.anomaly{kind=} + a "
                    "postmortem dump to --flight-out")
    ap.add_argument("--watchdog-interval", type=float, default=1.0,
                    metavar="S", help="watchdog sampling interval")
    ap.add_argument("--warmup", action="store_true",
                    help="AOT-compile the serving hot path before traffic "
                    "(flips /readyz from 503 to 200 on completion)")
    args = ap.parse_args(argv)

    if args.node_shape:
        # the emulated-socket flag must land before the backend initializes
        from repro.node.topology import ensure_emulated_sockets
        tp, n_groups = (int(x) for x in args.node_shape.split("x"))
        ensure_emulated_sockets(tp * n_groups)

    from repro.configs import get_config, pad_for_tp, reduced
    from repro.obs import get_registry, serve_metrics, trace

    install_flight_dump_signal(args.flight_out)

    # the engine/node is built after the httpd starts; /readyz reads the
    # warmed state through this mutable slot once _wire_obs fills it in
    ready = {"fn": lambda: False}
    server = None
    if args.metrics_port is not None:
        server = serve_metrics(get_registry(), port=args.metrics_port,
                               ready_check=lambda: ready["fn"]())
        print(f"metrics: {server.url}/metrics "
              f"(+ /metrics.json, /healthz, /readyz, /debug/*)")
    if args.trace_out:
        trace.enable()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    try:
        if args.node_shape:
            cfg = pad_for_tp(cfg, int(args.node_shape.split("x")[0]))
            return _serve_node(args, cfg, server, ready)
        return _serve_single(args, cfg, server, ready)
    finally:
        if args.trace_out:
            trace.disable()
            path = trace.export(args.trace_out)
            print(f"trace: {len(trace.events())} events -> {path}")
        if server is not None:
            server.stop()


if __name__ == "__main__":
    main()
