"""CoE serving driver: ``python -m repro.launch.serve [...]``.

Builds a Samba-CoE-style composition (router + N experts derived from one
backbone config), loads all experts on the capacity tier (host DRAM = the
paper's DDR), and serves batched requests through the continuous-batching engine over
the three-tier switching engine and paged KV pool. Reports the paper's Fig-1 breakdown (switch vs execute) and cache
statistics.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import CompositionOfExperts, ExpertHandle, HashRouter
from repro.models import get_model
from repro.serving import Request, ServingEngine


def build_coe(cfg, n_experts: int, hbm_experts: float, seed: int = 0):
    """Create n_experts fine-tune-style variants of one backbone (the paper
    derives all 150 experts from Llama2-7B). ``hbm_experts`` is the HBM
    tier capacity in units of one expert."""
    model = get_model(cfg)
    rng = jax.random.PRNGKey(seed)
    base = model.init(rng)
    host_base = jax.tree.map(np.asarray, base)
    nbytes = sum(x.nbytes for x in jax.tree.leaves(host_base))
    coe = CompositionOfExperts(
        HashRouter(n_experts), None,
        hbm_capacity_bytes=int(max(1.0, hbm_experts) * nbytes))
    domains = ["code", "math", "translate", "chat", "legal", "medical"]
    for i in range(n_experts):
        # cheap fine-tune stand-in: per-expert perturbation of the base
        rs = np.random.RandomState(i)
        pert = jax.tree.map(
            lambda x: (x + (rs.standard_normal(x.shape) * 0.01).astype(x.dtype))
            if x.dtype in (np.float32, np.float16) or x.dtype.str == "<V2"
            else x, host_base)
        coe.register(ExpertHandle(f"expert{i:03d}", cfg, pert,
                                  domain=domains[i % len(domains)]))
    return coe, nbytes


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="samba-coe-expert-7b")
    ap.add_argument("--n-experts", type=int, default=8)
    ap.add_argument("--hbm-experts", type=float, default=2.5,
                    help="HBM tier capacity in units of one expert")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--n-slots", type=int, default=8)
    ap.add_argument("--scheduler", default="continuous",
                    choices=["continuous", "run_to_completion"])
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)

    coe, nbytes = build_coe(cfg, args.n_experts, args.hbm_experts)
    engine = ServingEngine(coe, cfg,
                           max_len=args.prompt_len + args.new_tokens,
                           n_slots=args.n_slots, block_size=8,
                           scheduler=args.scheduler)

    rs = np.random.RandomState(0)
    for i in range(args.requests):
        engine.submit(Request(
            rid=i,
            tokens=rs.randint(0, cfg.vocab_size, (args.prompt_len,)).astype(np.int32),
            max_new_tokens=args.new_tokens))

    t0 = time.perf_counter()
    done = engine.drain()
    wall = time.perf_counter() - t0
    st = engine.stats
    print(f"served {len(done)} requests in {wall:.2f}s "
          f"({st.tokens_out} tokens, {st.tokens_per_second:.1f} tok/s)")
    print(f"breakdown: route={st.route_s:.3f}s switch={st.switch_s:.3f}s "
          f"prefill={st.prefill_s:.3f}s decode={st.exec_s:.3f}s "
          f"(paper Fig-1 split)")
    print(f"scheduler: {st.decode_rounds} rounds, "
          f"occupancy {st.mean_occupancy:.2f}, {st.switches} switches")
    print(f"weight cache: {coe.cache.stats}")
    print(f"kv pool: {engine.pool.stats}")
    return engine


if __name__ == "__main__":
    main()
