"""Composition of Experts (paper §II, §V): the system-level contribution.

A CoE = one router + N independently-built experts. One inference:
    (1) run the router on the prompt batch,
    (2) activate the chosen expert(s): capacity tier -> HBM copy (LRU cache),
    (3) run the expert: prefill + autoregressive decode.

This module owns the composition, the expert registry (the "dynamic
linker/loader" of §V-B: each expert declares its memory contract ahead of
time), per-expert batch grouping (BS=8 semantics of §VI-C), prefetch overlap,
and the switch/execute latency breakdown of Fig 1.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.memory_tiers import HBMBudget
from repro.core.switching import HBMWeightCache, tree_bytes
from repro.models import get_model
from repro.models.common import param_bytes
from repro.store import ExpertStore, HostMemoryStore


@dataclass
class ExpertHandle:
    """One expert in the composition. Params live on the capacity tier
    (the ``ExpertStore``) until activated. ``host_params`` may be None when
    the expert is already persisted in the composition's store under
    ``name`` (e.g. an on-disk ``MmapFileStore``)."""
    name: str
    cfg: ModelConfig
    host_params: Any = None           # host-side pytree, or None if in store
    domain: str = "general"

    @functools.cached_property
    def nbytes(self) -> int:
        # params are immutable after registration; the scheduler reads this
        # every step, so the pytree walk must not repeat. register() primes
        # this from the store when host_params is None.
        if self.host_params is None:
            raise ValueError(
                f"expert {self.name}: nbytes unknown before registration")
        return int(sum(np.asarray(x).nbytes
                       for x in jax.tree.leaves(self.host_params)))


@dataclass
class GenerationResult:
    tokens: np.ndarray
    switch_seconds: float
    exec_seconds: float
    route_seconds: float
    expert_of_prompt: np.ndarray


class CompositionOfExperts:
    """The Samba-CoE execution engine on the three-tier memory system."""

    def __init__(self, router, router_params, hbm_capacity_bytes: int,
                 sharding=None, kv_reserve_bytes: int = 0,
                 store: Optional[ExpertStore] = None,
                 max_inflight_prefetch: int = 2,
                 registry=None, obs_labels=None):
        """``kv_reserve_bytes`` carves a slice of the HBM tier out of the
        expert weight cache for the serving engine's paged KV pool — the
        explicit resident-experts vs concurrent-requests tradeoff
        (``core.memory_tiers.HBMBudget``). ``self.hbm_budget`` records the
        split; ``ServingEngine`` sizes its ``PagedKVCache`` from it.

        ``store`` is the capacity-tier backend holding every expert
        (``repro.store``): host DRAM by default, mmap-on-disk or
        int8-quantized for capacities past host memory. The weight cache
        runs its async prefetch pipeline against it."""
        if not 0 <= kv_reserve_bytes < hbm_capacity_bytes:
            raise ValueError(
                f"kv_reserve_bytes={kv_reserve_bytes} must be in "
                f"[0, hbm_capacity_bytes={hbm_capacity_bytes})")
        self.router = router
        self.router_params = router_params   # router lives in HBM (paper Fig 9)
        self.experts: Dict[str, ExpertHandle] = {}
        self._models: Dict[str, Any] = {}
        self.store = store if store is not None else HostMemoryStore()
        self.hbm_budget = HBMBudget(
            total_bytes=hbm_capacity_bytes,
            weights_bytes=hbm_capacity_bytes - kv_reserve_bytes,
            kv_bytes=kv_reserve_bytes)
        self.cache = HBMWeightCache(
            self.hbm_budget.weights_bytes,
            store=self.store,
            sharding=sharding,
            max_inflight=max_inflight_prefetch,
            registry=registry,
            labels=obs_labels,
        )

    # -- registry (the dynamic linker/loader of §V-B) --------------------
    def register(self, handle: ExpertHandle):
        if handle.name in self.experts:
            raise KeyError(f"duplicate expert {handle.name}")
        if handle.host_params is not None:
            self.store.put(handle.name, handle.host_params)
            # the store owns the capacity-tier copy from here on; keeping
            # the handle's uncompressed pytree referenced would pin it in
            # DRAM and defeat the mmap/int8 backends' capacity point
            handle.nbytes      # cached_property: prime the AOT contract
            handle.host_params = None
        elif not self.store.contains(handle.name):
            raise KeyError(
                f"expert {handle.name}: no host_params given and not "
                f"present in the capacity-tier store")
        else:
            # prime the AOT size contract from the store manifest
            handle.__dict__["nbytes"] = self.store.nbytes(handle.name)
        self.experts[handle.name] = handle
        self._models[handle.name] = get_model(handle.cfg)

    def memory_contract(self, name: str) -> Dict[str, int]:
        """Ahead-of-time footprint declaration (paper: 'each compiled model
        binary tells us exactly how much HBM and DDR space it requires').
        ``ddr_bytes`` is what the capacity-tier backend actually occupies —
        smaller than ``hbm_bytes`` for the int8-quantized store."""
        h = self.experts[name]
        ddr = (self.store.stored_bytes(name) if self.store.contains(name)
               else h.nbytes)
        return {"hbm_bytes": h.nbytes, "ddr_bytes": ddr}

    def expert_names(self) -> List[str]:
        return list(self.experts.keys())

    # -- inference --------------------------------------------------------
    def route(self, tokens) -> np.ndarray:
        idx = self.router.route(self.router_params, tokens)
        return np.asarray(jax.device_get(idx))

    def route_request(self, tokens) -> tuple:
        """Route ONE request's prompt ``(S,)`` to an expert name; returns
        ``(name, seconds)`` so callers (engine submit, node dispatch) can
        account routing time. The single route-once implementation both
        serving front-ends share."""
        t0 = time.perf_counter()
        names = self.expert_names()
        e = int(self.route(np.asarray(tokens)[None])[0]) % len(names)
        return names[e], time.perf_counter() - t0

    def generate(self, tokens: np.ndarray, n_tokens: int, *,
                 prefetch_next: bool = True) -> GenerationResult:
        """tokens (B,S) int32. Each prompt may route to a different expert;
        prompts are grouped per expert (paper §VI-C BS>1 semantics) and each
        (group, expert) pair runs sequentially, with the *next* group's
        expert prefetched during the current group's decode."""
        names = self.expert_names()
        t0 = time.perf_counter()
        eidx = self.route(tokens) % len(names)
        route_s = time.perf_counter() - t0

        order = np.argsort(eidx, kind="stable")
        groups: List[tuple] = []
        for e in np.unique(eidx[order]):
            rows = np.where(eidx == e)[0]
            groups.append((int(e), rows))

        B, S = tokens.shape
        out = np.zeros((B, n_tokens), np.int32)
        switch_s = 0.0
        exec_s = 0.0
        for gi, (e, rows) in enumerate(groups):
            name = names[e]
            t0 = time.perf_counter()
            params = self.cache.activate(name)
            switch_s += time.perf_counter() - t0

            if prefetch_next and gi + 1 < len(groups):
                self.cache.prefetch(names[groups[gi + 1][0]])

            model = self._models[name]
            sub = jnp.asarray(tokens[rows])
            t0 = time.perf_counter()
            last, cache = model.prefill(params, {"tokens": sub},
                                        max_len=S + n_tokens)
            tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
            toks = [tok]
            for t in range(n_tokens - 1):
                lg, cache = model.decode_step(params, cache, tok[:, None],
                                              jnp.int32(S + t))
                tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                toks.append(tok)
            seq = jax.device_get(jnp.stack(toks, axis=1))
            exec_s += time.perf_counter() - t0
            out[rows] = seq
        return GenerationResult(out, switch_s, exec_s, route_s, eidx)
