"""Three-tier memory system (paper §III-B, §IV, §V-A).

SN40L tiers → TPU-node analogues:
    SRAM (520 MB PMUs)      → VMEM        (managed by Pallas BlockSpecs)
    HBM  (64 GB, 1.8 TB/s)  → device HBM  (software-managed expert cache)
    DDR  (1.5 TB, 200 GB/s) → host DRAM   (expert capacity tier)

This module provides:
  * tier presets (SN40L node, TPU v5e host, DGX A100/H100) used by the
    bandwidth model and the Table V / Fig 12 benchmarks;
  * ``StaticAllocator`` — the paper's static lifetime-based garbage
    collection: symbols with disjoint lifetimes share device addresses;
  * ``spill_order`` — the paper's bandwidth-aware spill heuristic: when HBM
    does not fit, spill symbols with the smallest aggregate transfer
    footprint first (weights stay, low-reuse intermediates go);
  * ``HBMBudget`` / ``plan_hbm_budget`` — the serving-time split of the HBM
    tier between resident expert weights (the LRU cache of
    ``core.switching``) and the paged KV pool of ``serving.kvcache``:
    resident-experts vs concurrent-requests as ONE explicit tradeoff.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


GiB = 1024 ** 3
GBps = 1e9


@dataclass(frozen=True)
class MemoryTier:
    name: str
    capacity: int          # bytes
    bandwidth: float       # bytes/s


@dataclass(frozen=True)
class MachineTiers:
    """Per-socket tiers + the capacity-tier -> HBM copy bandwidth per node."""
    name: str
    sram: MemoryTier
    hbm: MemoryTier
    capacity: MemoryTier           # DDR (SN40L) or host DRAM (TPU/DGX)
    copy_bw_node: float            # capacity->HBM bytes/s, whole node
    sockets_per_node: int
    peak_flops_bf16: float         # per socket
    # achievable fraction of HBM bandwidth on fused decode. Paper §VI-B:
    # SN40L sustains ~85% with whole-decoder fusion; optimized GPU decoders
    # "rarely exceed 50%". Our Pallas fused-decode path targets the SN40L
    # regime on TPU.
    hbm_efficiency: float = 0.85


# --- presets (paper Table II, DGX specs from paper §VI-C refs) -----------
SN40L_NODE = MachineTiers(
    name="sn40l",
    sram=MemoryTier("sram", int(0.52 * GiB), 400e12),
    hbm=MemoryTier("hbm", 64 * GiB, 1.8e12),
    capacity=MemoryTier("ddr", int(1.5 * 1024) * GiB, 200 * GBps),
    copy_bw_node=1e12,             # >1 TB/s aggregate DDR->HBM (paper §VI-C)
    sockets_per_node=8,
    peak_flops_bf16=638e12,
    hbm_efficiency=0.85,           # paper §VI-B
)

DGX_A100 = MachineTiers(
    name="dgx-a100",
    sram=MemoryTier("sram", int(0.04 * GiB), 200e12),
    hbm=MemoryTier("hbm", 80 * GiB, 2.0e12),
    capacity=MemoryTier("host", 2048 * GiB, 200 * GBps),
    copy_bw_node=32 * GBps,        # host->GPU PCIe (paper: 32 GB/s)
    sockets_per_node=8,
    peak_flops_bf16=312e12,
    hbm_efficiency=0.45,           # paper §VI-B: "rarely exceed 50%"
)

DGX_H100 = MachineTiers(
    name="dgx-h100",
    sram=MemoryTier("sram", int(0.05 * GiB), 400e12),
    hbm=MemoryTier("hbm", 80 * GiB, 3.35e12),
    capacity=MemoryTier("host", 2048 * GiB, 200 * GBps),
    copy_bw_node=64 * GBps,        # paper: 64 GB/s
    sockets_per_node=8,
    peak_flops_bf16=989e12,
    hbm_efficiency=0.5,
)

TPU_V5E_NODE = MachineTiers(
    name="tpu-v5e",
    sram=MemoryTier("vmem", 128 * 1024 ** 2, 400e12),
    hbm=MemoryTier("hbm", 16 * GiB, 819 * GBps),
    capacity=MemoryTier("host", 512 * GiB, 200 * GBps),
    copy_bw_node=8 * 32 * GBps,    # 8 chips/host x PCIe-class DMA
    sockets_per_node=8,
    peak_flops_bf16=197e12,
    hbm_efficiency=0.8,            # our fused decode path (kernels/)
)

MACHINES = {m.name: m for m in (SN40L_NODE, DGX_A100, DGX_H100, TPU_V5E_NODE)}


# ----------------------------------------------------------------------
# Serving-time HBM split: expert weights vs paged KV pool
# ----------------------------------------------------------------------

@dataclass(frozen=True)
class HBMBudget:
    """How one HBM tier is divided at serving time.

    ``weights_bytes`` caps the ``HBMWeightCache`` (how many experts stay
    resident, i.e. how many switches are HBM hits); ``kv_bytes`` caps the
    ``PagedKVCache`` (how many requests decode concurrently). The two sum to
    ``total_bytes`` — growing one shrinks the other, which is exactly the
    CoE serving tradeoff of paper §V-B/§VI-C.
    """
    total_bytes: int
    weights_bytes: int
    kv_bytes: int

    def resident_experts(self, expert_bytes: int) -> int:
        return self.weights_bytes // max(expert_bytes, 1)

    def kv_blocks(self, block_bytes: int) -> int:
        return self.kv_bytes // max(block_bytes, 1)


def plan_hbm_budget(total_bytes: int, expert_bytes: int, block_bytes: int,
                    *, min_resident_experts: int = 2,
                    kv_fraction: float = 0.2) -> HBMBudget:
    """Split an HBM tier between the expert LRU cache and the KV pool.

    Reserves ``kv_fraction`` of the tier for KV, but never shrinks the
    weight share below ``min_resident_experts`` experts (the active expert
    plus at least one prefetch target — otherwise every switch is a miss and
    prefetch can never overlap decode) and never below one KV block.
    """
    if total_bytes < min_resident_experts * expert_bytes + block_bytes:
        raise MemoryError(
            f"HBM tier of {total_bytes} bytes cannot hold "
            f"{min_resident_experts} experts ({expert_bytes} B each) plus "
            f"one KV block ({block_bytes} B)")
    kv = int(total_bytes * kv_fraction)
    floor_w = min_resident_experts * expert_bytes
    kv = min(kv, total_bytes - floor_w)
    kv = max(kv, block_bytes)
    return HBMBudget(total_bytes=total_bytes,
                     weights_bytes=total_bytes - kv, kv_bytes=kv)


# ----------------------------------------------------------------------
# Static lifetime allocator (paper §V-A)
# ----------------------------------------------------------------------

@dataclass(frozen=True)
class Symbol:
    name: str
    size: int              # bytes
    first_use: int         # step index
    last_use: int
    read_only: bool = False
    transfer_footprint: int = 0   # aggregate bytes moved if spilled (reuse x size)


@dataclass
class Allocation:
    offsets: Dict[str, int]
    peak: int


def allocate_static(symbols: Sequence[Symbol], align: int = 512) -> Allocation:
    """Greedy lifetime-based allocation: symbols with disjoint [first,last]
    lifetimes may share addresses. This is the paper's 'static garbage
    collection' — no runtime allocator, no CPU round-trips.
    """
    def rnd(x):
        return (x + align - 1) // align * align

    events = sorted(symbols, key=lambda s: (s.first_use, -s.size))
    # free list of (offset, size) holes; live: name -> (offset, size, last_use)
    live: Dict[str, Tuple[int, int, int]] = {}
    holes: List[Tuple[int, int]] = []
    peak = 0
    offsets: Dict[str, int] = {}
    top = 0

    for sym in events:
        # retire symbols whose lifetime ended before this first_use
        for n, (off, sz, last) in list(live.items()):
            if last < sym.first_use:
                holes.append((off, sz))
                del live[n]
        holes.sort()
        # coalesce adjacent holes
        merged: List[Tuple[int, int]] = []
        for off, sz in holes:
            if merged and merged[-1][0] + merged[-1][1] == off:
                merged[-1] = (merged[-1][0], merged[-1][1] + sz)
            else:
                merged.append((off, sz))
        holes = merged
        need = rnd(sym.size)
        # best-fit
        best = None
        for i, (off, sz) in enumerate(holes):
            if sz >= need and (best is None or sz < holes[best][1]):
                best = i
        if best is not None:
            off, sz = holes.pop(best)
            offsets[sym.name] = off
            if sz > need:
                holes.append((off + need, sz - need))
        else:
            offsets[sym.name] = top
            top += need
        live[sym.name] = (offsets[sym.name], need, sym.last_use)
        peak = max(peak, top)
    return Allocation(offsets, peak)


def spill_order(symbols: Sequence[Symbol]) -> List[Symbol]:
    """Paper §V-A: spill candidates ordered by aggregate transfer footprint
    ascending — symbols that would cost the least DDR bandwidth go first.
    Weights (high reuse during decode) naturally sort last and stay in HBM."""
    return sorted(symbols, key=lambda s: (s.transfer_footprint, s.size))


def plan_placement(symbols: Sequence[Symbol], hbm_capacity: int,
                   align: int = 512) -> Tuple[Allocation, List[str]]:
    """Allocate into HBM; spill by ``spill_order`` until the peak fits.
    Returns (allocation of resident symbols, spilled symbol names)."""
    resident = list(symbols)
    spilled: List[str] = []
    order = spill_order(symbols)
    k = 0
    while True:
        alloc = allocate_static(resident, align)
        if alloc.peak <= hbm_capacity or not resident:
            return alloc, spilled
        victim = order[k].name
        k += 1
        resident = [s for s in resident if s.name != victim]
        spilled.append(victim)
