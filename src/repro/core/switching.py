"""Expert switching engine: the HBM tier as a software-managed LRU cache of
expert weights over the capacity tier (paper §V-B CoE runtime).

Mechanics reproduced from the paper:
  * LRU eviction when HBM capacity is hit;
  * read-only symbols (weights) skip copy-back to the capacity tier on
    eviction — only mutable state is written back (to the backing
    ``ExpertStore``);
  * per-model ahead-of-time size contracts (each compiled expert declares its
    HBM/DDR footprint before activation);
  * prefetch: a predicted-next expert is loaded on a background executor —
    store read + H2D copy both happen off the critical path, the analogue of
    the paper's §VII P2P/DDR streams running concurrently with compute.

The prefetch pipeline is double-buffered: at most ``max_inflight``
(default 2) loads ride the executor; issuing a prefetch beyond that cancels
the oldest unconsumed one (the newest prediction wins). ``activate``
consumes the in-flight future for its expert when one exists — blocking
only for whatever tail of the load has not finished yet ("hit under
prefetch") — and falls back to a synchronous load through the same pipeline
on a true miss. Per-phase timing is split into store-read seconds vs H2D
copy seconds (worker side) and ``switch_seconds`` (caller-side stall, the
Fig-1 "switch" bar).
"""
from __future__ import annotations

import time
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax

from repro.core.memory_tiers import MachineTiers, TPU_V5E_NODE
from repro.obs import trace
from repro.obs.ledger import TransferLedger
from repro.obs.metrics import MetricsRegistry
from repro.obs.stats import StatsView, counter_field
from repro.store import ExpertStore, HostMemoryStore


def tree_bytes(tree) -> int:
    return int(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)))


class SwitchStats(StatsView):
    """Switching-engine counters as a view over the metrics registry
    (``switch.*`` series). Field semantics unchanged from the old
    dataclass; ``as_dict`` keys are a superset of the old shape (the two
    ``failed-prefetch`` attribution fields are new)."""

    PREFIX = "switch"
    DERIVED = ("copy_seconds", "overlap_ratio")

    hits = counter_field()
    misses = counter_field()
    prefetch_hits = counter_field()   # activates served by in-flight prefetch
    prefetch_failures = counter_field()  # prefetch loads that died; retried as miss
    prefetches_issued = counter_field()
    prefetches_cancelled = counter_field()
    evictions = counter_field()
    drops = counter_field()           # explicit drop() retirements
    bytes_copied_in = counter_field()
    bytes_copied_back = counter_field()
    bytes_copyback_elided = counter_field()
    switch_seconds = counter_field(0.0)  # caller-side stall inside activate()
    stall_miss_seconds = counter_field(0.0)      # ...due to true misses
    stall_prefetch_seconds = counter_field(0.0)  # ...due to prefetch consumes
    stall_failed_prefetch_seconds = counter_field(0.0)  # ...waiting on a
    # prefetch future that then raised — previously silently folded into the
    # miss bucket, hiding the wasted prefetch-issue cost
    store_read_seconds = counter_field(0.0)  # capacity-tier read (worker side)
    h2d_seconds = counter_field(0.0)  # device_put + ready wait (worker side)

    @property
    def copy_seconds(self) -> float:
        """End-to-end load time (read + H2D), regardless of overlap."""
        return self.store_read_seconds + self.h2d_seconds

    @property
    def overlap_ratio(self) -> float:
        """Fraction of total load time hidden off the critical path.
        Clamped: caller-side stall includes bookkeeping/eviction time the
        worker-side phase timers don't see, so the raw ratio can dip below
        0 on miss-heavy runs."""
        total = self.copy_seconds
        if total <= 0:
            return 0.0
        return max(0.0, min(1.0, 1.0 - self.switch_seconds / total))


@dataclass
class _Entry:
    value: Any             # device pytree
    nbytes: int
    read_only: bool
    dirty: bool = False


@dataclass
class _Loaded:
    value: Any             # device pytree, ready
    nbytes: int
    read_s: float
    h2d_s: float


class _CallableStore(ExpertStore):
    """Adapter: a bare ``fetch(expert_id) -> host pytree`` callable as a
    read-only store (legacy constructor path)."""

    cheap_nbytes = False     # sizing requires a full fetch

    def __init__(self, fetch: Callable[[str], Any]):
        super().__init__()
        self._fetch = fetch

    def put(self, name, tree):
        raise NotImplementedError("fetch-callable store is read-only")

    def get(self, name):
        self._note_read(0)           # size unknown until fetched
        return self._fetch(name)

    def contains(self, name):
        return True                    # the callable decides; assume yes

    def delete(self, name):
        raise NotImplementedError

    def keys(self):
        return []

    def nbytes(self, name):
        return tree_bytes(self.get(name))


class HBMWeightCache:
    """LRU cache of expert parameter pytrees in device memory ("HBM"),
    backed by an ``ExpertStore`` capacity tier ("DDR").

    ``store.get(expert_id)`` is the DDR read; ``device_put`` is the
    DDR->HBM copy — both run on the prefetch executor. Dirty non-read-only
    entries are written back to the store (or the explicit ``writeback``
    callable) on eviction or ``drop``; read-only entries elide the
    copy-back (the paper's elision).
    """

    def __init__(self, capacity_bytes: int,
                 store: Optional[ExpertStore] = None,
                 fetch: Optional[Callable[[str], Any]] = None,
                 writeback: Optional[Callable[[str, Any], None]] = None,
                 device=None,
                 sharding=None,
                 max_inflight: int = 2,
                 registry: Optional[MetricsRegistry] = None,
                 labels: Optional[Dict[str, Any]] = None):
        if (store is None) == (fetch is None):
            raise ValueError("pass exactly one of store= or fetch=")
        self.capacity = int(capacity_bytes)
        self.store = store if store is not None else _CallableStore(fetch)
        if writeback is not None:
            self.writeback = writeback
        elif store is not None:
            self.writeback = store.put
        else:
            self.writeback = None
        self.device = device
        self.sharding = sharding
        self.max_inflight = max(1, int(max_inflight))
        self._entries: "OrderedDict[str, _Entry]" = OrderedDict()
        self._inflight: "OrderedDict[str, Future]" = OrderedDict()
        self._reserved: dict = {}            # expert_id -> bytes held inflight
        self._pool: Optional[ThreadPoolExecutor] = None
        self._used = 0
        # stats view + tier-transfer ledger share one registry (a private
        # one unless the caller publishes into a shared registry — the node
        # scheduler labels each group's cache, serve.py the default one)
        registry = registry if registry is not None else MetricsRegistry()
        self.stats = SwitchStats(registry=registry, labels=labels)
        self.ledger = TransferLedger(registry, labels)
        self._hbm_used_gauge = registry.gauge("switch.hbm_used_bytes", labels)

    # -- internals -----------------------------------------------------
    def _executor(self) -> ThreadPoolExecutor:
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self.max_inflight,
                thread_name_prefix="hbm-prefetch")
        return self._pool

    def _put_device(self, host_tree):
        if self.sharding is not None:
            return jax.device_put(host_tree, self.sharding)
        if self.device is not None:
            return jax.device_put(host_tree, self.device)
        return jax.device_put(host_tree)

    def _load_job(self, expert_id: str) -> _Loaded:
        """Worker-side load: store read, then H2D copy. No shared-state
        mutation here — the consuming (caller) thread owns the books."""
        t0 = time.perf_counter()
        with trace.span("store_read", cat="switch", expert=expert_id):
            host = self.store.get(expert_id)
        t1 = time.perf_counter()
        with trace.span("h2d", cat="switch", expert=expert_id):
            dev = self._put_device(host)
            jax.block_until_ready(dev)
        t2 = time.perf_counter()
        return _Loaded(dev, tree_bytes(host), t1 - t0, t2 - t1)

    def _retire(self, name: str, entry: _Entry):
        """Account one entry leaving HBM (eviction or drop): write back
        dirty mutable state, elide the copy for read-only weights."""
        self._used -= entry.nbytes
        self._hbm_used_gauge.set(self._used)
        if entry.dirty and not entry.read_only and self.writeback is not None:
            t0 = time.perf_counter()
            with trace.span("writeback", cat="switch", expert=name):
                host = jax.device_get(entry.value)
                self.writeback(name, host)
            self.stats.bytes_copied_back += entry.nbytes
            self.ledger.record("writeback", entry.nbytes,
                               time.perf_counter() - t0, cause="dirty",
                               expert=name)
        else:
            self.stats.bytes_copyback_elided += entry.nbytes
            self.ledger.record("elided", entry.nbytes, cause="read_only",
                               expert=name)

    def _evict_one(self):
        name, entry = self._entries.popitem(last=False)     # LRU = oldest
        self.stats.evictions += 1
        self._retire(name, entry)

    def _make_room(self, need: int, *, strict: bool = True) -> bool:
        """Evict until ``need`` bytes fit inside the capacity NOT already
        reserved by in-flight loads. ``strict=False`` (the prefetch path)
        returns False instead of raising when the bytes cannot fit; the
        strict path (a demand miss) outranks speculation — it cancels
        stale in-flight prefetches to reclaim their reservations before
        giving up."""
        def budget():
            return self.capacity - sum(self._reserved.values())
        if need > budget():
            if not strict:
                return False
            while need > budget() and self._inflight:
                self.cancel(next(iter(self._inflight)))
            if need > budget():
                raise MemoryError(
                    f"expert of {need} bytes exceeds HBM tier capacity "
                    f"{self.capacity} (minus {self.capacity - budget()} "
                    f"bytes reserved by in-flight loads)")
        while self._used + need > budget():
            self._evict_one()
        return True

    def _unreserve(self, expert_id: str):
        need = self._reserved.pop(expert_id, None)
        if need:
            self.ledger.release(need)

    def _finish_load(self, expert_id: str, loaded: _Loaded, read_only: bool,
                     cause: str = "miss"):
        self._make_room(loaded.nbytes)
        self.stats.bytes_copied_in += loaded.nbytes
        self.stats.store_read_seconds += loaded.read_s
        self.stats.h2d_seconds += loaded.h2d_s
        self.ledger.record("store_read", loaded.nbytes, loaded.read_s,
                           cause=cause, expert=expert_id)
        self.ledger.record("h2d", loaded.nbytes, loaded.h2d_s, cause=cause)
        self._entries[expert_id] = _Entry(loaded.value, loaded.nbytes,
                                          read_only)
        self._used += loaded.nbytes
        self._hbm_used_gauge.set(self._used)
        return loaded.value

    # -- public API ------------------------------------------------------
    def resident(self, expert_id: str) -> bool:
        return expert_id in self._entries

    def inflight(self, expert_id: str) -> bool:
        return expert_id in self._inflight

    def ready(self, expert_id: str) -> bool:
        """Activating this expert would not stall: already in HBM, or its
        prefetch has fully landed *successfully* (admission consults this;
        a load that died with an exception will retry as a miss, which is
        a stall, so it must not report ready)."""
        if expert_id in self._entries:
            return True
        fut = self._inflight.get(expert_id)
        return (fut is not None and fut.done() and not fut.cancelled()
                and fut.exception() is None)

    @property
    def used_bytes(self) -> int:
        return self._used

    def activate(self, expert_id: str, *, read_only: bool = True):
        """Return the device pytree for an expert. Resident -> no stall;
        in-flight prefetch -> block only for the unfinished tail; true
        miss -> synchronous load through the same pipeline. The measured
        block time lands in ``stats.switch_seconds``."""
        if expert_id in self._entries:
            self._entries.move_to_end(expert_id)
            self.stats.hits += 1
            return self._entries[expert_id].value
        t0 = time.perf_counter()
        sp = trace.span("activate", cat="switch", expert=expert_id)
        sp.__enter__()
        fut = self._inflight.pop(expert_id, None)
        consumed_prefetch = False
        failed_wait_s = 0.0          # time sunk into a prefetch that raised
        loaded = None
        if fut is not None:
            self._unreserve(expert_id)
            try:
                loaded = fut.result()
                consumed_prefetch = True
                self.stats.hits += 1
                self.stats.prefetch_hits += 1
            except Exception:
                # failed prefetch load: retry as a miss — but the wait on
                # the doomed future is its own stall cause, not miss time
                # (previously folded into the miss bucket, hiding the
                # wasted prefetch-issue cost)
                failed_wait_s = time.perf_counter() - t0
                self.stats.prefetch_failures += 1
                self.stats.stall_failed_prefetch_seconds += failed_wait_s
                self.ledger.note_stall(failed_wait_s, cause="failed_prefetch")
                trace.instant("prefetch_failed", cat="switch",
                              expert=expert_id)
        if loaded is None:
            # true miss: load inline on the caller thread — submitting to
            # the (max_inflight-sized) executor would queue the critical
            # path behind in-flight prefetches of OTHER experts
            self.stats.misses += 1
            loaded = self._load_job(expert_id)
        cause = "prefetch" if consumed_prefetch else (
            "failed_prefetch" if failed_wait_s else "miss")
        value = self._finish_load(expert_id, loaded, read_only, cause=cause)
        dt = time.perf_counter() - t0
        self.stats.switch_seconds += dt
        if consumed_prefetch:
            self.stats.stall_prefetch_seconds += dt
            self.ledger.note_stall(dt, cause="prefetch")
        else:
            miss_dt = dt - failed_wait_s
            self.stats.stall_miss_seconds += miss_dt
            self.ledger.note_stall(miss_dt, cause="miss")
        sp.add(outcome=cause, nbytes=loaded.nbytes)
        sp.__exit__(None, None, None)
        return value

    def prefetch(self, expert_id: str, *, read_only: bool = True) -> bool:
        """Issue an async load for a predicted-next expert; returns True if
        one was started. Never blocks: the store read and the H2D copy both
        run on the background executor and overlap in-flight compute
        (paper Fig 9 step overlap). ``read_only`` is advisory here — the
        entry's flag is set by the ``activate`` that consumes it."""
        if expert_id in self._entries or expert_id in self._inflight:
            return False
        while len(self._inflight) >= self.max_inflight:
            stale = next(iter(self._inflight))   # oldest prediction loses
            self.cancel(stale)
        # reserve HBM up front (size from the store's AOT manifest) so
        # concurrent in-flight loads can never over-commit the tier; a
        # prediction that cannot fit is skipped, not an error. Legacy
        # fetch-callable stores can only size an expert by fetching it —
        # a synchronous caller-thread read that would defeat the prefetch —
        # so they skip the reservation (pre-reservation semantics).
        if self.store.cheap_nbytes:
            try:
                need = self.store.nbytes(expert_id)
            except Exception:
                return False                 # unknown expert: nothing to do
            if not self._make_room(need, strict=False):
                return False
            self._reserved[expert_id] = need
            self.ledger.reserve(need)
        self._inflight[expert_id] = self._executor().submit(
            self._load_job, expert_id)
        self.stats.prefetches_issued += 1
        return True

    def cancel(self, expert_id: str) -> bool:
        """Cancel an in-flight prefetch. If the load already started on the
        worker, its result is discarded instead (never installed)."""
        fut = self._inflight.pop(expert_id, None)
        if fut is None:
            return False
        self._unreserve(expert_id)
        fut.cancel()
        self.stats.prefetches_cancelled += 1
        return True

    def mark_dirty(self, expert_id: str):
        self._entries[expert_id].dirty = True

    def drop(self, expert_id: str):
        """Explicitly retire an expert: cancel any in-flight prefetch and,
        for resident entries, write back dirty mutable state before
        releasing HBM (same books as eviction — previously this silently
        lost dirty state and skipped the stats)."""
        self.cancel(expert_id)
        if expert_id in self._entries:
            entry = self._entries.pop(expert_id)
            self.stats.drops += 1
            self._retire(expert_id, entry)

    def expert_ids(self):
        return list(self._entries.keys())

    def close(self):
        """Cancel pending prefetches and stop the executor. The cache stays
        usable — a later activate/prefetch restarts it lazily."""
        for expert_id in list(self._inflight):
            self.cancel(expert_id)
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None


def model_switch_time(nbytes: int, machine: MachineTiers = TPU_V5E_NODE) -> float:
    """Analytic switch latency: capacity-tier -> HBM copy at node bandwidth
    (paper Fig 1 / Fig 12: the DDR->HBM copy term)."""
    return nbytes / machine.copy_bw_node
