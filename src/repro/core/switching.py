"""Expert switching engine: the HBM tier as a software-managed LRU cache of
expert weights over the host-DRAM capacity tier (paper §V-B CoE runtime).

Mechanics reproduced from the paper:
  * LRU eviction when HBM capacity is hit;
  * read-only symbols (weights) skip copy-back to the capacity tier on
    eviction — only mutable state would be written back;
  * per-model ahead-of-time size contracts (each compiled expert declares its
    HBM/DDR footprint before activation);
  * prefetch: the copy of a predicted next expert is issued asynchronously so
    it overlaps with the current expert's decode (JAX dispatch is async —
    the transfer rides the same mechanism the paper's §VII P2P/DDR streams
    use, without blocking the compute stream).
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.core.memory_tiers import MachineTiers, TPU_V5E_NODE


def tree_bytes(tree) -> int:
    return int(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)))


@dataclass
class SwitchStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    bytes_copied_in: int = 0
    bytes_copied_back: int = 0
    bytes_copyback_elided: int = 0
    switch_seconds: float = 0.0

    def as_dict(self):
        return dataclasses_asdict(self)


def dataclasses_asdict(obj):
    import dataclasses
    return dataclasses.asdict(obj)


@dataclass
class _Entry:
    value: Any             # device pytree
    nbytes: int
    read_only: bool
    dirty: bool = False


class HBMWeightCache:
    """LRU cache of expert parameter pytrees in device memory ("HBM"),
    backed by a host-memory fetch function (the "DDR" capacity tier).

    ``fetch(expert_id) -> host pytree`` is the DDR read; ``device_put`` is
    the DDR->HBM copy. ``writeback(expert_id, value)`` is only invoked for
    dirty non-read-only entries (paper's copy-back elision).
    """

    def __init__(self, capacity_bytes: int,
                 fetch: Callable[[str], Any],
                 writeback: Optional[Callable[[str, Any], None]] = None,
                 device=None,
                 sharding=None):
        self.capacity = int(capacity_bytes)
        self.fetch = fetch
        self.writeback = writeback
        self.device = device
        self.sharding = sharding
        self._entries: "OrderedDict[str, _Entry]" = OrderedDict()
        self._used = 0
        self.stats = SwitchStats()

    # -- internals -----------------------------------------------------
    def _put_device(self, host_tree):
        if self.sharding is not None:
            return jax.device_put(host_tree, self.sharding)
        if self.device is not None:
            return jax.device_put(host_tree, self.device)
        return jax.device_put(host_tree)

    def _evict_one(self):
        name, entry = self._entries.popitem(last=False)     # LRU = oldest
        self._used -= entry.nbytes
        self.stats.evictions += 1
        if entry.dirty and not entry.read_only and self.writeback is not None:
            host = jax.device_get(entry.value)
            self.writeback(name, host)
            self.stats.bytes_copied_back += entry.nbytes
        else:
            self.stats.bytes_copyback_elided += entry.nbytes
        del entry

    def _make_room(self, need: int):
        if need > self.capacity:
            raise MemoryError(
                f"expert of {need} bytes exceeds HBM tier capacity "
                f"{self.capacity}")
        while self._used + need > self.capacity:
            self._evict_one()

    # -- public API ------------------------------------------------------
    def resident(self, expert_id: str) -> bool:
        return expert_id in self._entries

    @property
    def used_bytes(self) -> int:
        return self._used

    def activate(self, expert_id: str, *, read_only: bool = True):
        """Return the device pytree for an expert, copying it in on miss.
        Updates LRU order. Blocks until the copy is complete (decode needs
        the weights); use ``prefetch`` to overlap."""
        if expert_id in self._entries:
            self._entries.move_to_end(expert_id)
            self.stats.hits += 1
            return self._entries[expert_id].value
        self.stats.misses += 1
        t0 = time.perf_counter()
        host = self.fetch(expert_id)
        nbytes = tree_bytes(host)
        self._make_room(nbytes)
        dev = self._put_device(host)
        jax.block_until_ready(dev)
        self.stats.switch_seconds += time.perf_counter() - t0
        self.stats.bytes_copied_in += nbytes
        self._entries[expert_id] = _Entry(dev, nbytes, read_only)
        self._used += nbytes
        return dev

    def prefetch(self, expert_id: str, *, read_only: bool = True) -> bool:
        """Issue an async copy for a predicted-next expert; returns True if a
        copy was started. Does NOT block — the transfer overlaps with
        whatever compute is in flight (paper Fig 9 step overlap)."""
        if expert_id in self._entries:
            return False
        host = self.fetch(expert_id)
        nbytes = tree_bytes(host)
        self._make_room(nbytes)
        dev = self._put_device(host)      # async dispatch, no block
        self.stats.bytes_copied_in += nbytes
        self._entries[expert_id] = _Entry(dev, nbytes, read_only)
        self._entries.move_to_end(expert_id, last=False)  # prefetch ≠ recency
        self._used += nbytes
        return True

    def mark_dirty(self, expert_id: str):
        self._entries[expert_id].dirty = True

    def drop(self, expert_id: str):
        if expert_id in self._entries:
            e = self._entries.pop(expert_id)
            self._used -= e.nbytes

    def expert_ids(self):
        return list(self._entries.keys())


def model_switch_time(nbytes: int, machine: MachineTiers = TPU_V5E_NODE) -> float:
    """Analytic switch latency: capacity-tier -> HBM copy at node bandwidth
    (paper Fig 1 / Fig 12: the DDR->HBM copy term)."""
    return nbytes / machine.copy_bw_node
