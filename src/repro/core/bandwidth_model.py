"""Static bandwidth/latency model (paper §VII 'Managing bandwidth in
software': a first-order static model of application needs vs hardware).

Used by: the CoE scheduler (switch-vs-execute tradeoffs), the Table V /
Fig 12 benchmarks (cross-machine latency/footprint projections), and the
roofline analysis (three-term step-time model).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional

from repro.core.memory_tiers import MachineTiers, MACHINES
from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class StepCost:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def bottleneck(self) -> str:
        vals = {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}
        return max(vals, key=vals.get)


def decode_step_cost(n_active_params: int, kv_bytes_per_token_ctx: int,
                     batch: int, machine: MachineTiers, tp: int = 8,
                     dtype_bytes: int = 2,
                     collective_bytes: float = 0.0,
                     link_bw: float = 50e9) -> StepCost:
    """One autoregressive decode step for a whole batch, TP over `tp` sockets.

    memory term: every active weight byte + the KV cache bytes stream from
    HBM once per step (the paper's >85%-of-HBM-bw fused decode regime).
    """
    weight_bytes = n_active_params * dtype_bytes
    flops = 2.0 * n_active_params * batch
    mem = (weight_bytes + kv_bytes_per_token_ctx * batch) / tp
    comp = flops / tp
    coll = collective_bytes / tp
    return StepCost(
        compute_s=comp / machine.peak_flops_bf16,
        memory_s=mem / (machine.hbm.bandwidth * machine.hbm_efficiency),
        collective_s=coll / link_bw,
    )


def switch_cost(expert_bytes: int, machine: MachineTiers) -> float:
    """Capacity tier -> HBM copy time (whole node bandwidth)."""
    return expert_bytes / machine.copy_bw_node


def expert_service_cost(expert_bytes: int, requests: float,
                        machine: MachineTiers, *, tp: int = 1,
                        avg_tokens: int = 16, resident: bool = True,
                        dtype_bytes: int = 2) -> float:
    """First-order seconds to serve ``requests`` requests of one expert on a
    ``tp``-socket group: decode execution (memory-bound step model) plus, for
    a non-resident expert, one capacity-tier -> HBM copy per activation.
    ``node/placement.py`` balances socket groups on this cost — per-socket
    *bandwidth*, not FLOPs, drives the assignment (arXiv 2403.14123)."""
    n_params = max(expert_bytes // dtype_bytes, 1)
    step = decode_step_cost(n_params, 0, 1, machine, tp=tp).step_s
    exec_s = requests * avg_tokens * step
    miss_s = 0.0 if resident else requests * switch_cost(expert_bytes, machine)
    return exec_s + miss_s


def coe_latency(n_experts_used: int, expert_bytes: int, resident_experts: int,
                decode_cost: StepCost, n_tokens: int, machine: MachineTiers,
                router_cost_s: float = 0.0) -> Dict[str, float]:
    """Fig 12 model: total latency to serve one batch where
    ``n_experts_used`` distinct experts are needed and ``resident_experts``
    already sit in HBM (LRU hits)."""
    misses = max(0, n_experts_used - resident_experts)
    sw = misses * switch_cost(expert_bytes, machine)
    ex = n_experts_used * n_tokens * decode_cost.step_s
    return {"switch_s": sw, "exec_s": ex, "router_s": router_cost_s,
            "total_s": sw + ex + router_cost_s}


def footprint_nodes(n_experts: int, expert_bytes: int, machine: MachineTiers,
                    use_capacity_tier: bool) -> int:
    """Fig 13 model: nodes needed to *hold* a CoE at full service latency.
    With the capacity tier, experts live in DDR/host and stream to HBM; the
    HBM only needs the working set. Without it (the DGX HBM-only scenario),
    all experts must fit in aggregate HBM."""
    total = n_experts * expert_bytes
    if use_capacity_tier:
        # capacity tier is per socket (paper Table II: 1.5 TiB DDR / socket)
        per_node = machine.capacity.capacity * machine.sockets_per_node
    else:
        # HBM-only: reserve ~8% for KV cache + activations
        per_node = machine.hbm.capacity * machine.sockets_per_node * 0.92
    return max(1, math.ceil(total / per_node))
