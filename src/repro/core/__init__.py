"""Core: the paper's contribution — Composition of Experts on a three-tier
memory system with streaming-dataflow fusion."""
from repro.core.coe import CompositionOfExperts, ExpertHandle, GenerationResult
from repro.core.router import LMRouter, HashRouter
from repro.core.switching import HBMWeightCache, SwitchStats, model_switch_time
from repro.core.memory_tiers import (
    MemoryTier, MachineTiers, MACHINES, SN40L_NODE, DGX_A100, DGX_H100,
    TPU_V5E_NODE, Symbol, allocate_static, spill_order, plan_placement,
    HBMBudget, plan_hbm_budget,
)
from repro.core import bandwidth_model, fusion

__all__ = [
    "CompositionOfExperts", "ExpertHandle", "GenerationResult",
    "LMRouter", "HashRouter", "HBMWeightCache", "SwitchStats",
    "model_switch_time", "MemoryTier", "MachineTiers", "MACHINES",
    "SN40L_NODE", "DGX_A100", "DGX_H100", "TPU_V5E_NODE",
    "Symbol", "allocate_static", "spill_order", "plan_placement",
    "HBMBudget", "plan_hbm_budget",
    "bandwidth_model", "fusion",
]
