"""Fusion planner: the streaming-dataflow analogue at the graph level.

The paper's compiler fuses 20+ ops per kernel automatically (Fig 11). On TPU
the analogous decisions are (a) which op groups become single Pallas
mega-kernels, and (b) what XLA fuses inside one jit. This module models the
op-list of a decoder layer for any ModelConfig and reports, per fusion level:
  * kernel-launch counts (paper Fig 11),
  * HBM traffic and operational intensity (paper Table I).

Byte accounting per op: ``weight_bytes`` (parameters, read once per step in
either regime), ``stream_bytes`` (KV-cache-like streams, read in either
regime), ``act_in``/``act_out`` (activations — these round-trip to HBM when
UNFUSED, and stay in VMEM inside a fused group).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class Op:
    name: str
    flops: float
    weight_bytes: float = 0.0
    act_in: float = 0.0
    act_out: float = 0.0
    stream_bytes: float = 0.0

    @property
    def total_bytes(self):
        return self.weight_bytes + self.act_in + self.act_out + self.stream_bytes


def decoder_layer_ops(cfg: ModelConfig, batch: int, ctx: int,
                      seq: int = 1, dtype_bytes: int = 2) -> List[Op]:
    """Op list for one layer processing ``seq`` new tokens per sequence
    against ``ctx`` context (decode: seq=1; prefill/train: seq=S, ctx=S)."""
    D, F = cfg.d_model, cfg.d_ff or cfg.moe_d_ff
    Hq, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    B = batch
    T = B * seq                       # tokens processed this step
    act = T * D * dtype_bytes
    qb = T * Hq * dh * dtype_bytes
    kvb = T * Hkv * dh * dtype_bytes
    cache = B * ctx * Hkv * dh * dtype_bytes
    score = T * Hq * ctx * dtype_bytes

    ops = [
        Op("rmsnorm_attn", 4 * T * D, D * dtype_bytes, act, act),
        Op("q_proj", 2 * T * D * Hq * dh, D * Hq * dh * dtype_bytes, act, qb),
        Op("k_proj", 2 * T * D * Hkv * dh, D * Hkv * dh * dtype_bytes, act, kvb),
        Op("v_proj", 2 * T * D * Hkv * dh, D * Hkv * dh * dtype_bytes, act, kvb),
        Op("rope", 6 * T * (Hq + Hkv) * dh, 0, qb + kvb, qb + kvb),
        Op("cache_append", 0, 0, kvb, kvb),
        Op("attn_scores", 2 * T * Hq * dh * ctx, 0, qb, score,
           stream_bytes=cache),
        Op("softmax", 5 * T * Hq * ctx, 0, score, score),
        Op("attn_values", 2 * T * Hq * dh * ctx, 0, score, qb,
           stream_bytes=cache),
        Op("o_proj", 2 * T * Hq * dh * D, Hq * dh * D * dtype_bytes, qb, act),
        Op("residual_1", T * D, 0, 2 * act, act),
        Op("rmsnorm_mlp", 4 * T * D, D * dtype_bytes, act, act),
    ]
    hidden = T * F * dtype_bytes
    wDF = D * F * dtype_bytes
    if cfg.act in ("swiglu", "geglu"):
        ops += [
            Op("gate_proj", 2 * T * D * F, wDF, act, hidden),
            Op("up_proj", 2 * T * D * F, wDF, act, hidden),
            Op("act_mul", 3 * T * F, 0, 2 * hidden, hidden),
            Op("down_proj", 2 * T * F * D, wDF, hidden, act),
        ]
    else:
        ops += [
            Op("up_proj", 2 * T * D * F, wDF, act, hidden),
            Op("act", 2 * T * F, 0, hidden, hidden),
            Op("down_proj", 2 * T * F * D, wDF, hidden, act),
        ]
    ops.append(Op("residual_2", T * D, 0, 2 * act, act))
    if cfg.n_experts:
        ops.append(Op("router_gemm", 2 * T * D * cfg.n_experts,
                      D * cfg.n_experts * dtype_bytes, act,
                      T * cfg.n_experts * dtype_bytes))
        ops.append(Op("topk_dispatch", 8 * T * cfg.n_experts, 0,
                      T * cfg.n_experts * dtype_bytes, act))
    return ops


# the fused plan: which ops collapse into each Pallas mega-kernel
FUSED_GROUPS = [
    ("qkv_rope", ["rmsnorm_attn", "q_proj", "k_proj", "v_proj", "rope"]),
    ("flash_attention", ["cache_append", "attn_scores", "softmax",
                         "attn_values"]),
    ("oproj_residual", ["o_proj", "residual_1"]),
    ("ffn_fused", ["rmsnorm_mlp", "gate_proj", "up_proj", "act_mul", "act",
                   "down_proj", "residual_2"]),
    ("moe_fused", ["router_gemm", "topk_dispatch"]),
]


@dataclass
class FusionReport:
    unfused_kernels: int
    fused_kernels: int
    unfused_hbm_bytes: float
    fused_hbm_bytes: float
    flops: float

    @property
    def launch_ratio(self) -> float:
        return self.unfused_kernels / max(1, self.fused_kernels)

    @property
    def traffic_ratio(self) -> float:
        return self.unfused_hbm_bytes / self.fused_hbm_bytes

    @property
    def intensity_unfused(self) -> float:
        return self.flops / self.unfused_hbm_bytes

    @property
    def intensity_fused(self) -> float:
        return self.flops / self.fused_hbm_bytes


def plan(cfg: ModelConfig, batch: int, ctx: int, seq: int = 1,
         dtype_bytes: int = 2) -> FusionReport:
    ops = decoder_layer_ops(cfg, batch, ctx, seq, dtype_bytes)
    by_name: Dict[str, Op] = {o.name: o for o in ops}
    flops = sum(o.flops for o in ops)
    unfused_bytes = sum(o.total_bytes for o in ops)

    fused_kernels = 0
    fused_bytes = 0.0
    covered = set()
    for kname, members in FUSED_GROUPS:
        group = [by_name[m] for m in members if m in by_name and
                 m not in covered]
        if not group:
            continue
        covered.update(o.name for o in group)
        fused_kernels += 1
        # fused: weights + external streams read once; activations stay in
        # VMEM except the group input and the group output
        fused_bytes += (sum(o.weight_bytes + o.stream_bytes for o in group)
                        + group[0].act_in + group[-1].act_out)
    for o in ops:
        if o.name not in covered:
            fused_kernels += 1
            fused_bytes += o.total_bytes

    return FusionReport(len(ops), fused_kernels, unfused_bytes, fused_bytes,
                        flops)


def model_fusion_report(cfg: ModelConfig, batch: int, ctx: int,
                        seq: int = 1, dtype_bytes: int = 2) -> FusionReport:
    """Whole-model per-step report (layers x per-layer + embed/head)."""
    r = plan(cfg, batch, ctx, seq, dtype_bytes)
    L = cfg.n_layers
    T = batch * seq
    head_flops = 2 * T * cfg.d_model * cfg.vocab_size
    head_bytes = (cfg.d_model * cfg.vocab_size + T * cfg.vocab_size) \
        * dtype_bytes
    return FusionReport(
        unfused_kernels=r.unfused_kernels * L + 2,
        fused_kernels=r.fused_kernels * L + 2,
        unfused_hbm_bytes=r.unfused_hbm_bytes * L + head_bytes,
        fused_hbm_bytes=r.fused_hbm_bytes * L + head_bytes,
        flops=r.flops * L + head_flops,
    )


def backend_prediction(cfg: ModelConfig, batch: int, ctx: int,
                       backend: str, seq: int = 1,
                       dtype_bytes: int = 2) -> Dict[str, float]:
    """Model-predicted per-decode-step HBM bytes and operational intensity
    for a serving backend (``serving/backends.py``): 'xla' executes the
    unfused op graph (every inter-op activation round-trips to HBM), 'fused'
    the Pallas mega-kernel plan (activations stay in VMEM inside each
    ``FUSED_GROUPS`` entry). The Fig-6 fused-vs-unfused sweep prints these
    next to the measured traffic of the compiled step."""
    r = model_fusion_report(cfg, batch, ctx, seq, dtype_bytes)
    fused = backend == "fused"
    return {
        "backend": backend,
        "predicted_hbm_bytes": r.fused_hbm_bytes if fused
        else r.unfused_hbm_bytes,
        "predicted_intensity": r.intensity_fused if fused
        else r.intensity_unfused,
        "predicted_kernels": r.fused_kernels if fused else r.unfused_kernels,
        "flops": r.flops,
    }
