"""CoE router (paper §II): a specialist model that assigns each prompt to the
most relevant expert.

Two routers are provided:
  * ``LMRouter`` — the paper's design: an LM backbone (Llama2-class, same
    family as the experts) with a classification head over experts; the
    pooled last-hidden-state is projected to expert logits.
  * ``HashRouter`` — a deterministic, weight-free router for benchmarks and
    property tests (stable prompt -> expert mapping).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import get_model
from repro.models.common import spec, init_params, abstract_params


@dataclass
class LMRouter:
    cfg: ModelConfig
    n_experts: int

    def param_specs(self):
        backbone = get_model(self.cfg).param_specs()
        return {
            "backbone": backbone,
            "head": spec((self.cfg.d_model, self.n_experts),
                         ("embed", "experts_r")),
        }

    def init(self, rng):
        return init_params(rng, self.param_specs())

    def abstract_params(self):
        return abstract_params(self.param_specs())

    def logits(self, params, tokens):
        """tokens (B,S) -> (B, n_experts)."""
        from repro.models import registry
        mod = registry._family_module(self.cfg.family)
        # pooled last hidden state: forward with last_only, before unembed we
        # reuse logits path — simplest faithful readout: last-token hidden is
        # recovered by a linear head on the last-token embedding-space logits.
        # To keep one forward path, we call forward(last_only) on a model with
        # tied unembed removed and read the hidden via a stop at final norm.
        h = self._last_hidden(params["backbone"], tokens)
        return (h.astype(jnp.float32) @ params["head"].astype(jnp.float32))

    def _last_hidden(self, bparams, tokens):
        from repro.models import layers as L
        from repro.models import transformer as T
        cfg = self.cfg
        B, S = tokens.shape
        h = T.embed_tokens(cfg, bparams, tokens)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

        def body(hh, lp):
            y, _ = T._layer(cfg, lp, hh, positions, moe=cfg.n_experts > 0)
            return y, None

        h, _ = jax.lax.scan(body, h, bparams["layers"])
        h = L.apply_norm(cfg, bparams["final_norm"], h)
        return h[:, -1]

    def route(self, params, tokens) -> jnp.ndarray:
        """tokens (B,S) -> (B,) expert indices."""
        return jnp.argmax(self.logits(params, tokens), axis=-1)


class HashRouter:
    """Deterministic router: stable hash of the prompt token ids."""

    def __init__(self, n_experts: int, seed: int = 0):
        self.n_experts = n_experts
        self.seed = seed

    def route_host(self, tokens: np.ndarray) -> np.ndarray:
        out = []
        for row in np.asarray(tokens):
            hsh = hashlib.sha256(
                row.tobytes() + str(self.seed).encode()).digest()
            out.append(int.from_bytes(hsh[:4], "big") % self.n_experts)
        return np.asarray(out, np.int32)

    def route(self, params, tokens):
        return jnp.asarray(self.route_host(np.asarray(tokens)))
