"""SLO attainment and goodput accounting per tenant/priority.

The frontend accepts TTFT deadlines (``slo_ttft_s``) and — new here — TPOT
deadlines (``slo_tpot_s``, mean inter-token seconds after the first token).
This tracker turns finished requests into the serving numbers an operator
actually pages on:

  * **attainment** — fraction of finished requests that met every SLO they
    declared (a request with no SLO counts as met: vacuous truth keeps
    mixed traffic comparable);
  * **goodput** — *SLO-met* tokens per second (tokens from requests that
    missed a deadline are throughput, not goodput — the §VII serving
    claims are only meaningful in goodput terms);
  * **burn rate** — per-tenant miss rate over rolling windows divided by
    the error budget (``1 - target_attainment``), the SRE-style signal:
    burn rate 1.0 = exactly spending the budget, >1 = on track to blow it.

Registry series (all labeled ``{tenant=,priority=}`` so node deployments
compose with ``{group=}`` labels): ``slo.requests``, ``slo.requests_met``,
``slo.ttft_miss``, ``slo.tpot_miss``, ``slo.tokens_out``,
``slo.tokens_met``, plus ``slo.burn_rate{tenant=,window=}`` gauges.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Any, Dict, Optional, Tuple

from repro.obs.metrics import MetricsRegistry


def ttft_met(req: Any) -> Optional[bool]:
    """Did the request meet its TTFT deadline? ``None`` = no deadline."""
    slo = getattr(req, "slo_ttft_s", None)
    if slo is None or req.first_token_s is None:
        return None
    return (req.first_token_s - req.arrival_s) <= slo


def tpot_met(req: Any) -> Optional[bool]:
    """Did the request meet its TPOT (mean inter-token) deadline? ``None``
    = no deadline or single-token output (no inter-token gap exists)."""
    slo = getattr(req, "slo_tpot_s", None)
    if slo is None or req.done_s is None or req.first_token_s is None:
        return None
    n = len(req.output) if req.output is not None else 0
    if n <= 1:
        return None
    return (req.done_s - req.first_token_s) / (n - 1) <= slo


def request_slo_met(req: Any) -> bool:
    """True unless a *declared* deadline was missed."""
    return ttft_met(req) is not False and tpot_met(req) is not False


class SLOTracker:
    """Rolls finished requests into attainment/goodput/burn-rate series."""

    def __init__(self, registry: MetricsRegistry,
                 labels: Optional[Dict[str, Any]] = None, *,
                 target_attainment: float = 0.99,
                 windows: Tuple[float, ...] = (60.0, 300.0),
                 clock=time.perf_counter):
        if not 0.0 < target_attainment < 1.0:
            raise ValueError("target_attainment must be in (0, 1)")
        self._registry = registry
        self._labels = dict(labels or {})
        self.target_attainment = target_attainment
        self.windows = tuple(float(w) for w in windows)
        self._clock = clock
        self._t0 = clock()
        # mirrors of the registry counters, keyed (tenant, priority), so
        # attainment/goodput math never re-parses label strings
        self._requests: Dict[Tuple[str, int], int] = {}
        self._met: Dict[Tuple[str, int], int] = {}
        self._tokens: Dict[Tuple[str, int], int] = {}
        self._tokens_met: Dict[Tuple[str, int], int] = {}
        # per-tenant rolling (t, met) events for the burn-rate windows
        self._events: Dict[str, deque] = {}

    def _ctr(self, name: str, tenant: str, priority: int):
        return self._registry.counter(name, labels={
            **self._labels, "tenant": tenant, "priority": priority})

    # -- ingest ------------------------------------------------------------
    def observe(self, req: Any) -> bool:
        """Account one finished request; returns whether it met its SLOs."""
        tenant = getattr(req, "tenant", "default")
        prio = int(getattr(req, "priority", 0))
        key = (tenant, prio)
        n_tok = len(req.output) if getattr(req, "output", None) is not None \
            else 0
        t_ok, p_ok = ttft_met(req), tpot_met(req)
        met = t_ok is not False and p_ok is not False

        self._requests[key] = self._requests.get(key, 0) + 1
        self._tokens[key] = self._tokens.get(key, 0) + n_tok
        self._ctr("slo.requests", tenant, prio).inc()
        self._ctr("slo.tokens_out", tenant, prio).inc(n_tok)
        if t_ok is False:
            self._ctr("slo.ttft_miss", tenant, prio).inc()
        if p_ok is False:
            self._ctr("slo.tpot_miss", tenant, prio).inc()
        if met:
            self._met[key] = self._met.get(key, 0) + 1
            self._tokens_met[key] = self._tokens_met.get(key, 0) + n_tok
            self._ctr("slo.requests_met", tenant, prio).inc()
            self._ctr("slo.tokens_met", tenant, prio).inc(n_tok)

        now = self._clock()
        evs = self._events.setdefault(tenant, deque())
        evs.append((now, met))
        horizon = max(self.windows) if self.windows else 0.0
        while evs and evs[0][0] < now - horizon:
            evs.popleft()
        for w in self.windows:
            self._registry.gauge("slo.burn_rate", labels={
                **self._labels, "tenant": tenant, "window": int(w)}
            ).set(self.burn_rate(w, tenant, now=now))
        return met

    # -- derived views -----------------------------------------------------
    def _sum(self, d: Dict[Tuple[str, int], int],
             tenant: Optional[str]) -> int:
        return sum(v for (t, _), v in d.items()
                   if tenant is None or t == tenant)

    def attainment(self, tenant: Optional[str] = None) -> float:
        """SLO-met fraction of finished requests (1.0 before any finish)."""
        n = self._sum(self._requests, tenant)
        return self._sum(self._met, tenant) / n if n else 1.0

    def goodput(self, tenant: Optional[str] = None,
                wall_s: Optional[float] = None) -> float:
        """SLO-met tokens/s since construction (or over ``wall_s``)."""
        wall = wall_s if wall_s is not None else self._clock() - self._t0
        return self._sum(self._tokens_met, tenant) / wall if wall > 0 else 0.0

    def burn_rate(self, window_s: float, tenant: str,
                  now: Optional[float] = None) -> float:
        """Miss rate over the trailing window / error budget. 0.0 with no
        traffic in the window (nothing served = nothing missed)."""
        now = self._clock() if now is None else now
        evs = self._events.get(tenant, ())
        n = miss = 0
        for t, met in evs:
            if t >= now - window_s:
                n += 1
                miss += not met
        if n == 0:
            return 0.0
        return (miss / n) / (1.0 - self.target_attainment)

    def as_dict(self, tenant: Optional[str] = None) -> Dict[str, Any]:
        """Summary for ``/debug`` endpoints and bench reporting."""
        return {
            "requests": self._sum(self._requests, tenant),
            "requests_met": self._sum(self._met, tenant),
            "tokens_out": self._sum(self._tokens, tenant),
            "tokens_met": self._sum(self._tokens_met, tenant),
            "attainment": self.attainment(tenant),
            "goodput_tok_s": self.goodput(tenant),
            "target_attainment": self.target_attainment,
        }

    def tenants(self):
        return sorted({t for t, _ in self._requests})
