"""Runtime invariant watchdog: a background sampler over live engines.

A wedged serving process rarely crashes — it sits there with a stuck slot,
a leaked KV block, or a queue nobody drains, while the metrics endpoint
keeps answering. The watchdog samples engine state on an interval and
checks the invariants the rest of the system assumes:

  * **stuck_request** — an occupied slot whose request has emitted no token
    for ``stall_s`` (decode stopped making progress for THAT request);
  * **kv_invariant** — the ``PagedKVCache`` refcount books no longer
    balance (``pool.check_invariants()``: free list + refcounted blocks
    must partition the pool, refcount sum must cover the live tables — a
    leaked or double-freed block shows up here);
  * **hbm_budget** — the engine reports weights+KV outside its HBM tier
    budget (``hbm_in_budget()``);
  * **queue_stall** — a queued request older than ``queue_age_s`` (stalled
    admission: KV backpressure wedge, starvation logic gone wrong).

Every anomaly increments ``obs.anomaly{kind=}``, lands an instant trace
event and a flight-recorder event, and (``dump_path=``) triggers a full
postmortem bundle. ``strict=True`` raises ``WatchdogError`` from
``check_now()`` — the fault-injection tests run in that mode; production
samplers stay non-strict and page off the counter.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional, Sequence

from repro.obs import flightrec, trace
from repro.obs.metrics import MetricsRegistry


class WatchdogError(RuntimeError):
    """Strict-mode invariant violation; ``.anomalies`` holds the findings."""

    def __init__(self, anomalies: List[Dict[str, Any]]):
        self.anomalies = anomalies
        super().__init__(
            "; ".join(a.get("message", a["kind"]) for a in anomalies))


class Watchdog:
    """Samples one or more engines for invariant violations."""

    def __init__(self, engines, *,
                 registry: Optional[MetricsRegistry] = None,
                 interval_s: float = 1.0,
                 stall_s: float = 30.0,
                 queue_age_s: float = 60.0,
                 strict: bool = False,
                 recorder: Optional[flightrec.FlightRecorder] = None,
                 dump_path=None,
                 clock=time.perf_counter):
        if not isinstance(engines, Sequence):
            engines = [engines]
        self.engines = list(engines)
        self._registry = registry if registry is not None else (
            self.engines[0]._registry if self.engines
            else MetricsRegistry())
        self.interval_s = interval_s
        self.stall_s = stall_s
        self.queue_age_s = queue_age_s
        self.strict = strict
        self._recorder = recorder
        self._dump_path = dump_path
        self._clock = clock
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self.checks_run = 0

    # -- the checks --------------------------------------------------------
    def _engine_anomalies(self, eng, now: float) -> List[Dict[str, Any]]:
        found: List[Dict[str, Any]] = []
        labels = dict(getattr(eng, "_obs_labels", {}) or {})

        for idx, slot in enumerate(getattr(eng, "slots", ())):
            if slot is None:
                continue
            req = slot.req
            last = (getattr(req, "last_token_s", None)
                    or req.first_token_s or req.arrival_s)
            stalled = now - last
            if stalled > self.stall_s:
                found.append({
                    "kind": "stuck_request", "slot": idx, "rid": req.rid,
                    "expert": slot.expert, "stalled_s": stalled, **labels,
                    "message": f"slot {idx} rid {req.rid}: no token for "
                               f"{stalled:.1f}s"})

        pool = getattr(eng, "pool", None)
        if pool is not None:
            violations = pool.check_invariants()
            if violations:
                found.append({
                    "kind": "kv_invariant", "violations": violations,
                    **labels,
                    "message": "kv pool invariant: "
                               + "; ".join(violations)})

        in_budget = getattr(eng, "hbm_in_budget", None)
        if in_budget is not None and not in_budget():
            found.append({"kind": "hbm_budget", **labels,
                          "message": "HBM tier over budget"})

        for req in getattr(eng, "queue", ()):
            age = now - (getattr(req, "submit_s", None) or req.arrival_s)
            if age > self.queue_age_s:
                found.append({
                    "kind": "queue_stall", "rid": req.rid,
                    "expert": req.expert, "age_s": age, **labels,
                    "message": f"rid {req.rid} queued {age:.1f}s "
                               f"without admission"})
        return found

    def check_now(self) -> List[Dict[str, Any]]:
        """One sampling pass over every engine. Returns the anomalies
        (empty on a clean system); raises in strict mode instead."""
        self.checks_run += 1
        now = self._clock()
        anomalies: List[Dict[str, Any]] = []
        for eng in self.engines:
            anomalies.extend(self._engine_anomalies(eng, now))
        rec = self._recorder if self._recorder is not None \
            else flightrec.get_recorder()
        for a in anomalies:
            self._registry.counter(
                "obs.anomaly", labels={"kind": a["kind"]}).inc()
            trace.instant("anomaly", cat="watchdog", **{
                k: v for k, v in a.items() if k != "violations"})
            # the event's own "kind" is the ring-event class; the anomaly
            # class rides along as anomaly_kind
            rec.record("anomaly", anomaly_kind=a["kind"], **{
                k: v for k, v in a.items() if k != "kind"})
        if anomalies and self._dump_path is not None:
            rec.dump(self._dump_path, self._registry,
                     reason="watchdog_anomaly")
        if anomalies and self.strict:
            raise WatchdogError(anomalies)
        return anomalies

    # -- background sampler ------------------------------------------------
    def start(self) -> "Watchdog":
        if self._thread is not None:
            return self
        self._stop.clear()

        def loop():
            while not self._stop.wait(self.interval_s):
                try:
                    self.check_now()
                except WatchdogError:
                    pass       # strict raise is for check_now() callers;
                               # the sampler already counted + dumped

        self._thread = threading.Thread(target=loop, name="obs-watchdog",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=5)
        self._thread = None
