"""Metrics + debug snapshot endpoint (``launch/serve.py --metrics-port``).

Serves a registry and live component state over HTTP on a background
thread:

    GET /               JSON index of every mounted endpoint
    GET /metrics        Prometheus text exposition
    GET /metrics.json   flat JSON snapshot (same keys the bench JSONs use)
    GET /healthz        liveness probe (the process answers)
    GET /readyz         readiness probe: 503 until the engine's ``warmup()``
                        completed — load drivers must not count cold-compile
                        time as serving latency
    GET /debug/flight   the flight recorder's postmortem bundle, on demand
    GET /debug/<name>   any registered debug provider (slots, pool,
                        sessions, placement, ...) as JSON

Stdlib-only (``http.server``); fine for scrape-rate traffic, not a
user-facing proxy.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Dict, Optional

from repro.obs import flightrec
from repro.obs.metrics import MetricsRegistry


class MetricsServer:
    def __init__(self, registry: MetricsRegistry, port: int = 0,
                 host: str = "127.0.0.1", *,
                 ready_check: Optional[Callable[[], bool]] = None,
                 debug: Optional[Dict[str, Callable[[], Any]]] = None,
                 recorder: Optional[flightrec.FlightRecorder] = None):
        reg = registry
        self._debug: Dict[str, Callable[[], Any]] = dict(debug or {})
        srv = self

        class Handler(BaseHTTPRequestHandler):
            def _reply(self, body: bytes, ctype: str, code: int = 200):
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def _json(self, obj, code: int = 200):
                self._reply(json.dumps(obj, indent=1, default=str).encode(),
                            "application/json", code)

            def do_GET(self):
                if self.path == "/":
                    self._json({"endpoints": srv.endpoints()})
                elif self.path == "/metrics":
                    self._reply(reg.to_prometheus().encode(),
                                "text/plain; version=0.0.4")
                elif self.path == "/metrics.json":
                    self._json(reg.snapshot())
                elif self.path == "/healthz":
                    self._reply(b"ok\n", "text/plain")
                elif self.path == "/readyz":
                    if ready_check is None or ready_check():
                        self._reply(b"ready\n", "text/plain")
                    else:
                        self._reply(b"warming\n", "text/plain", 503)
                elif self.path == "/debug/flight":
                    rec = (recorder if recorder is not None
                           else flightrec.get_recorder())
                    self._json(rec.bundle(reg))
                elif self.path.startswith("/debug/"):
                    name = self.path[len("/debug/"):]
                    fn = srv._debug.get(name)
                    if fn is None:
                        self.send_error(404)
                        return
                    try:
                        self._json(fn())
                    except Exception as e:  # noqa: BLE001 — debug surface
                        self._json({"error": f"{type(e).__name__}: {e}"},
                                   code=500)
                else:
                    self.send_error(404)

            def log_message(self, *args):       # scrapes are not news
                pass

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._thread: Optional[threading.Thread] = None

    def add_debug(self, name: str, fn: Callable[[], Any]) -> None:
        """Mount (or replace) ``/debug/<name>``."""
        self._debug[name] = fn

    def endpoints(self):
        return (["/metrics", "/metrics.json", "/healthz", "/readyz",
                 "/debug/flight"]
                + sorted(f"/debug/{n}" for n in self._debug))

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        host, port = self._httpd.server_address[:2]
        return f"http://{host}:{port}"

    def start(self) -> "MetricsServer":
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="metrics-httpd", daemon=True)
        self._thread.start()
        return self

    def stop(self):
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None


def serve_metrics(registry: MetricsRegistry, port: int = 0,
                  host: str = "127.0.0.1", *,
                  ready_check: Optional[Callable[[], bool]] = None,
                  debug: Optional[Dict[str, Callable[[], Any]]] = None,
                  recorder: Optional[flightrec.FlightRecorder] = None
                  ) -> MetricsServer:
    """Start serving ``registry`` in the background; returns the server
    (``.port`` for the bound port, ``.stop()`` to shut down)."""
    return MetricsServer(registry, port, host, ready_check=ready_check,
                         debug=debug, recorder=recorder).start()
