"""Metrics snapshot endpoint (``launch/serve.py --metrics-port``).

Serves a registry over HTTP on a background thread:

    GET /metrics        Prometheus text exposition
    GET /metrics.json   flat JSON snapshot (same keys the bench JSONs use)
    GET /healthz        liveness probe

Stdlib-only (``http.server``); fine for scrape-rate traffic, not a
user-facing proxy.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.obs.metrics import MetricsRegistry


class MetricsServer:
    def __init__(self, registry: MetricsRegistry, port: int = 0,
                 host: str = "127.0.0.1"):
        reg = registry

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):
                if self.path in ("/", "/metrics"):
                    body = reg.to_prometheus().encode()
                    ctype = "text/plain; version=0.0.4"
                elif self.path == "/metrics.json":
                    body = json.dumps(reg.snapshot(), indent=1).encode()
                    ctype = "application/json"
                elif self.path == "/healthz":
                    body, ctype = b"ok\n", "text/plain"
                else:
                    self.send_error(404)
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):       # scrapes are not news
                pass

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._thread: Optional[threading.Thread] = None

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        host, port = self._httpd.server_address[:2]
        return f"http://{host}:{port}"

    def start(self) -> "MetricsServer":
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="metrics-httpd", daemon=True)
        self._thread.start()
        return self

    def stop(self):
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None


def serve_metrics(registry: MetricsRegistry, port: int = 0,
                  host: str = "127.0.0.1") -> MetricsServer:
    """Start serving ``registry`` in the background; returns the server
    (``.port`` for the bound port, ``.stop()`` to shut down)."""
    return MetricsServer(registry, port, host).start()
