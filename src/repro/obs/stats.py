"""Registry-backed stats views.

The repo's four serving stats objects (``ServeStats``, ``SwitchStats``,
``NodeStats``, ``PagedStats``) used to be ad-hoc dataclasses of bare
counters. They are now *views* over a ``MetricsRegistry``: every field is a
descriptor whose storage is a registry counter/gauge named
``<prefix>.<field>`` under the view's labels, so the same numbers the
engine/cache/node mutate in place are simultaneously visible to the
Prometheus endpoint, registry snapshots and the benchmark JSON — no copying,
no second bookkeeping path.

The classes keep their dataclass ergonomics: ``stats.hits += 1``,
keyword construction (``NodeStats(requests=3, ...)``), a dataclass-style
``repr`` and the public ``.as_dict()`` shape every benchmark gate depends
on. A view constructed bare (``SwitchStats()``) owns a private registry —
two engines never alias each other's counters by accident; passing
``registry=``/``labels=`` publishes into a shared registry (what
``launch/serve.py --metrics-port`` and ``RDUNode`` do, labelling per
socket group).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

from repro.obs.metrics import Counter, Gauge, MetricsRegistry


class stat_field:
    """Descriptor: one numeric stats field stored in the view's registry."""

    __slots__ = ("kind", "default", "name")

    def __init__(self, kind: str = "counter", default=0):
        if kind not in ("counter", "gauge"):
            raise ValueError(f"unknown stat kind {kind!r}")
        self.kind = kind
        self.default = default

    def __set_name__(self, owner, name):
        self.name = name

    def __get__(self, obj, objtype=None):
        if obj is None:
            return self
        v = obj._metric(self.name).value
        # preserve int-ness for count-like fields initialised with an int
        if isinstance(self.default, int) and isinstance(v, float):
            return int(v) if v.is_integer() else v
        return v

    def __set__(self, obj, v):
        obj._metric(self.name).set(v)


def counter_field(default=0):
    return stat_field("counter", default)


def gauge_field(default=0):
    return stat_field("gauge", default)


class StatsView:
    """Base class for registry-backed stats. Subclasses declare fields as
    ``counter_field()`` / ``gauge_field()`` class attributes, set ``PREFIX``
    (the registry metric-name prefix) and optionally ``DERIVED`` (property
    names included in ``as_dict``)."""

    PREFIX = "stats"
    DERIVED: Tuple[str, ...] = ()

    _FIELDS: Tuple[str, ...] = ()
    _KINDS: Dict[str, stat_field] = {}

    def __init_subclass__(cls, **kw):
        super().__init_subclass__(**kw)
        fields = dict(getattr(cls, "_KINDS", {}))
        for name, attr in vars(cls).items():
            if isinstance(attr, stat_field):
                fields[name] = attr
        cls._KINDS = fields
        cls._FIELDS = tuple(fields)

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 labels: Optional[Dict[str, Any]] = None, **values):
        self._registry = registry if registry is not None else MetricsRegistry()
        self._labels = dict(labels or {})
        self._metrics: Dict[str, Any] = {}
        for f in self._FIELDS:          # eager: snapshots show zeros, not gaps
            self._metric(f)
        unknown = set(values) - set(self._FIELDS)
        if unknown:
            raise TypeError(f"{type(self).__name__}: unknown fields {unknown}")
        for k, v in values.items():
            setattr(self, k, v)

    @property
    def registry(self) -> MetricsRegistry:
        return self._registry

    @property
    def labels(self) -> Dict[str, Any]:
        return dict(self._labels)

    def _metric(self, field: str):
        m = self._metrics.get(field)
        if m is None:
            spec = self._KINDS[field]
            name = f"{self.PREFIX}.{field}"
            if spec.kind == "counter":
                m = self._registry.counter(name, self._labels)
            else:
                m = self._registry.gauge(name, self._labels)
            if m.value == 0 and spec.default != 0:
                m.set(spec.default)
            self._metrics[field] = m
        return m

    def reset(self):
        """Zero every field in place (same registry, same series)."""
        for f in self._FIELDS:
            self._metric(f).set(self._KINDS[f].default)
        return self

    def as_dict(self) -> Dict[str, Any]:
        return as_dict(self)

    def __repr__(self):
        inner = ", ".join(f"{f}={getattr(self, f)!r}" for f in self._FIELDS)
        return f"{type(self).__name__}({inner})"

    def __eq__(self, other):
        if not isinstance(other, type(self)):
            return NotImplemented
        return all(getattr(self, f) == getattr(other, f)
                   for f in self._FIELDS)


def as_dict(obj, derived: Tuple[str, ...] = ()) -> Dict[str, Any]:
    """THE shared stats serializer (previously each stats class hand-rolled
    its own). Works on ``StatsView`` subclasses (fields + their ``DERIVED``
    properties) and plain dataclasses (``dataclasses.asdict`` + ``derived``
    extras)."""
    if isinstance(obj, StatsView):
        out = {f: getattr(obj, f) for f in obj._FIELDS}
        names = tuple(obj.DERIVED) + tuple(d for d in derived
                                           if d not in obj.DERIVED)
    elif dataclasses.is_dataclass(obj):
        out = dataclasses.asdict(obj)
        names = derived
    else:
        raise TypeError(f"as_dict: unsupported type {type(obj).__name__}")
    for d in names:
        out[d] = getattr(obj, d)
    return out
