"""Black-box flight recorder: a bounded ring of structured serving events.

Production postmortems need the *last thing the system did*, not the full
history: which requests were admitted into which slots, which expert
switches ran, what got preempted, which sessions were evicted and which
blocks reclaimed — right up to the moment something wedged. The recorder is
a fixed-capacity ring of small dicts (``record()`` is one deque append on
the hot path; overflow drops the oldest event and counts the drop), plus
registered *state providers* that snapshot live component state
(slots/pool/sessions/placement) only when a dump is actually taken.

``dump()`` writes one self-contained JSON postmortem bundle::

    {"schema": "repro.flightrec/1", "events": [...], "dropped_events": n,
     "metrics": <registry snapshot>, "state": {"slots": ..., "pool": ...}}

Triggers: on demand (``/debug/flight``), on a watchdog anomaly
(``Watchdog(dump_on_anomaly=...)``), or via SIGUSR2 in ``launch/serve.py``.
``validate_bundle`` is the schema check the tests and the signal handler
round-trip through.

Like ``obs.trace``, a process-default recorder backs a module-level
``record()`` so the kv pool and session manager can emit events without
threading a recorder handle through every constructor.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

SCHEMA = "repro.flightrec/1"

#: every ring event carries these; ``kind`` names the event class
EVENT_KINDS = ("admit", "evict", "preempt", "switch", "reclaim", "handoff",
               "anomaly", "done")


class FlightRecorder:
    """Bounded ring of structured events + lazily-snapshotted state."""

    def __init__(self, capacity: int = 4096):
        self.capacity = int(capacity)
        self._ring: deque = deque()
        self._lock = threading.Lock()
        self.dropped_events = 0
        # name -> zero-arg callable returning a JSON-able snapshot; called
        # only at dump time so providers may be arbitrarily expensive
        self._state_providers: Dict[str, Callable[[], Any]] = {}

    # -- recording (hot path) ---------------------------------------------
    def record(self, kind: str, **fields) -> None:
        ev = {"ts": time.perf_counter(), "kind": kind, **fields}
        with self._lock:
            if len(self._ring) >= self.capacity:
                self._ring.popleft()
                self.dropped_events += 1
            self._ring.append(ev)

    # -- state providers ---------------------------------------------------
    def add_state_provider(self, name: str,
                           fn: Callable[[], Any]) -> None:
        """Register (or replace) a named live-state snapshot for dumps."""
        self._state_providers[name] = fn

    def state_providers(self) -> Dict[str, Callable[[], Any]]:
        return dict(self._state_providers)

    # -- export ------------------------------------------------------------
    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._ring)

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()
            self.dropped_events = 0

    def bundle(self, registry=None, reason: str = "on_demand"
               ) -> Dict[str, Any]:
        """The postmortem document. A provider that raises is captured as
        ``{"error": ...}`` — a dump taken because something is broken must
        not die on the broken component's own state."""
        state: Dict[str, Any] = {}
        for name, fn in self._state_providers.items():
            try:
                state[name] = fn()
            except Exception as e:        # noqa: BLE001 — postmortem path
                state[name] = {"error": f"{type(e).__name__}: {e}"}
        return {"schema": SCHEMA,
                "reason": reason,
                "wall_time": time.time(),
                "capacity": self.capacity,
                "dropped_events": self.dropped_events,
                "events": self.events(),
                "metrics": dict(registry.snapshot()) if registry is not None
                else {},
                "state": state}

    def dump(self, path, registry=None,
             reason: str = "on_demand") -> Path:
        """Write the bundle as JSON; returns the path written."""
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.bundle(registry, reason=reason),
                                   indent=1, default=str))
        return path


def validate_bundle(doc: Dict[str, Any]) -> List[str]:
    """Schema check for a dumped bundle; returns problems (empty = valid)."""
    problems: List[str] = []
    if not isinstance(doc, dict):
        return ["bundle is not an object"]
    if doc.get("schema") != SCHEMA:
        problems.append(f"schema {doc.get('schema')!r} != {SCHEMA!r}")
    for key, typ in (("events", list), ("metrics", dict), ("state", dict),
                     ("dropped_events", int), ("reason", str)):
        if not isinstance(doc.get(key), typ):
            problems.append(f"missing/typed-wrong {key!r} "
                            f"(want {typ.__name__})")
    for i, ev in enumerate(doc.get("events") or []):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        if "kind" not in ev or "ts" not in ev:
            problems.append(f"event {i}: missing kind/ts")
    return problems


# ----------------------------------------------------------------------
# Process-wide default recorder (module-level API the components use)
# ----------------------------------------------------------------------
_recorder = FlightRecorder()


def get_recorder() -> FlightRecorder:
    return _recorder


def set_recorder(rec: FlightRecorder) -> FlightRecorder:
    global _recorder
    old, _recorder = _recorder, rec
    return old


def record(kind: str, **fields) -> None:
    _recorder.record(kind, **fields)


def add_state_provider(name: str, fn: Callable[[], Any]) -> None:
    _recorder.add_state_provider(name, fn)


def dump(path, registry=None, reason: str = "on_demand") -> Path:
    return _recorder.dump(path, registry, reason=reason)
