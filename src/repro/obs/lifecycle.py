"""Per-request phase ledger: where every request's wall-clock went.

CoServe-style serving analysis decomposes each request's latency into the
queue/switch/compute phases that scheduling actually controls. The engine
stamps five monotonic timestamps on every ``Request``::

    arrival_s   offered arrival (frontend heap entry / trace replay offset)
    submit_s    entered the engine (top of ``ServingEngine.submit``)
    admit_s     admission started its prefill / handoff adoption
    first_token_s   prefill done, first token emitted
    done_s      last token emitted

and the ledger derives the phase decomposition::

    queue_wait = submit_s - arrival_s        (frontend heap / replay delay)
    route      = route_s                     (router forward at submit)
    admit_wait = admit_s - submit_s - route_s (engine queue: expert rotation,
                                              KV backpressure, slot waits)
    prefill    = first_token_s - admit_s
    decode     = done_s - first_token_s

The five phases telescope: their sum is EXACTLY ``done_s - arrival_s``
(tests assert it to float tolerance). Two attribution fields ride along
without entering the sum — ``switch_stall_s`` (expert activation time the
request's own admission paid) and ``preemptions`` (times the frontend
pulled it back out of the engine queue) — because they explain *why*
``admit_wait``/``prefill`` grew, they are not extra wall-clock.

Aggregation: each phase lands in a ``serve.phase_seconds{phase=}`` P²
histogram (per engine, so node deployments get per-``{group=}`` series),
and the last ``keep`` per-request records stay readable for ``/debug``
and the flight-recorder bundle.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Dict, List, Optional

from repro.obs.metrics import MetricsRegistry

PHASES = ("queue_wait", "route", "admit_wait", "prefill", "decode")


def phase_record(req: Any) -> Dict[str, Any]:
    """Pure phase decomposition of one finished request (no registry).
    Requests missing a stamp (direct engine submits predate the frontend,
    a handoff carries its own prefill stamp) degrade to zero-width phases
    rather than failing — the telescoped sum stays exact."""
    arrival = req.arrival_s
    submit = getattr(req, "submit_s", None) or arrival
    route = float(getattr(req, "route_s", 0.0) or 0.0)
    admit = getattr(req, "admit_s", None) or submit
    first = req.first_token_s if req.first_token_s is not None else admit
    done = req.done_s if req.done_s is not None else first
    phases = {
        "queue_wait": submit - arrival,
        "route": route,
        "admit_wait": admit - submit - route,
        "prefill": first - admit,
        "decode": done - first,
    }
    out = len(req.output) if getattr(req, "output", None) is not None else 0
    tpot: Optional[float] = ((done - first) / (out - 1)) if out > 1 else None
    return {
        "rid": req.rid,
        "tenant": getattr(req, "tenant", "default"),
        "priority": int(getattr(req, "priority", 0)),
        "expert": req.expert,
        "tokens_out": out,
        "prefix_hit_tokens": int(getattr(req, "prefix_hit_tokens", 0)),
        "wall_s": done - arrival,
        "ttft_s": first - arrival,
        "tpot_s": tpot,
        "phases": phases,
        # attribution (not part of the telescoped sum):
        "switch_stall_s": float(getattr(req, "switch_stall_s", 0.0) or 0.0),
        "preemptions": int(getattr(req, "preemptions", 0)),
    }


class LifecycleTracker:
    """Aggregates finished requests' phase decompositions into
    ``serve.phase_seconds{phase=}`` histograms + a bounded record ring."""

    def __init__(self, registry: MetricsRegistry,
                 labels: Optional[Dict[str, Any]] = None, keep: int = 512):
        labels = dict(labels or {})
        self._hists = {
            ph: registry.histogram("serve.phase_seconds",
                                   labels={**labels, "phase": ph})
            for ph in PHASES}
        self._stall_hist = registry.histogram("serve.switch_stall_s",
                                              labels=labels)
        self._records: deque = deque(maxlen=keep)

    def complete(self, req: Any) -> Dict[str, Any]:
        """Record one finished request; returns its phase record."""
        rec = phase_record(req)
        for ph, h in self._hists.items():
            h.observe(max(0.0, rec["phases"][ph]))
        if rec["switch_stall_s"]:
            self._stall_hist.observe(rec["switch_stall_s"])
        self._records.append(rec)
        return rec

    def records(self, n: Optional[int] = None) -> List[Dict[str, Any]]:
        """Most recent ``n`` (default all retained) per-request records,
        oldest first."""
        recs = list(self._records)
        return recs if n is None else recs[-n:]
