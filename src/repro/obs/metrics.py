"""Metrics registry: counters, gauges and streaming-quantile histograms.

One ``MetricsRegistry`` is the single place every subsystem (serving engine,
switching cache, paged KV pool, node scheduler, tier ledger) publishes its
numbers. Metrics are identified by ``(name, labels)`` — labels are the
low-cardinality dimensions the paper's analysis needs (expert, socket group,
memory tier, transfer cause) — and the registry can render itself as a flat
JSON-able snapshot or Prometheus text exposition.

Histograms estimate p50/p95/p99 *without storing samples* via the P²
algorithm (Jain & Chlamtac 1985): five markers per target quantile, O(1)
memory and O(1) per observation, accurate to a few percent on the smooth
latency distributions serving produces (accuracy is asserted against exact
quantiles in ``tests/test_obs.py``).

A process-wide default registry (``get_registry``) backs components that are
not handed an explicit one; ``scoped()`` swaps it out for a fresh registry
inside a ``with`` block so tests and benchmark sweeps never see each other's
series.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

LabelsT = Tuple[Tuple[str, str], ...]


def _labels_key(labels: Optional[Dict[str, Any]]) -> LabelsT:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _flat_name(name: str, labels: LabelsT) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonically-increasing sum (int or float increments)."""

    kind = "counter"

    __slots__ = ("name", "labels", "_value", "_lock")

    def __init__(self, name: str, labels: LabelsT = ()):
        self.name = name
        self.labels = labels
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, v=1):
        if v < 0:
            raise ValueError(f"counter {self.name}: negative increment {v}")
        with self._lock:
            self._value += v

    def set(self, v):
        """Stats-view escape hatch (``stats.hits += 1`` is get-then-set);
        plain counter users should ``inc``."""
        with self._lock:
            self._value = v

    @property
    def value(self):
        return self._value


class Gauge:
    """A value that can go up and down (bytes in use, occupancy, ratios)."""

    kind = "gauge"

    __slots__ = ("name", "labels", "_value", "_fn", "_lock")

    def __init__(self, name: str, labels: LabelsT = (),
                 fn: Optional[Callable[[], float]] = None):
        self.name = name
        self.labels = labels
        self._value = 0.0
        self._fn = fn
        self._lock = threading.Lock()

    def set(self, v):
        with self._lock:
            self._value = v

    def inc(self, v=1):
        with self._lock:
            self._value += v

    def dec(self, v=1):
        self.inc(-v)

    @property
    def value(self):
        if self._fn is not None:
            return self._fn()
        return self._value


class _P2Quantile:
    """P² single-quantile estimator (Jain & Chlamtac, CACM 1985)."""

    __slots__ = ("p", "_init", "q", "n", "np_", "dn")

    def __init__(self, p: float):
        if not 0.0 < p < 1.0:
            raise ValueError(f"quantile must be in (0,1), got {p}")
        self.p = p
        self._init: List[float] = []     # first five observations
        self.q: List[float] = []         # marker heights
        self.n: List[float] = []         # marker positions (1-indexed)
        self.np_: List[float] = []       # desired positions
        self.dn = [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0]

    def observe(self, x: float):
        if len(self._init) < 5:
            self._init.append(x)
            if len(self._init) == 5:
                self._init.sort()
                self.q = list(self._init)
                self.n = [1.0, 2.0, 3.0, 4.0, 5.0]
                p = self.p
                self.np_ = [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p,
                            3.0 + 2.0 * p, 5.0]
            return
        q, n, np_ = self.q, self.n, self.np_
        if x < q[0]:
            q[0] = x
            k = 0
        elif x >= q[4]:
            q[4] = x
            k = 3
        else:
            k = 0
            while x >= q[k + 1]:
                k += 1
        for i in range(k + 1, 5):
            n[i] += 1.0
        for i in range(5):
            np_[i] += self.dn[i]
        for i in (1, 2, 3):
            d = np_[i] - n[i]
            if ((d >= 1.0 and n[i + 1] - n[i] > 1.0)
                    or (d <= -1.0 and n[i - 1] - n[i] < -1.0)):
                d = 1.0 if d > 0 else -1.0
                qp = self._parabolic(i, d)
                if not q[i - 1] < qp < q[i + 1]:
                    qp = self._linear(i, d)
                q[i] = qp
                n[i] += d

    def _parabolic(self, i: int, d: float) -> float:
        q, n = self.q, self.n
        return q[i] + d / (n[i + 1] - n[i - 1]) * (
            (n[i] - n[i - 1] + d) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
            + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))

    def _linear(self, i: int, d: float) -> float:
        q, n = self.q, self.n
        j = i + int(d)
        return q[i] + d * (q[j] - q[i]) / (n[j] - n[i])

    @property
    def value(self) -> float:
        k = len(self._init)
        if k == 0:
            return 0.0
        if k < 5 or not self.q:
            s = sorted(self._init)
            idx = min(int(self.p * k), k - 1)
            return s[idx]
        return self.q[2]


class Histogram:
    """Streaming-quantile histogram: count/sum/min/max plus one P²
    estimator per requested quantile. No samples are retained."""

    kind = "histogram"

    def __init__(self, name: str, labels: LabelsT = (),
                 quantiles: Iterable[float] = (0.5, 0.95, 0.99)):
        self.name = name
        self.labels = labels
        self.quantiles = tuple(quantiles)
        self._est = {p: _P2Quantile(p) for p in self.quantiles}
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._lock = threading.Lock()

    def observe(self, x: float):
        x = float(x)
        with self._lock:
            self.count += 1
            self.sum += x
            self.min = min(self.min, x)
            self.max = max(self.max, x)
            for est in self._est.values():
                est.observe(x)

    def quantile(self, p: float) -> float:
        return self._est[p].value

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def summary(self) -> Dict[str, float]:
        out = {"count": self.count, "sum": self.sum, "mean": self.mean}
        if self.count:
            out["min"] = self.min
            out["max"] = self.max
        for p in self.quantiles:
            out[f"p{_plabel(p)}"] = self.quantile(p)
        return out


def _plabel(p: float) -> str:
    s = f"{p * 100:g}"
    return s.replace(".", "_")


class MetricsRegistry:
    """Get-or-create metric store keyed by ``(name, sorted labels)``."""

    def __init__(self):
        self._metrics: Dict[Tuple[str, LabelsT], Any] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, cls, name: str, labels, **kwargs):
        key = (name, _labels_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, key[1], **kwargs)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, requested {cls.__name__}")
            return m

    def counter(self, name: str, labels: Optional[Dict] = None) -> Counter:
        return self._get_or_create(Counter, name, labels)

    def gauge(self, name: str, labels: Optional[Dict] = None) -> Gauge:
        return self._get_or_create(Gauge, name, labels)

    def derived_gauge(self, name: str, fn: Callable[[], float],
                      labels: Optional[Dict] = None) -> Gauge:
        """A gauge whose value is computed at read time (bandwidths,
        ratios over other metrics)."""
        g = self._get_or_create(Gauge, name, labels, fn=fn)
        g._fn = fn                     # rebinding refreshes the closure
        return g

    def histogram(self, name: str, labels: Optional[Dict] = None,
                  quantiles: Iterable[float] = (0.5, 0.95, 0.99)) -> Histogram:
        return self._get_or_create(Histogram, name, labels,
                                   quantiles=quantiles)

    def metrics(self) -> List[Any]:
        with self._lock:
            return list(self._metrics.values())

    def snapshot(self) -> Dict[str, Any]:
        """Flat ``{flat_name: value}`` dict. Histograms expand into
        ``name:count / name:sum / name:p50 ...`` entries."""
        out: Dict[str, Any] = {}
        for m in self.metrics():
            if isinstance(m, Histogram):
                for k, v in m.summary().items():
                    out[_flat_name(f"{m.name}:{k}", m.labels)] = v
            else:
                out[_flat_name(m.name, m.labels)] = m.value
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition (quantiles as ``summary`` series)."""
        def sanitize(name):
            return "".join(c if c.isalnum() or c == "_" else "_"
                           for c in name)

        def fmt_labels(labels, extra=()):
            items = list(labels) + list(extra)
            if not items:
                return ""
            return "{" + ",".join(f'{sanitize(k)}="{v}"'
                                  for k, v in items) + "}"

        lines = []
        for m in sorted(self.metrics(), key=lambda m: (m.name, m.labels)):
            name = sanitize(m.name)
            if isinstance(m, Histogram):
                lines.append(f"# TYPE {name} summary")
                for p in m.quantiles:
                    lines.append(
                        f"{name}{fmt_labels(m.labels, [('quantile', p)])} "
                        f"{m.quantile(p)}")
                lines.append(f"{name}_sum{fmt_labels(m.labels)} {m.sum}")
                lines.append(f"{name}_count{fmt_labels(m.labels)} {m.count}")
            else:
                lines.append(f"# TYPE {name} {m.kind}")
                lines.append(f"{name}{fmt_labels(m.labels)} {m.value}")
        return "\n".join(lines) + "\n"


# ----------------------------------------------------------------------
# Process-wide default registry
# ----------------------------------------------------------------------
_default = MetricsRegistry()
_default_lock = threading.Lock()


def get_registry() -> MetricsRegistry:
    """The process-wide default registry (what ``--metrics-port`` serves)."""
    return _default


def set_registry(reg: MetricsRegistry) -> MetricsRegistry:
    global _default
    with _default_lock:
        old, _default = _default, reg
    return old


@contextmanager
def scoped(reg: Optional[MetricsRegistry] = None):
    """Swap the default registry for ``reg`` (or a fresh one) inside the
    block — test/benchmark isolation without threading a registry through
    every constructor."""
    reg = reg if reg is not None else MetricsRegistry()
    old = set_registry(reg)
    try:
        yield reg
    finally:
        set_registry(old)
