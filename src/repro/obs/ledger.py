"""Tier-transfer ledger: one byte-and-latency-attributed view of every move
between memory tiers.

Before this existed, DDR->host reads, host->HBM copies and HBM reservations
were accounted three different ways (``core/switching.py`` stat fields,
``store/*`` ``StoreStats``, ad-hoc gauges in ``node/scheduler.py``). The
ledger unifies them: every transfer is recorded against a named *edge* of
the three-tier system with a *cause*, and the registry exposes

  * ``ledger.bytes{edge=,cause=}`` / ``ledger.seconds{edge=,cause=}``
    counters,
  * ``ledger.transfers{edge=,cause=}`` counts,
  * ``ledger.bandwidth_bps{edge=}`` derived gauges (bytes / seconds so far),
  * ``ledger.hbm_reserved_bytes`` — in-flight prefetch reservations against
    the HBM tier (the switching engine's over-commit guard),
  * ``ledger.stall_seconds{cause=}`` — caller-visible stall attributed per
    cause, and ``ledger.overlap_ratio``, the paper's Fig-9 claim as a
    first-class metric: the fraction of total transfer time hidden off the
    critical path.

Edges (src->dst in tier terms):
    ``store_read``  DDR/disk capacity tier -> host staging (store ``get``)
    ``h2d``         host -> HBM (``device_put``)
    ``writeback``   HBM -> capacity tier (dirty mutable state on evict/drop)
    ``elided``      a copy the runtime proved unnecessary (read-only
                    weights skipping writeback — bytes only, zero seconds)
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Optional

from repro.obs.metrics import MetricsRegistry

EDGES = ("store_read", "h2d", "writeback", "elided")

_EDGE_TIERS = {
    "store_read": ("ddr", "host"),
    "h2d": ("host", "hbm"),
    "writeback": ("hbm", "ddr"),
    "elided": ("hbm", "ddr"),
}


class TransferLedger:
    """Byte + latency accounting for tier transfers, over a registry."""

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 labels: Optional[Dict[str, Any]] = None):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.labels = dict(labels or {})
        self._lock = threading.Lock()
        self._bytes: Dict[str, float] = {e: 0.0 for e in EDGES}
        self._seconds: Dict[str, float] = {e: 0.0 for e in EDGES}
        self._reserved = self.registry.gauge("ledger.hbm_reserved_bytes",
                                             self.labels)
        for edge in EDGES:
            self.registry.derived_gauge(
                "ledger.bandwidth_bps", self._bw_fn(edge),
                {**self.labels, "edge": edge})
        self.registry.derived_gauge("ledger.overlap_ratio",
                                    lambda: self.overlap_ratio, self.labels)

    def _bw_fn(self, edge: str):
        def fn():
            s = self._seconds[edge]
            return self._bytes[edge] / s if s > 0 else 0.0
        return fn

    def _labeled(self, edge: str, cause: Optional[str]):
        lbl = dict(self.labels)
        lbl["edge"] = edge
        if cause:
            lbl["cause"] = cause
        return lbl

    # -- recording -----------------------------------------------------
    def record(self, edge: str, nbytes: int, seconds: float = 0.0, *,
               cause: Optional[str] = None, expert: Optional[str] = None):
        """Account one transfer on ``edge``: ``nbytes`` moved in
        ``seconds`` (as measured where the copy ran — worker-side for the
        prefetch pipeline). ``cause`` attributes it (prefetch / miss /
        failed_prefetch / writeback...); ``expert`` adds a per-expert bytes
        series."""
        if edge not in EDGES:
            raise ValueError(f"unknown ledger edge {edge!r} (not in {EDGES})")
        lbl = self._labeled(edge, cause)
        reg = self.registry
        reg.counter("ledger.bytes", lbl).inc(nbytes)
        reg.counter("ledger.seconds", lbl).inc(seconds)
        reg.counter("ledger.transfers", lbl).inc()
        if seconds > 0:
            reg.histogram("ledger.transfer_s",
                          {**self.labels, "edge": edge}).observe(seconds)
        if expert is not None:
            reg.counter("ledger.bytes_by_expert",
                        {**self.labels, "expert": expert}).inc(nbytes)
        with self._lock:
            self._bytes[edge] += nbytes
            self._seconds[edge] += seconds

    def note_stall(self, seconds: float, *, cause: str):
        """Caller-visible stall time (what the serving thread actually
        waited), attributed per cause. The gap between total transfer
        seconds and stall seconds is what prefetch hid."""
        self.registry.counter(
            "ledger.stall_seconds",
            {**self.labels, "cause": cause}).inc(seconds)
        self.registry.histogram(
            "ledger.stall_s", {**self.labels, "cause": cause}).observe(seconds)

    def reserve(self, nbytes: int):
        """HBM bytes promised to an in-flight load (prefetch issue)."""
        self._reserved.inc(nbytes)

    def release(self, nbytes: int):
        """Reservation resolved: the load landed, failed or was cancelled."""
        self._reserved.dec(nbytes)

    # -- derived views ---------------------------------------------------
    def bytes_moved(self, edge: str) -> int:
        return int(self._bytes[edge])

    def seconds(self, edge: str) -> float:
        return self._seconds[edge]

    def bandwidth_bps(self, edge: str) -> float:
        return self._bw_fn(edge)()

    @property
    def reserved_bytes(self) -> int:
        return int(self._reserved.value)

    @property
    def copy_seconds(self) -> float:
        """End-to-end inbound load time (store read + H2D)."""
        return self._seconds["store_read"] + self._seconds["h2d"]

    @property
    def stall_seconds(self) -> float:
        total = 0.0
        for m in self.registry.metrics():
            if m.name == "ledger.stall_seconds":
                lbl = dict(m.labels)
                if all(lbl.get(k) == str(v) for k, v in self.labels.items()):
                    total += m.value
        return total

    @property
    def overlap_ratio(self) -> float:
        """Fraction of inbound transfer time hidden from the caller
        (clamped to [0, 1]: stall includes bookkeeping the worker-side
        phase timers don't see)."""
        total = self.copy_seconds
        if total <= 0:
            return 0.0
        return max(0.0, min(1.0, 1.0 - self.stall_seconds / total))

    def as_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        for edge in EDGES:
            out[f"{edge}_bytes"] = self.bytes_moved(edge)
            out[f"{edge}_seconds"] = self.seconds(edge)
            out[f"{edge}_bandwidth_bps"] = self.bandwidth_bps(edge)
        out["hbm_reserved_bytes"] = self.reserved_bytes
        out["stall_seconds"] = self.stall_seconds
        out["overlap_ratio"] = self.overlap_ratio
        return out
