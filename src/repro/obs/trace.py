"""Span tracing with Chrome-trace / Perfetto JSON export.

``span("prefill", request_id=3)`` is a context manager that records one
complete event (begin/end/attrs) into a per-thread ring buffer; ``instant``
records a point event (placement decisions, request admission);
``async_begin``/``async_end`` bracket one request's whole lifecycle across
scheduler steps (they need not nest and may even end on another thread).
Buffers are per-thread so the switching cache's prefetch workers and the
engine's caller thread never contend on a lock in the record path; ring
semantics bound memory on long runs (oldest events drop first). Drops are
COUNTED per ring — ``Tracer.dropped_events`` totals them, the default
registry exposes them as the ``trace.dropped_events`` gauge
(``register_metrics``), and every Chrome-trace export stamps the total
into its ``metadata`` so a truncated timeline is never mistaken for a
complete one.

Tracing is OFF by default and the disabled path is allocation-free:
``span()`` returns a module-level no-op singleton, so the engine can leave
trace calls on the per-step decode hot path (asserted by
``tests/test_obs.py``).

``export(path)`` writes the Chrome trace-event format that Perfetto
(https://ui.perfetto.dev) and ``chrome://tracing`` open directly:
``{"traceEvents": [{"name", "ph", "ts", "dur", "pid", "tid", "args"}]}``
with timestamps in microseconds since the tracer was enabled.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from pathlib import Path
from typing import Any, Dict, List, Optional


class Span:
    """A live span: ``with`` records one complete ("X") event on exit.
    ``add(**attrs)`` attaches attrs discovered mid-span (outcome, bytes)."""

    __slots__ = ("_tracer", "name", "cat", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args

    def add(self, **attrs):
        self.args.update(attrs)
        return self

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        tr = self._tracer
        tr._record({"name": self.name, "cat": self.cat, "ph": "X",
                    "ts": tr._us(self._t0), "dur": (t1 - self._t0) * 1e6,
                    "args": self.args})
        return False


class _NoopSpan:
    """Shared do-nothing span for the disabled path — one instance per
    process, so disabled tracing allocates nothing per call."""

    __slots__ = ()

    def add(self, **attrs):
        return self

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NOOP_SPAN = _NoopSpan()


class _Ring:
    """One thread's event ring. Only the owning thread appends, so the
    bounded-append drop count needs no lock; readers snapshot under the
    tracer lock like before."""

    __slots__ = ("events", "maxlen", "dropped")

    def __init__(self, maxlen: int):
        self.events: deque = deque(maxlen=maxlen)
        self.maxlen = maxlen
        self.dropped = 0

    def append(self, ev: Dict[str, Any]):
        if len(self.events) == self.maxlen:
            self.dropped += 1            # deque(maxlen) evicts the oldest
        self.events.append(ev)

    def clear(self):
        self.events.clear()
        self.dropped = 0


class Tracer:
    """Per-thread ring buffers of Chrome trace events."""

    def __init__(self, buffer_size: int = 1 << 16):
        self.buffer_size = buffer_size
        self.enabled = False
        self._pid = os.getpid()
        self._epoch = time.perf_counter()
        self._local = threading.local()
        # list of (tid, ring), not a dict keyed by tid: thread idents are
        # reused after a thread exits, and a dict would silently drop a
        # dead thread's events when a new thread inherits its ident
        self._rings: List[tuple] = []
        self._thread_names: Dict[int, str] = {}
        self._lock = threading.Lock()

    # -- recording -----------------------------------------------------
    def _us(self, t: float) -> float:
        return (t - self._epoch) * 1e6

    def _ring(self) -> _Ring:
        ring = getattr(self._local, "ring", None)
        if ring is None:
            tid = threading.get_ident()
            ring = _Ring(self.buffer_size)
            with self._lock:
                self._rings.append((tid, ring))
                self._thread_names[tid] = threading.current_thread().name
            self._local.ring = ring
        return ring

    def _record(self, ev: Dict[str, Any]):
        if not self.enabled:
            return
        ev.setdefault("pid", self._pid)
        ev.setdefault("tid", threading.get_ident())
        self._ring().append(ev)

    def span(self, name: str, cat: str = "repro", **attrs):
        if not self.enabled:
            return NOOP_SPAN
        return Span(self, name, cat, attrs)

    def instant(self, name: str, cat: str = "repro", **attrs):
        if not self.enabled:
            return
        self._record({"name": name, "cat": cat, "ph": "i",
                      "ts": self._us(time.perf_counter()), "s": "t",
                      "args": attrs})

    def async_begin(self, name: str, id: int, cat: str = "repro", **attrs):
        """Open one lane of a non-nesting flow (e.g. a request's admit->done
        lifecycle). Pair with ``async_end`` on the same (name, id)."""
        if not self.enabled:
            return
        self._record({"name": name, "cat": cat, "ph": "b", "id": int(id),
                      "ts": self._us(time.perf_counter()), "args": attrs})

    def async_end(self, name: str, id: int, cat: str = "repro", **attrs):
        if not self.enabled:
            return
        self._record({"name": name, "cat": cat, "ph": "e", "id": int(id),
                      "ts": self._us(time.perf_counter()), "args": attrs})

    # -- lifecycle -----------------------------------------------------
    def start(self, *, reset: bool = True):
        if reset:
            self.clear()
        self.enabled = True

    def stop(self):
        self.enabled = False

    def clear(self):
        with self._lock:
            rings = list(self._rings)
        for _, r in rings:
            r.clear()
        self._epoch = time.perf_counter()

    @property
    def dropped_events(self) -> int:
        """Events lost to ring overflow across all threads (since the last
        ``clear``)."""
        with self._lock:
            rings = list(self._rings)
        return sum(r.dropped for _, r in rings)

    # -- export --------------------------------------------------------
    def events(self) -> List[Dict[str, Any]]:
        """All recorded events, oldest first across threads."""
        with self._lock:
            rings = list(self._rings)
        evs: List[Dict[str, Any]] = []
        for _, ring in rings:
            evs.extend(list(ring.events))
        evs.sort(key=lambda e: e.get("ts", 0.0))
        return evs

    def to_chrome_trace(self) -> Dict[str, Any]:
        evs = self.events()
        with self._lock:
            names = dict(self._thread_names)
        meta = [{"name": "thread_name", "ph": "M", "pid": self._pid,
                 "tid": tid, "args": {"name": tname}}
                for tid, tname in sorted(names.items())]
        # Perfetto ignores unknown top-level keys; readers of the exported
        # document can tell a truncated timeline from a complete one
        return {"traceEvents": meta + evs, "displayTimeUnit": "ms",
                "metadata": {"trace.dropped_events": self.dropped_events}}

    def export(self, path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_chrome_trace()))
        return path


# ----------------------------------------------------------------------
# Process-wide default tracer (module-level API all call sites use)
# ----------------------------------------------------------------------
_tracer = Tracer()


def get_tracer() -> Tracer:
    return _tracer


def set_tracer(tracer: Tracer) -> Tracer:
    global _tracer
    old, _tracer = _tracer, tracer
    return old


def enabled() -> bool:
    return _tracer.enabled


def enable(*, reset: bool = True):
    _tracer.start(reset=reset)


def disable():
    _tracer.stop()


def span(name: str, cat: str = "repro", **attrs):
    t = _tracer
    if not t.enabled:
        return NOOP_SPAN
    return Span(t, name, cat, attrs)


def instant(name: str, cat: str = "repro", **attrs):
    _tracer.instant(name, cat, **attrs)


def async_begin(name: str, id: int, cat: str = "repro", **attrs):
    _tracer.async_begin(name, id, cat, **attrs)


def async_end(name: str, id: int, cat: str = "repro", **attrs):
    _tracer.async_end(name, id, cat, **attrs)


def export(path) -> Path:
    return _tracer.export(path)


def events() -> List[Dict[str, Any]]:
    return _tracer.events()


def dropped_events() -> int:
    return _tracer.dropped_events


def register_metrics(registry) -> None:
    """Expose the default tracer's overflow count as the
    ``trace.dropped_events`` gauge on ``registry`` (reads through
    ``set_tracer`` swaps). Idempotent — re-registering returns the same
    series."""
    registry.derived_gauge("trace.dropped_events",
                           lambda: float(_tracer.dropped_events))


def validate_chrome_trace(doc: Dict[str, Any]) -> List[str]:
    """Schema check for an exported trace document; returns a list of
    problems (empty = valid). Used by tests and the bench harness."""
    problems = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["missing top-level 'traceEvents'"]
    open_async: Dict[Any, int] = {}
    for i, ev in enumerate(doc["traceEvents"]):
        for req in ("name", "ph", "pid", "tid"):
            if req not in ev:
                problems.append(f"event {i}: missing {req!r}")
        ph = ev.get("ph")
        if ph not in ("X", "i", "b", "e", "M"):
            problems.append(f"event {i}: unknown phase {ph!r}")
            continue
        if ph != "M" and "ts" not in ev:
            problems.append(f"event {i}: missing ts")
        if ph == "X" and not isinstance(ev.get("dur"), (int, float)):
            problems.append(f"event {i}: complete event without dur")
        if ph in ("b", "e"):
            key = (ev.get("name"), ev.get("id"))
            open_async[key] = open_async.get(key, 0) + (1 if ph == "b" else -1)
            if open_async[key] < 0:
                problems.append(f"event {i}: async end before begin {key}")
    for key, n in open_async.items():
        if n > 0:
            problems.append(f"unclosed async span {key}")
    return problems
