"""Unified observability layer: metrics registry, span tracing, tier ledger.

The paper's claims are measurement claims (Fig 1 switch/execute split,
Fig 9 prefetch overlap, Fig 12-13 switching/footprint curves); this package
is where the repro attributes every millisecond and byte:

  * ``obs.metrics``  — ``MetricsRegistry``: counters / gauges / streaming-
    quantile histograms, labeled (expert, socket group, tier), with a
    process default registry and ``scoped()`` test isolation;
  * ``obs.trace``    — ``span()`` context managers recording into per-thread
    ring buffers, exported as Chrome-trace / Perfetto JSON;
  * ``obs.ledger``   — ``TransferLedger``: every DDR->host / host->HBM /
    writeback transfer byte-and-latency-attributed on one view, with
    derived bandwidth gauges and the overlap ratio first-class;
  * ``obs.stats``    — the registry-backed view machinery behind
    ``ServeStats`` / ``SwitchStats`` / ``NodeStats`` / ``PagedStats`` and
    the shared ``as_dict`` serializer;
  * ``obs.httpd``    — the ``--metrics-port`` Prometheus/JSON endpoint.

See ``docs/observability.md`` for the metric catalog and span taxonomy.
"""
from repro.obs import trace
from repro.obs.httpd import MetricsServer, serve_metrics
from repro.obs.ledger import TransferLedger
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               get_registry, scoped, set_registry)
from repro.obs.stats import (StatsView, as_dict, counter_field, gauge_field,
                             stat_field)

__all__ = [
    "trace",
    "MetricsServer", "serve_metrics",
    "TransferLedger",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "get_registry", "scoped", "set_registry",
    "StatsView", "as_dict", "counter_field", "gauge_field", "stat_field",
]
