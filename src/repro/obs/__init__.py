"""Unified observability layer: metrics, tracing, ledger, lifecycle plane.

The paper's claims are measurement claims (Fig 1 switch/execute split,
Fig 9 prefetch overlap, Fig 12-13 switching/footprint curves); this package
is where the repro attributes every millisecond and byte:

  * ``obs.metrics``  — ``MetricsRegistry``: counters / gauges / streaming-
    quantile histograms, labeled (expert, socket group, tier), with a
    process default registry and ``scoped()`` test isolation;
  * ``obs.trace``    — ``span()`` context managers recording into per-thread
    ring buffers, exported as Chrome-trace / Perfetto JSON (overflow drops
    counted and stamped into the export);
  * ``obs.ledger``   — ``TransferLedger``: every DDR->host / host->HBM /
    writeback transfer byte-and-latency-attributed on one view, with
    derived bandwidth gauges and the overlap ratio first-class;
  * ``obs.stats``    — the registry-backed view machinery behind
    ``ServeStats`` / ``SwitchStats`` / ``NodeStats`` / ``PagedStats`` and
    the shared ``as_dict`` serializer;
  * ``obs.lifecycle`` — per-request phase ledger (queue_wait / route /
    admit_wait / prefill / decode) aggregated into
    ``serve.phase_seconds{phase=}`` histograms;
  * ``obs.slo``      — TTFT+TPOT SLO attainment, goodput (SLO-met tokens/s)
    and burn-rate windows per tenant/priority;
  * ``obs.watchdog`` — background invariant sampler (stuck requests, KV
    refcount leaks, HBM budget, queue age) feeding ``obs.anomaly{kind=}``;
  * ``obs.flightrec`` — black-box event ring whose ``dump()`` writes a JSON
    postmortem bundle (SIGUSR2 / watchdog / ``/debug/flight``);
  * ``obs.httpd``    — the ``--metrics-port`` Prometheus/JSON endpoint plus
    ``/readyz`` and the ``/debug/*`` state snapshots.

See ``docs/observability.md`` for the metric catalog, span taxonomy, phase
taxonomy, and the postmortem walkthrough.
"""
from repro.obs import flightrec, trace
from repro.obs.flightrec import FlightRecorder, validate_bundle
from repro.obs.httpd import MetricsServer, serve_metrics
from repro.obs.ledger import TransferLedger
from repro.obs.lifecycle import LifecycleTracker, phase_record
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               get_registry, scoped, set_registry)
from repro.obs.slo import SLOTracker, request_slo_met
from repro.obs.stats import (StatsView, as_dict, counter_field, gauge_field,
                             stat_field)
from repro.obs.watchdog import Watchdog, WatchdogError

# the default registry always carries the tracer's overflow count
trace.register_metrics(get_registry())

__all__ = [
    "trace", "flightrec",
    "FlightRecorder", "validate_bundle",
    "MetricsServer", "serve_metrics",
    "TransferLedger",
    "LifecycleTracker", "phase_record",
    "SLOTracker", "request_slo_met",
    "Watchdog", "WatchdogError",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "get_registry", "scoped", "set_registry",
    "StatsView", "as_dict", "counter_field", "gauge_field", "stat_field",
]
