from repro.data.pipeline import DataConfig, SyntheticLM, MemmapTokens, make_source, iterate

__all__ = ["DataConfig", "SyntheticLM", "MemmapTokens", "make_source", "iterate"]
