"""Deterministic, resumable data pipeline.

Two sources:
  * ``SyntheticLM`` — stateless PRNG token stream: batch(step) is a pure
    function of (seed, step), so restart-at-step-k is exact (fault
    tolerance / elasticity: any host can reproduce any shard of any step).
  * ``MemmapTokens`` — file-backed token corpus (np.memmap), sharded by
    (host, step) with the same pure-function indexing.

Both emit the global batch; the launcher slices the per-host shard via the
mesh's addressable devices (data parallel dimension).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    corpus_path: Optional[str] = None      # None -> synthetic


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rs = np.random.Generator(np.random.Philox(key=c.seed, counter=step))
        toks = rs.integers(0, c.vocab_size, (c.global_batch, c.seq_len + 1),
                           dtype=np.int64).astype(np.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def shard_at(self, step: int, shard: int, n_shards: int):
        b = self.batch_at(step)
        rows = self.cfg.global_batch // n_shards
        sl = slice(shard * rows, (shard + 1) * rows)
        return {k: v[sl] for k, v in b.items()}


class MemmapTokens:
    """Token file (int32 flat) chunked into (seq_len+1) windows, strided by a
    step-indexed permutation so resume is exact."""

    def __init__(self, cfg: DataConfig):
        assert cfg.corpus_path
        self.cfg = cfg
        self.data = np.memmap(cfg.corpus_path, dtype=np.int32, mode="r")
        self.n_windows = (len(self.data) - 1) // cfg.seq_len

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rs = np.random.Generator(np.random.Philox(key=c.seed ^ 0xDA7A,
                                                  counter=step))
        idx = rs.integers(0, self.n_windows, (c.global_batch,))
        toks = np.stack([
            np.asarray(self.data[i * c.seq_len:(i * c.seq_len) + c.seq_len + 1])
            for i in idx])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "targets": toks[:, 1:].astype(np.int32)}


def make_source(cfg: DataConfig):
    if cfg.corpus_path:
        return MemmapTokens(cfg)
    return SyntheticLM(cfg)


def iterate(source, start_step: int = 0) -> Iterator:
    step = start_step
    while True:
        yield step, source.batch_at(step)
        step += 1
