"""Speculative decoding for CoE serving (paper §VI-B: employed on the 70B
and 405B Llama 3.1 deployments, Table IV).

Greedy draft-verify: a cheap draft expert proposes ``gamma`` tokens
autoregressively; the target expert scores all of them in ONE parallel
``extend_step`` against its KV cache; the longest matching prefix is
accepted plus one corrected token from the target distribution. With greedy
(argmax) decoding the output is provably IDENTICAL to the target model's own
greedy decode — the test suite asserts this token-for-token.

In a CoE this is a natural fit: the composition already hosts many models,
so a small general expert doubles as the draft for larger specialists, and
the three-tier switching engine keeps both resident in HBM.

This module is the standalone, dense-cache REFERENCE implementation (one
request batch, its own prefill/extend). Production serving uses
``engine.SpeculativeDecode`` — the same draft-verify algorithm as a decode
policy on the continuous-batching engine's paged slot machinery — and the
test suite asserts both match the target's greedy decode token-for-token.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import get_model


def extend_step(cfg: ModelConfig, params, cache, tokens, pos):
    """Multi-token cache-attending step: tokens (B,g) at positions
    pos..pos+g-1. Returns (logits (B,g,V), cache). Dense/moe families."""
    from repro.models import layers as L
    from repro.models import transformer as T
    assert cfg.family in ("dense", "moe"), "spec-dec verify: dense/moe only"
    B, g = tokens.shape
    h = T.embed_tokens(cfg, params, tokens)
    positions = pos + jnp.arange(g, dtype=jnp.int32)[None]
    positions = jnp.broadcast_to(positions, (B, g))
    S = cache["k"].shape[2]
    W = cfg.sliding_window
    moe = cfg.n_experts > 0

    def body(hh, xs):
        lp, kc, vc = xs
        p = lp["attn"]
        hn = L.apply_norm(cfg, p["norm"], hh)
        q = jnp.einsum("bsd,dhk->bshk", hn, p["wq"])
        k = jnp.einsum("bsd,dhk->bshk", hn, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", hn, p["wv"])
        if cfg.qkv_bias:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
        q = L.apply_rope(cfg, q, positions)
        k = L.apply_rope(cfg, k, positions)
        idx = jnp.mod(pos, S) if W else pos
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, idx, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, idx, 1)
        # verify attention: γ queries at offset pos against the whole cache
        o = L.naive_attention(q, kc, vc, causal=True, q_offset=pos)
        y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        if cfg.attn_out_bias:
            y = y + p["bo"]
        hh = hh + y
        hh = T._mlp(cfg, lp["mlp_norm"], lp["mlp"], hh, moe)
        return hh, (kc, vc)

    h, (kc, vc) = jax.lax.scan(body, h, (params["layers"], cache["k"],
                                         cache["v"]))
    cache = dict(cache, k=kc, v=vc)
    h = L.apply_norm(cfg, params["final_norm"], h)
    logits = T.unembed(cfg, params, h)
    return logits, cache


@dataclass
class SpecStats:
    proposed: int = 0
    accepted: int = 0
    target_calls: int = 0
    draft_calls: int = 0

    @property
    def acceptance_rate(self):
        return self.accepted / max(self.proposed, 1)

    @property
    def tokens_per_target_call(self):
        return (self.accepted + self.target_calls) / max(self.target_calls, 1)


class SpeculativeDecoder:
    """Greedy speculative decoding: draft proposes, target verifies."""

    def __init__(self, target_cfg: ModelConfig, draft_cfg: ModelConfig,
                 gamma: int = 4):
        assert target_cfg.vocab_size == draft_cfg.vocab_size
        self.t_cfg, self.d_cfg = target_cfg, draft_cfg
        self.t_model = get_model(target_cfg)
        self.d_model = get_model(draft_cfg)
        self.gamma = gamma
        self.stats = SpecStats()

    def generate(self, t_params, d_params, prompt: np.ndarray,
                 n_tokens: int) -> np.ndarray:
        """prompt (B,S) -> (B, n_tokens). Greedy; B=1 fast path semantics
        (per-row acceptance lengths are tracked independently)."""
        B, S = prompt.shape
        max_len = S + n_tokens + self.gamma + 2
        jp = jnp.asarray(prompt)
        t_last, t_cache = self.t_model.prefill(t_params, {"tokens": jp},
                                               max_len)
        d_last, d_cache = self.d_model.prefill(d_params, {"tokens": jp},
                                               max_len)
        out = np.zeros((B, n_tokens), np.int32)
        n_done = 0
        cur = jnp.argmax(t_last, -1).astype(jnp.int32)    # token at pos S
        out[:, 0] = np.asarray(cur)
        n_done = 1
        pos = S                                            # next write pos

        while n_done < n_tokens:
            g = min(self.gamma, n_tokens - n_done)
            # --- draft proposes g tokens autoregressively
            d_tokens = [cur]
            dc = d_cache
            for i in range(g):
                lg, dc = self.d_model.decode_step(
                    d_params, dc, d_tokens[-1][:, None], jnp.int32(pos + i))
                d_tokens.append(jnp.argmax(lg, -1).astype(jnp.int32))
                self.stats.draft_calls += 1
            prop = jnp.stack(d_tokens[:-1], axis=1)        # (B,g) inputs
            draft_next = jnp.stack(d_tokens[1:], axis=1)   # (B,g) proposals

            # --- target verifies all g in one parallel pass
            t_logits, t_cache = extend_step(self.t_cfg, t_params, t_cache,
                                            prop, jnp.int32(pos))
            self.stats.target_calls += 1
            t_next = jnp.argmax(t_logits, -1).astype(jnp.int32)  # (B,g)

            match = np.asarray(draft_next == t_next)       # (B,g)
            # accepted length = longest all-match prefix (per batch row);
            # batch-synchronous serving uses the min across rows
            prefix = 0
            for i in range(g):
                if match[:, i].all():
                    prefix += 1
                else:
                    break
            self.stats.proposed += g
            self.stats.accepted += prefix
            # emit accepted tokens + (if a mismatch occurred) the target's
            # correction; all-accepted rounds emit exactly g tokens
            emit = np.asarray(t_next[:, :min(prefix + 1, g)])
            emit = emit[:, : n_tokens - n_done]
            out[:, n_done:n_done + emit.shape[1]] = emit
            n_done += emit.shape[1]
            cur = jnp.asarray(emit[:, -1])
            pos = pos + emit.shape[1]
            # re-sync the draft cache to the accepted position: replay the
            # accepted tokens it hasn't ingested (stale suffix is masked by
            # pos, so only the pointer matters; ingest the last token)
            d_cache = dc
        return out[:, :n_tokens]
