"""Paged KV-cache pool for concurrent CoE serving.

The HBM tier holds three competing populations: expert weights (LRU cache,
core/switching.py), the router, and per-request KV caches. A paged pool
(vLLM-style block tables) bounds the KV population: requests allocate
fixed-size blocks on demand, free them on completion, and fragmentation is
impossible by construction. The pool's byte budget plugs into the same
three-tier accounting the expert cache uses, so the CoE runtime can trade
resident experts against concurrent requests explicitly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class PagedStats:
    allocs: int = 0
    frees: int = 0
    blocks_in_use: int = 0
    peak_blocks: int = 0


class PagedKVCache:
    """Block-paged K/V pool. Layout: (n_blocks, block, kv_heads, head_dim)."""

    def __init__(self, n_blocks: int, block_size: int, n_layers: int,
                 kv_heads: int, head_dim: int, dtype=jnp.bfloat16):
        self.n_blocks = n_blocks
        self.block = block_size
        self.k = jnp.zeros((n_layers, n_blocks, block_size, kv_heads, head_dim),
                           dtype)
        self.v = jnp.zeros_like(self.k)
        self._free: List[int] = list(range(n_blocks))[::-1]
        self._tables: Dict[int, List[int]] = {}
        self._lengths: Dict[int, int] = {}
        self.stats = PagedStats()

    # -- bookkeeping -------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def bytes_in_use(self) -> int:
        per_block = int(np.prod(self.k.shape[2:])) * self.k.dtype.itemsize * 2
        return self.stats.blocks_in_use * per_block * self.k.shape[0]

    def table(self, rid: int) -> List[int]:
        return list(self._tables[rid])

    def length(self, rid: int) -> int:
        return self._lengths[rid]

    # -- allocation ---------------------------------------------------------
    def open(self, rid: int):
        if rid in self._tables:
            raise KeyError(f"request {rid} already open")
        self._tables[rid] = []
        self._lengths[rid] = 0

    def _ensure(self, rid: int, n_tokens: int):
        need_blocks = -(-(self._lengths[rid] + n_tokens) // self.block)
        while len(self._tables[rid]) < need_blocks:
            if not self._free:
                raise MemoryError("KV pool exhausted")
            self._tables[rid].append(self._free.pop())
            self.stats.allocs += 1
            self.stats.blocks_in_use += 1
            self.stats.peak_blocks = max(self.stats.peak_blocks,
                                         self.stats.blocks_in_use)

    def append(self, rid: int, k_new, v_new):
        """k_new/v_new (L, n_tokens, kv_heads, head_dim) for one request."""
        L, n, H, dh = k_new.shape
        self._ensure(rid, n)
        start = self._lengths[rid]
        for i in range(n):                       # token-granular placement
            tok = start + i
            blk = self._tables[rid][tok // self.block]
            off = tok % self.block
            self.k = self.k.at[:, blk, off].set(k_new[:, i])
            self.v = self.v.at[:, blk, off].set(v_new[:, i])
        self._lengths[rid] = start + n

    def gather(self, rid: int):
        """Contiguous (L, len, kv_heads, head_dim) view for attention."""
        tbl = jnp.asarray(self._tables[rid], jnp.int32)
        k = self.k[:, tbl].reshape(self.k.shape[0], -1, *self.k.shape[3:])
        v = self.v[:, tbl].reshape(self.v.shape[0], -1, *self.v.shape[3:])
        n = self._lengths[rid]
        return k[:, :n], v[:, :n]

    def free(self, rid: int):
        for blk in self._tables.pop(rid):
            self._free.append(blk)
            self.stats.frees += 1
            self.stats.blocks_in_use -= 1
        del self._lengths[rid]
