"""Paged KV-cache pool for concurrent CoE serving.

The HBM tier holds three competing populations: expert weights (LRU cache,
core/switching.py), the router, and per-request KV caches. A paged pool
(vLLM-style block tables) bounds the KV population: requests allocate
fixed-size blocks on demand, free them on completion, and fragmentation is
impossible by construction. The pool's byte budget plugs into the same
three-tier accounting the expert cache uses (``core.memory_tiers.HBMBudget``),
so the CoE runtime can trade resident experts against concurrent requests
explicitly.

This pool is the ONLY KV storage of ``serving.engine.ServingEngine``: every
decode slot is a block table here. Two access paths coexist:

  * host path — ``open/append/gather/free`` (prefill writes, reference
    reads, recycling);
  * device path — the engine's jitted paged decode step scatters new K/V
    directly into ``self.k/self.v`` and the engine commits the updated
    arrays plus ``advance``d lengths afterwards. ``reserve`` must have been
    called first so the block table covers the written positions.

With ``scratch=True`` the pool carries one extra block (index
``scratch_index``) that is never allocated to a request: inactive decode
lanes scatter there so a single compiled step can serve any slot subset.

**Copy-on-write prefix sharing.** Every allocated block carries a refcount:
one reference per block table that contains it plus one per ``PrefixIndex``
entry that indexes it. ``open(rid, adopt=...)`` seats a request on blocks
another request (or the index) already owns — the shared prefix is never
re-prefilled and never duplicated, the paper's never-copy-hot-bytes
principle (§IV) applied to the KV tier. Shared blocks are read-only by
convention: before any write lands in a block with refcount > 1 (a partially
filled adopted tail), ``_make_tail_writable`` COW-splits it — a fresh block
is allocated, the shared rows are copied device-side, and the table entry is
swapped, so no writer can ever mutate bytes another reader attends over.
``free`` only releases blocks whose refcount reaches zero.

When the free list runs dry the pool asks its registered *reclaimers*
(session retention, the prefix index — both hold blocks speculatively) to
give blocks back before raising ``MemoryError`` — KV pages compete for the
HBM tier exactly like expert weights compete in the LRU weight cache.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import flightrec
from repro.obs.metrics import MetricsRegistry
from repro.obs.stats import StatsView, counter_field, gauge_field


class PagedStats(StatsView):
    """KV-pool counters as a view over the metrics registry (``kv.*``
    series). Same serialization surface as ``SwitchStats`` — benchmark
    JSON rows embed both. ``shared_blocks`` gauges how many physical
    blocks currently back more than one reference (the dedup win);
    ``cow_splits`` counts copy-on-write block splits."""

    PREFIX = "kv"

    allocs = counter_field()
    frees = counter_field()
    blocks_in_use = gauge_field()
    peak_blocks = gauge_field()
    shared_blocks = gauge_field()
    cow_splits = counter_field()


class PagedKVCache:
    """Block-paged K/V pool. Layout: (n_blocks, block, kv_heads, head_dim)."""

    def __init__(self, n_blocks: int, block_size: int, n_layers: int,
                 kv_heads: int, head_dim: int, dtype=jnp.bfloat16,
                 scratch: bool = False,
                 registry: Optional[MetricsRegistry] = None,
                 labels: Optional[Dict[str, Any]] = None):
        self.n_blocks = n_blocks
        self.block = block_size
        rows = n_blocks + (1 if scratch else 0)
        self.k = jnp.zeros((n_layers, rows, block_size, kv_heads, head_dim),
                           dtype)
        self.v = jnp.zeros_like(self.k)
        self.scratch_index: Optional[int] = n_blocks if scratch else None
        self._free: List[int] = list(range(n_blocks))[::-1]
        self._tables: Dict[int, List[int]] = {}
        self._lengths: Dict[int, int] = {}
        self._refs: Dict[int, int] = {}       # block -> reference count
        # objects with reclaim(need_blocks)->int / reclaimable()->int that
        # hold blocks speculatively (SessionManager, PrefixIndex) and can
        # give them back under pool pressure, in registration order
        self._reclaimers: List[Any] = []
        # monotonic versions of the host bookkeeping, so device-copy caches
        # (engine._DeviceTableCache) can skip re-uploading unchanged
        # tables/lengths every decode round
        self.table_version = 0        # bumped when any block table changes
        self.length_version = 0       # bumped when any length changes
        self.stats = PagedStats(registry=registry, labels=labels)

    # -- sizing ------------------------------------------------------------
    @staticmethod
    def block_bytes(block_size: int, n_layers: int, kv_heads: int,
                    head_dim: int, dtype=jnp.bfloat16) -> int:
        """Bytes of one K+V block across all layers."""
        itemsize = jnp.dtype(dtype).itemsize
        return 2 * n_layers * block_size * kv_heads * head_dim * itemsize

    @classmethod
    def for_budget(cls, budget_bytes: int, block_size: int, n_layers: int,
                   kv_heads: int, head_dim: int, dtype=jnp.bfloat16,
                   scratch: bool = False,
                   registry: Optional[MetricsRegistry] = None,
                   labels: Optional[Dict[str, Any]] = None) -> "PagedKVCache":
        """Largest pool whose K+V arrays fit in ``budget_bytes`` (the KV share
        of the HBM tier from ``core.memory_tiers.plan_hbm_budget``). The
        scratch row, when requested, counts against the budget."""
        per = cls.block_bytes(block_size, n_layers, kv_heads, head_dim, dtype)
        n_blocks = int(budget_bytes // per) - (1 if scratch else 0)
        if n_blocks < 1:
            raise MemoryError(
                f"KV budget {budget_bytes} bytes < "
                f"{'scratch + ' if scratch else ''}one block ({per} bytes)")
        return cls(n_blocks, block_size, n_layers, kv_heads, head_dim,
                   dtype, scratch=scratch, registry=registry, labels=labels)

    # -- bookkeeping -------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def _per_block_bytes(self) -> int:
        L, _, blk, H, dh = self.k.shape
        return self.block_bytes(blk, L, H, dh, self.k.dtype)

    def capacity_bytes(self) -> int:
        """Bytes of the allocatable blocks (scratch row excluded)."""
        return self.n_blocks * self._per_block_bytes()

    def bytes_in_use(self) -> int:
        return self.stats.blocks_in_use * self._per_block_bytes()

    def table(self, rid: int) -> List[int]:
        return list(self._tables[rid])

    def padded_table(self, rid: int, max_blocks: int) -> np.ndarray:
        """(max_blocks,) int32 block table padded with the scratch index
        (or block 0 when no scratch row exists) for the jitted decode step."""
        pad = self.scratch_index if self.scratch_index is not None else 0
        tbl = self._tables[rid]
        out = np.full((max_blocks,), pad, np.int32)
        out[: len(tbl)] = tbl
        return out

    def length(self, rid: int) -> int:
        return self._lengths[rid]

    def refcount(self, blk: int) -> int:
        """Current reference count of one block (0 = on the free list)."""
        return self._refs.get(blk, 0)

    def live_table_refs(self) -> int:
        """Total block-table references across every open request — the
        refcount invariant's ground truth (property tests compare
        ``sum(refcounts)`` against this plus the index/pin references)."""
        return sum(len(t) for t in self._tables.values())

    def open_rids(self) -> Tuple[int, ...]:
        return tuple(self._tables)

    def check_invariants(self) -> List[str]:
        """Audit the refcount books; returns violations (empty = healthy).
        The watchdog samples this: a leaked block (popped off the free list
        without a reference), a stats drift, or a table referencing more
        blocks than the refcounts cover all surface here."""
        problems: List[str] = []
        if len(self._refs) + len(self._free) != self.n_blocks:
            problems.append(
                f"partition broken: {len(self._refs)} refcounted + "
                f"{len(self._free)} free != {self.n_blocks} blocks")
        if self.stats.blocks_in_use != len(self._refs):
            problems.append(
                f"stats drift: blocks_in_use={self.stats.blocks_in_use} "
                f"!= {len(self._refs)} refcounted blocks")
        refsum = sum(self._refs.values())
        live = self.live_table_refs()
        if refsum < live:
            problems.append(
                f"refcount sum {refsum} < {live} live table references")
        bad = [b for b in self._refs if not 0 <= b < self.n_blocks]
        if bad:
            problems.append(f"refcounted blocks outside pool: {bad}")
        nonpos = [b for b, r in self._refs.items() if r <= 0]
        if nonpos:
            problems.append(f"non-positive refcounts on blocks: {nonpos}")
        return problems

    # -- reclaim (KV pages vs sessions/index competing for the pool) -------
    def add_reclaimer(self, reclaimer: Any) -> None:
        """Register an object holding blocks speculatively. Must expose
        ``reclaim(need_blocks) -> int`` (release at least this many blocks
        if possible, return how many were actually freed) and
        ``reclaimable() -> int`` (a conservative lower bound on what a
        reclaim could free). Consulted in registration order."""
        self._reclaimers.append(reclaimer)

    def reclaimable_blocks(self) -> int:
        """Blocks the registered reclaimers could free on demand — admission
        backpressure counts these next to ``free_blocks`` so retained
        sessions can never wedge the scheduler."""
        return sum(int(r.reclaimable()) for r in self._reclaimers)

    def _reclaim(self, need: int) -> None:
        """Ask reclaimers for blocks until the free list covers ``need``.
        Loops while anybody makes progress: evicting a leaf prefix entry can
        expose its parent as the next victim."""
        before = len(self._free)
        while len(self._free) < need:
            progress = 0
            for r in self._reclaimers:
                if len(self._free) >= need:
                    break
                progress += int(r.reclaim(need - len(self._free)))
            if progress == 0:
                break
        if len(self._free) != before:
            flightrec.record("reclaim", need=need,
                             freed=len(self._free) - before,
                             free_blocks=len(self._free))

    # -- refcounting -------------------------------------------------------
    def _alloc_block(self) -> int:
        if not self._free:
            self._reclaim(1)
        if not self._free:
            raise MemoryError("KV pool exhausted")
        blk = self._free.pop()
        self._refs[blk] = 1
        self.stats.allocs += 1
        self.stats.blocks_in_use += 1
        self.stats.peak_blocks = max(self.stats.peak_blocks,
                                     self.stats.blocks_in_use)
        return blk

    def _incref(self, blk: int) -> None:
        r = self._refs[blk]
        self._refs[blk] = r + 1
        if r == 1:
            self.stats.shared_blocks += 1

    def _decref(self, blk: int) -> None:
        r = self._refs[blk] - 1
        if r == 0:
            del self._refs[blk]
            self._free.append(blk)
            self.stats.frees += 1
            self.stats.blocks_in_use -= 1
        else:
            self._refs[blk] = r
            if r == 1:
                self.stats.shared_blocks -= 1

    def pin(self, blocks: Sequence[int]) -> None:
        """Take an extra reference on each block — protects a matched prefix
        from a concurrent reclaim between ``PrefixIndex.match`` and the
        adopting ``open``. Pair with ``unpin``."""
        for b in blocks:
            self._incref(b)

    def unpin(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            self._decref(b)

    # -- allocation ---------------------------------------------------------
    def open(self, rid: int, adopt: Optional[Sequence[int]] = None,
             adopt_len: int = 0):
        """Open a request's block table. With ``adopt``/``adopt_len`` the
        request starts seated on shared blocks covering its first
        ``adopt_len`` tokens (a prefix another request already prefilled):
        each adopted block's refcount is incremented and the blocks are
        treated as read-only — the first write into the partially filled
        tail triggers a COW split."""
        if rid in self._tables:
            raise KeyError(f"request {rid} already open")
        blocks = [int(b) for b in (adopt or ())]
        if blocks:
            if not 0 < adopt_len <= len(blocks) * self.block:
                raise ValueError(
                    f"adopt_len={adopt_len} outside ({0}, "
                    f"{len(blocks) * self.block}]")
            if adopt_len <= (len(blocks) - 1) * self.block:
                raise ValueError(
                    f"adopt_len={adopt_len} leaves the last of "
                    f"{len(blocks)} adopted blocks unused")
            for b in blocks:
                if b not in self._refs:
                    raise ValueError(f"cannot adopt free block {b}")
            for b in blocks:
                self._incref(b)
        elif adopt_len:
            raise ValueError("adopt_len without adopted blocks")
        self._tables[rid] = blocks
        self._lengths[rid] = adopt_len if blocks else 0
        self.table_version += 1
        self.length_version += 1

    def _make_tail_writable(self, rid: int) -> None:
        """COW split of a shared, partially filled tail block before a write:
        copy its rows into a fresh block device-side, swap the table entry,
        drop one reference on the shared original. Fully filled adopted
        blocks never need this — writes only ever land at positions >= the
        request's committed length."""
        n = self._lengths[rid]
        if n == 0 or n % self.block == 0:
            return
        bi = n // self.block
        tbl = self._tables[rid]
        old = tbl[bi]
        if self._refs[old] <= 1:
            return
        new = self._alloc_block()
        self.k = self.k.at[:, new].set(self.k[:, old])
        self.v = self.v.at[:, new].set(self.v[:, old])
        tbl[bi] = new
        self._decref(old)
        self.table_version += 1
        self.stats.cow_splits += 1

    def _ensure(self, rid: int, n_tokens: int):
        need_blocks = -(-(self._lengths[rid] + n_tokens) // self.block)
        while len(self._tables[rid]) < need_blocks:
            self._tables[rid].append(self._alloc_block())
            self.table_version += 1

    def reserve(self, rid: int, n_tokens: int):
        """Grow the block table so ``n_tokens`` more tokens fit. The engine's
        jitted step then scatters into the reserved positions directly —
        which writes the tail block, so a shared tail is COW-split here."""
        self._make_tail_writable(rid)
        self._ensure(rid, n_tokens)

    def advance(self, rid: int, n_tokens: int):
        """Commit ``n_tokens`` device-written tokens (after a jitted decode
        step that scattered into ``self.k/self.v``)."""
        need = -(-(self._lengths[rid] + n_tokens) // self.block)
        if need > len(self._tables[rid]):
            raise RuntimeError(
                f"advance({rid}, {n_tokens}) beyond reserved blocks")
        self._lengths[rid] += n_tokens
        self.length_version += 1

    def append(self, rid: int, k_new, v_new):
        """k_new/v_new (L, n_tokens, kv_heads, head_dim) for one request."""
        L, n, H, dh = k_new.shape
        self._make_tail_writable(rid)
        self._ensure(rid, n)
        start = self._lengths[rid]
        toks = np.arange(start, start + n)
        blks = np.asarray(self._tables[rid], np.int32)[toks // self.block]
        offs = (toks % self.block).astype(np.int32)
        self.k = self.k.at[:, blks, offs].set(k_new.astype(self.k.dtype))
        self.v = self.v.at[:, blks, offs].set(v_new.astype(self.v.dtype))
        self._lengths[rid] = start + n
        self.length_version += 1

    def gather(self, rid: int):
        """Contiguous (L, len, kv_heads, head_dim) view for attention."""
        tbl = jnp.asarray(self._tables[rid], jnp.int32)
        k = self.k[:, tbl].reshape(self.k.shape[0], -1, *self.k.shape[3:])
        v = self.v[:, tbl].reshape(self.v.shape[0], -1, *self.v.shape[3:])
        n = self._lengths[rid]
        return k[:, :n], v[:, :n]

    def free(self, rid: int):
        """Drop the request's references; blocks whose refcount reaches zero
        return to the free list. The table entry is removed and BOTH
        versions are bumped *before* any block becomes reallocatable, so a
        stale ``_DeviceTableCache`` snapshot keyed on the old version can
        never gather rows a later request reused."""
        tbl = self._tables.pop(rid)
        del self._lengths[rid]
        self.table_version += 1
        self.length_version += 1
        for blk in tbl:
            self._decref(blk)


# ----------------------------------------------------------------------
# Radix-style prefix index over token-id block hashes
# ----------------------------------------------------------------------

@dataclass
class _PrefixEntry:
    key: bytes
    parent: bytes
    block: int
    tokens: np.ndarray                   # the block's token ids (<= block)
    last_use: int = 0
    n_children: int = field(default=0)


class PrefixIndex:
    """Radix-style prefix index over ``PagedKVCache`` blocks.

    Keys are chained hashes of token-id blocks: ``key_i = H(key_{i-1} ||
    tokens_i)`` rooted at the expert name — KV is only valid for the
    expert whose weights produced it, so two experts never share blocks
    even for identical prompts. ``insert`` indexes every *full* block of a
    finished request's sequence (one extra pool reference each — the block
    survives the request's ``free``); ``match`` walks the chain over a new
    prompt and returns the shared blocks plus the matched token count. A
    match may end with a *partial* tail: the new prompt shares only the
    first few tokens of an indexed block — the block is adopted anyway
    (those rows are position-exact) and the adopter's first write COW-splits
    it. Stored token arrays are compared on every hop, so a hash collision
    degrades to a miss, never to a wrong adoption.

    The index is a ``PagedKVCache`` reclaimer: under pool pressure it evicts
    least-recently-used leaf entries whose block nobody else references.
    """

    def __init__(self, pool: PagedKVCache):
        self.pool = pool
        self._entries: Dict[bytes, _PrefixEntry] = {}
        self._children: Dict[bytes, List[bytes]] = {}
        self._clock = 0
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    @staticmethod
    def _root(expert: str) -> bytes:
        return b"root:" + expert.encode()

    @staticmethod
    def _key(parent: bytes, tokens: np.ndarray) -> bytes:
        return hashlib.blake2b(
            parent + np.ascontiguousarray(tokens, np.int32).tobytes(),
            digest_size=16).digest()

    # -- write path --------------------------------------------------------
    def insert(self, expert: str, tokens: np.ndarray,
               table: Sequence[int]) -> int:
        """Index the full blocks of a finished sequence (``tokens`` are the
        first ``pool.length(rid)`` token ids; ``table`` the rid's block
        table). Returns how many new entries were created. Existing entries
        are refreshed (LRU), not re-referenced."""
        self._clock += 1
        B = self.pool.block
        key = self._root(expert)
        created = 0
        for i in range(min(len(tokens) // B, len(table))):
            blk_toks = np.ascontiguousarray(tokens[i * B:(i + 1) * B],
                                            np.int32)
            child = self._key(key, blk_toks)
            e = self._entries.get(child)
            if e is None:
                self.pool._incref(int(table[i]))
                e = _PrefixEntry(key=child, parent=key, block=int(table[i]),
                                 tokens=blk_toks)
                self._entries[child] = e
                self._children.setdefault(key, []).append(child)
                if key in self._entries:
                    self._entries[key].n_children += 1
                created += 1
            e.last_use = self._clock
            key = child
        return created

    # -- read path ---------------------------------------------------------
    def match(self, expert: str,
              tokens: np.ndarray) -> Optional[Tuple[List[int], int]]:
        """Longest indexed prefix of ``tokens`` for this expert. Returns
        ``(blocks, n_tokens)`` with the blocks PINNED (one extra reference
        each — the caller must ``open(adopt=blocks, ...)`` then ``unpin``),
        or ``None`` on a miss. Adoption is capped at ``len(tokens) - 1`` so
        at least one suffix token always runs a forward (the first sampled
        token needs logits)."""
        self._clock += 1
        B = self.pool.block
        key = self._root(expert)
        blocks: List[int] = []
        i = 0
        while (i + 1) * B <= len(tokens):
            blk_toks = np.ascontiguousarray(tokens[i * B:(i + 1) * B],
                                            np.int32)
            child = self._key(key, blk_toks)
            e = self._entries.get(child)
            if e is None or not np.array_equal(e.tokens, blk_toks):
                break
            e.last_use = self._clock
            blocks.append(e.block)
            key = child
            i += 1
        n = i * B
        rest = np.ascontiguousarray(tokens[n:], np.int32)
        if len(rest):
            # partial tail: an indexed child block whose first tokens match
            # the remaining prompt — adopted read-only, COW on first write
            best, best_m = None, 0
            for ck in self._children.get(key, ()):  # noqa: B007
                e = self._entries.get(ck)
                if e is None:
                    continue
                m = int((np.cumprod(e.tokens[:len(rest)]
                                    == rest[:len(e.tokens)])).sum())
                if m > best_m:
                    best, best_m = e, m
            if best is not None and best_m > 0:
                best.last_use = self._clock
                blocks.append(best.block)
                n += best_m
        if n >= len(tokens):            # keep >= 1 token for the forward
            n = len(tokens) - 1
            blocks = blocks[: -(-n // B)] if n else []
        if n == 0:
            self.misses += 1
            return None
        self.hits += 1
        self.pool.pin(blocks)
        return blocks, n

    # -- eviction / reclaim ------------------------------------------------
    def _evict(self, e: _PrefixEntry) -> None:
        del self._entries[e.key]
        sibs = self._children.get(e.parent)
        if sibs is not None:
            sibs.remove(e.key)
            if not sibs:
                del self._children[e.parent]
        p = self._entries.get(e.parent)
        if p is not None:
            p.n_children -= 1
        self.pool._decref(e.block)

    def _victims(self) -> List[_PrefixEntry]:
        """LRU-ordered leaf entries whose block only the index references —
        evicting anything else frees no memory (shared block) or strands
        reachable children (interior node)."""
        return sorted((e for e in self._entries.values()
                       if e.n_children == 0
                       and self.pool.refcount(e.block) == 1),
                      key=lambda e: e.last_use)

    def reclaimable(self) -> int:
        return len(self._victims())

    def reclaim(self, need_blocks: int) -> int:
        freed = 0
        while freed < need_blocks:
            vs = self._victims()
            if not vs:
                break
            for e in vs:
                if freed >= need_blocks:
                    break
                self._evict(e)
                freed += 1
        return freed

    def clear(self) -> None:
        """Drop every entry and its pool reference."""
        while self._entries:
            for e in list(self._entries.values()):
                if e.n_children == 0:
                    self._evict(e)
