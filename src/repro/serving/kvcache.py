"""Paged KV-cache pool for concurrent CoE serving.

The HBM tier holds three competing populations: expert weights (LRU cache,
core/switching.py), the router, and per-request KV caches. A paged pool
(vLLM-style block tables) bounds the KV population: requests allocate
fixed-size blocks on demand, free them on completion, and fragmentation is
impossible by construction. The pool's byte budget plugs into the same
three-tier accounting the expert cache uses (``core.memory_tiers.HBMBudget``),
so the CoE runtime can trade resident experts against concurrent requests
explicitly.

This pool is the ONLY KV storage of ``serving.engine.ServingEngine``: every
decode slot is a block table here. Two access paths coexist:

  * host path — ``open/append/gather/free`` (prefill writes, reference
    reads, recycling);
  * device path — the engine's jitted paged decode step scatters new K/V
    directly into ``self.k/self.v`` and the engine commits the updated
    arrays plus ``advance``d lengths afterwards. ``reserve`` must have been
    called first so the block table covers the written positions.

With ``scratch=True`` the pool carries one extra block (index
``scratch_index``) that is never allocated to a request: inactive decode
lanes scatter there so a single compiled step can serve any slot subset.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.metrics import MetricsRegistry
from repro.obs.stats import StatsView, counter_field, gauge_field


class PagedStats(StatsView):
    """KV-pool counters as a view over the metrics registry (``kv.*``
    series). Same serialization surface as ``SwitchStats`` — benchmark
    JSON rows embed both."""

    PREFIX = "kv"

    allocs = counter_field()
    frees = counter_field()
    blocks_in_use = gauge_field()
    peak_blocks = gauge_field()


class PagedKVCache:
    """Block-paged K/V pool. Layout: (n_blocks, block, kv_heads, head_dim)."""

    def __init__(self, n_blocks: int, block_size: int, n_layers: int,
                 kv_heads: int, head_dim: int, dtype=jnp.bfloat16,
                 scratch: bool = False,
                 registry: Optional[MetricsRegistry] = None,
                 labels: Optional[Dict[str, Any]] = None):
        self.n_blocks = n_blocks
        self.block = block_size
        rows = n_blocks + (1 if scratch else 0)
        self.k = jnp.zeros((n_layers, rows, block_size, kv_heads, head_dim),
                           dtype)
        self.v = jnp.zeros_like(self.k)
        self.scratch_index: Optional[int] = n_blocks if scratch else None
        self._free: List[int] = list(range(n_blocks))[::-1]
        self._tables: Dict[int, List[int]] = {}
        self._lengths: Dict[int, int] = {}
        # monotonic versions of the host bookkeeping, so device-copy caches
        # (engine._DeviceTableCache) can skip re-uploading unchanged
        # tables/lengths every decode round
        self.table_version = 0        # bumped when any block table changes
        self.length_version = 0       # bumped when any length changes
        self.stats = PagedStats(registry=registry, labels=labels)

    # -- sizing ------------------------------------------------------------
    @staticmethod
    def block_bytes(block_size: int, n_layers: int, kv_heads: int,
                    head_dim: int, dtype=jnp.bfloat16) -> int:
        """Bytes of one K+V block across all layers."""
        itemsize = jnp.dtype(dtype).itemsize
        return 2 * n_layers * block_size * kv_heads * head_dim * itemsize

    @classmethod
    def for_budget(cls, budget_bytes: int, block_size: int, n_layers: int,
                   kv_heads: int, head_dim: int, dtype=jnp.bfloat16,
                   scratch: bool = False,
                   registry: Optional[MetricsRegistry] = None,
                   labels: Optional[Dict[str, Any]] = None) -> "PagedKVCache":
        """Largest pool whose K+V arrays fit in ``budget_bytes`` (the KV share
        of the HBM tier from ``core.memory_tiers.plan_hbm_budget``). The
        scratch row, when requested, counts against the budget."""
        per = cls.block_bytes(block_size, n_layers, kv_heads, head_dim, dtype)
        n_blocks = int(budget_bytes // per) - (1 if scratch else 0)
        if n_blocks < 1:
            raise MemoryError(
                f"KV budget {budget_bytes} bytes < "
                f"{'scratch + ' if scratch else ''}one block ({per} bytes)")
        return cls(n_blocks, block_size, n_layers, kv_heads, head_dim,
                   dtype, scratch=scratch, registry=registry, labels=labels)

    # -- bookkeeping -------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def _per_block_bytes(self) -> int:
        L, _, blk, H, dh = self.k.shape
        return self.block_bytes(blk, L, H, dh, self.k.dtype)

    def capacity_bytes(self) -> int:
        """Bytes of the allocatable blocks (scratch row excluded)."""
        return self.n_blocks * self._per_block_bytes()

    def bytes_in_use(self) -> int:
        return self.stats.blocks_in_use * self._per_block_bytes()

    def table(self, rid: int) -> List[int]:
        return list(self._tables[rid])

    def padded_table(self, rid: int, max_blocks: int) -> np.ndarray:
        """(max_blocks,) int32 block table padded with the scratch index
        (or block 0 when no scratch row exists) for the jitted decode step."""
        pad = self.scratch_index if self.scratch_index is not None else 0
        tbl = self._tables[rid]
        out = np.full((max_blocks,), pad, np.int32)
        out[: len(tbl)] = tbl
        return out

    def length(self, rid: int) -> int:
        return self._lengths[rid]

    # -- allocation ---------------------------------------------------------
    def open(self, rid: int):
        if rid in self._tables:
            raise KeyError(f"request {rid} already open")
        self._tables[rid] = []
        self._lengths[rid] = 0
        self.table_version += 1
        self.length_version += 1

    def _ensure(self, rid: int, n_tokens: int):
        need_blocks = -(-(self._lengths[rid] + n_tokens) // self.block)
        while len(self._tables[rid]) < need_blocks:
            if not self._free:
                raise MemoryError("KV pool exhausted")
            self._tables[rid].append(self._free.pop())
            self.table_version += 1
            self.stats.allocs += 1
            self.stats.blocks_in_use += 1
            self.stats.peak_blocks = max(self.stats.peak_blocks,
                                         self.stats.blocks_in_use)

    def reserve(self, rid: int, n_tokens: int):
        """Grow the block table so ``n_tokens`` more tokens fit. The engine's
        jitted step then scatters into the reserved positions directly."""
        self._ensure(rid, n_tokens)

    def advance(self, rid: int, n_tokens: int):
        """Commit ``n_tokens`` device-written tokens (after a jitted decode
        step that scattered into ``self.k/self.v``)."""
        need = -(-(self._lengths[rid] + n_tokens) // self.block)
        if need > len(self._tables[rid]):
            raise RuntimeError(
                f"advance({rid}, {n_tokens}) beyond reserved blocks")
        self._lengths[rid] += n_tokens
        self.length_version += 1

    def append(self, rid: int, k_new, v_new):
        """k_new/v_new (L, n_tokens, kv_heads, head_dim) for one request."""
        L, n, H, dh = k_new.shape
        self._ensure(rid, n)
        start = self._lengths[rid]
        toks = np.arange(start, start + n)
        blks = np.asarray(self._tables[rid], np.int32)[toks // self.block]
        offs = (toks % self.block).astype(np.int32)
        self.k = self.k.at[:, blks, offs].set(k_new.astype(self.k.dtype))
        self.v = self.v.at[:, blks, offs].set(v_new.astype(self.v.dtype))
        self._lengths[rid] = start + n
        self.length_version += 1

    def gather(self, rid: int):
        """Contiguous (L, len, kv_heads, head_dim) view for attention."""
        tbl = jnp.asarray(self._tables[rid], jnp.int32)
        k = self.k[:, tbl].reshape(self.k.shape[0], -1, *self.k.shape[3:])
        v = self.v[:, tbl].reshape(self.v.shape[0], -1, *self.v.shape[3:])
        n = self._lengths[rid]
        return k[:, :n], v[:, :n]

    def free(self, rid: int):
        for blk in self._tables.pop(rid):
            self._free.append(blk)
            self.stats.frees += 1
            self.stats.blocks_in_use -= 1
        del self._lengths[rid]
        self.table_version += 1
        self.length_version += 1
