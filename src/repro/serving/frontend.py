"""Asyncio streaming front door for the serving engine.

``ServingEngine`` is a synchronous single-thread scheduler — the right shape
for the decode loop, the wrong shape for a million concurrent users. This
module puts a front door on it:

  * **one pump thread owns the engine.** All engine access (submit, step,
    queue surgery) happens on that thread; callers talk to a thread-safe
    admission heap. The engine's ``on_token``/``on_done`` callbacks fire on
    the pump thread and only enqueue into per-request ``TokenStream``s, so
    the decode loop never blocks on a slow consumer.
  * **per-tenant quotas** at the door: a concurrency cap plus a token-bucket
    request rate. Over-quota submits raise ``QuotaExceeded`` immediately —
    load shedding happens before a request ever touches engine state.
  * **SLO-aware priority and preemption at admission**: the heap orders by
    (priority desc, TTFT deadline asc). The engine's own queue is kept
    short (``max_engine_queue``) so ordering decisions stay at the
    frontend; when a higher-priority request arrives, an unadmitted
    lower-priority request is pulled back out of the engine queue into the
    heap (``frontend.preemptions``). Requests already decoding are never
    preempted — their KV and slot investment is sunk.
  * **streaming**: tokens are observable as they are emitted, via the sync
    iterator ``TokenStream`` or an ``asyncio.Queue`` bridge
    (``stream_async``), plus a JSON-lines TCP server (``serve_tcp``) for
    real sockets.
"""
from __future__ import annotations

import asyncio
import heapq
import itertools
import json
import queue
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.obs import flightrec
from repro.serving.engine import Request, ServingEngine

_DONE = object()                         # TokenStream end-of-stream sentinel


class QuotaExceeded(Exception):
    """Tenant over its concurrency cap or request-rate bucket."""


@dataclass
class TenantQuota:
    """Admission limits for one tenant. ``requests_per_s=None`` disables
    rate limiting; ``burst`` is the token-bucket depth (defaults to the
    rate, min 1)."""

    max_concurrent: int = 8
    requests_per_s: Optional[float] = None
    burst: Optional[float] = None

    def bucket_depth(self) -> float:
        if self.requests_per_s is None:
            return float("inf")
        return max(self.burst if self.burst is not None
                   else self.requests_per_s, 1.0)


class _TenantState:
    def __init__(self, quota: TenantQuota):
        self.quota = quota
        self.inflight = 0
        self.tokens = quota.bucket_depth()
        self.last_refill = time.monotonic()

    def try_admit(self) -> bool:
        if self.inflight >= self.quota.max_concurrent:
            return False
        if self.quota.requests_per_s is not None:
            now = time.monotonic()
            self.tokens = min(
                self.quota.bucket_depth(),
                self.tokens + (now - self.last_refill)
                * self.quota.requests_per_s)
            self.last_refill = now
            if self.tokens < 1.0:
                return False
            self.tokens -= 1.0
        self.inflight += 1
        return True


class TokenStream:
    """Per-request stream of emitted token ids. Iterating blocks until the
    next token (or end of stream); ``drain()`` blocks to completion and
    returns everything at once."""

    def __init__(self, req: Request):
        self.request = req
        self._q: "queue.Queue[Any]" = queue.Queue()

    def _put(self, tok: int) -> None:
        self._q.put(tok)

    def _close(self) -> None:
        self._q.put(_DONE)

    def __iter__(self):
        while True:
            item = self._q.get()
            if item is _DONE:
                return
            yield item

    def drain(self) -> List[int]:
        return list(self)


@dataclass(order=True)
class _Pending:
    # heap key: higher priority first, then earlier TTFT deadline, then FIFO
    sort_key: Tuple[int, float, int]
    req: Request = None                  # type: ignore[assignment]
    stream: TokenStream = None           # type: ignore[assignment]


class StreamingFrontend:
    """Thread-safe, quota-enforcing, SLO-ordered front door to one engine
    (or anything engine-shaped, e.g. an ``RDUNode`` via a thin adapter)."""

    def __init__(self, engine: ServingEngine, *,
                 quotas: Optional[Dict[str, TenantQuota]] = None,
                 default_quota: Optional[TenantQuota] = None,
                 max_engine_queue: Optional[int] = None,
                 rid_base: int = 1_000_000):
        self.engine = engine
        self._default_quota = default_quota or TenantQuota()
        self._tenants: Dict[str, _TenantState] = {
            t: _TenantState(q) for t, q in (quotas or {}).items()}
        # short engine queue: ordering stays here, where priorities live
        self.max_engine_queue = (max_engine_queue
                                 if max_engine_queue is not None
                                 else engine.n_slots * 2)
        self._heap: List[_Pending] = []
        self._lock = threading.Lock()
        self._wake = threading.Event()
        self._seq = itertools.count()
        self._rids = itertools.count(rid_base)
        self._closed = False
        reg = engine._registry
        labels = engine._obs_labels
        self._m_submitted = reg.counter("frontend.submitted", labels=labels)
        self._m_rejected = reg.counter("frontend.rejected_quota",
                                       labels=labels)
        self._m_preempt = reg.counter("frontend.preemptions", labels=labels)
        self._m_streamed = reg.counter("frontend.streamed_tokens",
                                       labels=labels)
        self._thread = threading.Thread(target=self._pump, daemon=True,
                                        name="frontend-pump")
        self._thread.start()

    # -- client API --------------------------------------------------------
    def submit(self, tokens, max_new_tokens: int, *,
               tenant: str = "default", session_id: Optional[str] = None,
               priority: int = 0,
               slo_ttft_s: Optional[float] = None,
               slo_tpot_s: Optional[float] = None) -> TokenStream:
        """Admit one request (quota check now, engine later) and return its
        token stream. Raises ``QuotaExceeded`` instead of queueing when the
        tenant is over its limits."""
        if self._closed:
            raise RuntimeError("frontend is closed")
        with self._lock:
            ts = self._tenants.get(tenant)
            if ts is None:
                ts = self._tenants[tenant] = _TenantState(self._default_quota)
            if not ts.try_admit():
                self._m_rejected.inc()
                raise QuotaExceeded(f"tenant {tenant!r} over quota")
            req = Request(rid=next(self._rids),
                          tokens=np.asarray(tokens, np.int32),
                          max_new_tokens=max_new_tokens,
                          session_id=session_id, tenant=tenant,
                          priority=priority, slo_ttft_s=slo_ttft_s,
                          slo_tpot_s=slo_tpot_s)
            stream = TokenStream(req)
            req.on_token = lambda r, t: (stream._put(t),
                                         self._m_streamed.inc())
            req.on_done = lambda r: self._on_done(r, stream)
            deadline = req.arrival_s + (slo_ttft_s if slo_ttft_s is not None
                                        else float("inf"))
            heapq.heappush(self._heap, _Pending(
                (-priority, deadline, next(self._seq)), req, stream))
            self._m_submitted.inc()
        self._wake.set()
        return stream

    def _on_done(self, req: Request, stream: TokenStream) -> None:
        with self._lock:
            ts = self._tenants.get(req.tenant)
            if ts is not None:
                ts.inflight -= 1
        stream._close()

    def join(self, timeout: Optional[float] = None) -> bool:
        """Block until every submitted request has finished."""
        t0 = time.monotonic()
        while True:
            with self._lock:
                idle = not self._heap and not self.engine.has_work
            if idle:
                return True
            if timeout is not None and time.monotonic() - t0 > timeout:
                return False
            time.sleep(0.001)

    def close(self) -> None:
        self._closed = True
        self._wake.set()
        self._thread.join(timeout=10)

    # -- pump thread (sole owner of the engine) ----------------------------
    def _pump(self) -> None:
        while not self._closed:
            moved = self._feed_engine()
            if self.engine.has_work:
                self.engine.step()
            elif not moved:
                self._wake.wait(timeout=0.01)
                self._wake.clear()

    def _feed_engine(self) -> int:
        """Move heap-ordered pending work into the engine queue, preempting
        unadmitted lower-priority engine entries when a higher-priority
        request would otherwise wait behind them."""
        moved = 0
        with self._lock:
            while self._heap:
                if len(self.engine.queue) >= self.max_engine_queue:
                    if not self._preempt_for(self._heap[0]):
                        break
                p = heapq.heappop(self._heap)
                self.engine.submit(p.req)
                moved += 1
        return moved

    def _preempt_for(self, cand: _Pending) -> bool:
        """Pull the lowest-priority *unadmitted* request back out of the
        engine queue to make room for ``cand`` — only if it is strictly
        lower priority. Decoding slots are untouched (sunk KV cost)."""
        q = self.engine.queue
        if not q:
            return False
        victim = min(q, key=lambda r: r.priority)
        if victim.priority >= cand.req.priority:
            return False
        q.remove(victim)
        victim.preemptions += 1          # lifecycle-plane attribution
        flightrec.record("preempt", rid=victim.rid, tenant=victim.tenant,
                         priority=victim.priority, by=cand.req.rid)
        heapq.heappush(self._heap, _Pending(
            (-victim.priority,
             victim.arrival_s + (victim.slo_ttft_s
                                 if victim.slo_ttft_s is not None
                                 else float("inf")),
             next(self._seq)),
            victim, None))
        self._m_preempt.inc()
        return True

    # -- asyncio bridge ----------------------------------------------------
    def stream_async(self, stream: TokenStream,
                     loop: Optional[asyncio.AbstractEventLoop] = None
                     ) -> "asyncio.Queue[Any]":
        """Bridge a TokenStream onto an asyncio.Queue (``None`` terminates).
        Must be called from the event loop thread (or pass ``loop``)."""
        loop = loop or asyncio.get_event_loop()
        aq: "asyncio.Queue[Any]" = asyncio.Queue()

        def rely():
            for tok in stream:
                loop.call_soon_threadsafe(aq.put_nowait, tok)
            loop.call_soon_threadsafe(aq.put_nowait, None)

        threading.Thread(target=rely, daemon=True).start()
        return aq

    async def handle_connection(self, reader: asyncio.StreamReader,
                                writer: asyncio.StreamWriter) -> None:
        """One JSON-lines request per connection:
        ``{"tokens": [...], "max_new_tokens": n, "tenant": ..., ...}`` in,
        ``{"token": t}`` per emitted token and ``{"done": true, "output":
        [...]}`` (or ``{"error": ...}``) out."""
        try:
            line = await reader.readline()
            msg = json.loads(line)
            stream = self.submit(
                msg["tokens"], int(msg["max_new_tokens"]),
                tenant=msg.get("tenant", "default"),
                session_id=msg.get("session_id"),
                priority=int(msg.get("priority", 0)),
                slo_ttft_s=msg.get("slo_ttft_s"),
                slo_tpot_s=msg.get("slo_tpot_s"))
        except QuotaExceeded as e:
            writer.write(json.dumps({"error": str(e)}).encode() + b"\n")
            await writer.drain()
            writer.close()
            return
        aq = self.stream_async(stream, asyncio.get_running_loop())
        out = []
        while True:
            tok = await aq.get()
            if tok is None:
                break
            out.append(tok)
            writer.write(json.dumps({"token": tok}).encode() + b"\n")
            await writer.drain()
        writer.write(json.dumps({"done": True, "output": out}).encode()
                     + b"\n")
        await writer.drain()
        writer.close()

    async def serve_tcp(self, host: str = "127.0.0.1", port: int = 0):
        """Start the JSON-lines TCP server; returns the asyncio server
        (``server.sockets[0].getsockname()`` for the bound port)."""
        return await asyncio.start_server(self.handle_connection, host, port)
