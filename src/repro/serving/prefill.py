"""AOT-compiled, bucketed, packed prefill for the serving engine.

Prefill is the compile-shape hazard of the serving stack: the decode step
runs one fixed ``(n_slots, g)`` shape forever, but every novel *prompt
length* used to hit ``jax.jit`` with a fresh ``(1, S)`` signature — a
multi-second XLA compile stall right on the TTFT critical path ("heavy
traffic from millions of users" means every length shows up eventually).
This module removes the hazard and amortizes the per-admit forward:

  * **power-of-two length buckets** (``default_buckets``): a packed prefill
    always runs at a bucket shape, so the engine compiles ``O(log max_len)``
    forwards total — all of them ahead of time at ``warmup()``;
  * **packing via segment ids**: several prompts ride in ONE ``(1, bucket)``
    call. The causal mask is blocked across segments
    (``(seg_i == seg_j) & (j <= i)``) and RoPE positions restart per
    segment, so each prompt's logits and K/V are *bit-identical* to its own
    sequential ``prefill_kv`` call (masked cross-segment scores contribute
    exact zeros; verified at f32 and bf16 by tests/test_prefill.py).
    Padding gets its own segment id — pad queries attend at least
    themselves, so no softmax row is fully masked and no NaN can leak
    through ``0 * NaN`` into real rows;
  * **donated scatter handoff**: the packed K/V lands in the paged pool via
    a per-bucket jitted scatter with ``donate_argnums=(0, 1)`` — the pool
    buffers are updated in place, decode state is handed off without a
    copy. Pad positions scatter to the pool's scratch block.

``record_compile``/``compile_count`` is the compile-accounting hook the
recompile-regression test (and ``benchmarks/run.py --sweep-prefill``) keys
on: every site that triggers a fresh XLA compile in the serving path —
bucketed prefill, scatter, sequential ``prefill_kv``, decode extend —
reports here, so "zero compilations after warmup" is a testable invariant
rather than a hope.
"""
from __future__ import annotations

import math
import threading
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


# ----------------------------------------------------------------------
# Compile accounting
# ----------------------------------------------------------------------

_compile_lock = threading.Lock()
_compile_counts: Dict[str, int] = {}


def record_compile(site: str) -> None:
    """Report one fresh XLA compilation from ``site`` (e.g. ``"packed_
    prefill"``, ``"prefill_kv"``, ``"extend"``). Call exactly where a new
    shape enters a jit/lower cache."""
    with _compile_lock:
        _compile_counts[site] = _compile_counts.get(site, 0) + 1


def compile_count(site: Optional[str] = None) -> int:
    """Total compiles recorded (optionally for one site) since process start
    or the last ``reset_compile_counts``."""
    with _compile_lock:
        if site is not None:
            return _compile_counts.get(site, 0)
        return sum(_compile_counts.values())


def compile_counts() -> Dict[str, int]:
    """Per-site snapshot of the compile counters."""
    with _compile_lock:
        return dict(_compile_counts)


def reset_compile_counts() -> None:
    with _compile_lock:
        _compile_counts.clear()


# ----------------------------------------------------------------------
# Buckets and packing plans
# ----------------------------------------------------------------------

def default_buckets(max_len: int, min_bucket: int = 16) -> Tuple[int, ...]:
    """Power-of-two buckets ``min_bucket, 2*min_bucket, ...`` up to the
    first bucket covering ``max_len``."""
    if max_len < 1:
        raise ValueError("max_len must be >= 1")
    out = [min_bucket]
    while out[-1] < max_len:
        out.append(out[-1] * 2)
    return tuple(out)


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket covering ``n`` tokens."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"{n} tokens exceed the largest bucket {buckets[-1]}")


def plan_packs(lengths: Sequence[int], buckets: Sequence[int],
               max_segments: int) -> List[List[int]]:
    """Greedy in-order chunking of prompt ``lengths`` into packed prefill
    calls: consecutive prompts share a call while their total fits the
    largest bucket and the segment count stays within ``max_segments``.
    Returns lists of indices into ``lengths`` (order preserved — admission
    order is part of the scheduler's fairness contract)."""
    cap = buckets[-1]
    chunks: List[List[int]] = []
    cur: List[int] = []
    total = 0
    for i, n in enumerate(lengths):
        if n > cap:
            raise ValueError(f"prompt {i} ({n} tokens) exceeds bucket cap {cap}")
        if cur and (total + n > cap or len(cur) >= max_segments):
            chunks.append(cur)
            cur, total = [], 0
        cur.append(i)
        total += n
    if cur:
        chunks.append(cur)
    return chunks


# ----------------------------------------------------------------------
# The packed forward
# ----------------------------------------------------------------------

def packed_attention(q, k, v, seg):
    """Causal attention blocked across segments: query ``i`` attends key
    ``j`` iff ``j <= i`` AND both flat positions carry the same segment id.
    Shapes: q (B,Sq,Hq,dh), k/v (B,Sk,Hkv,dh), seg (B,Sq) int32 (GQA via
    head grouping, same contraction order as the sequential dense path so
    per-segment results stay bit-identical)."""
    B, Sq, Hq, dh = q.shape
    _, Sk, Hkv, dv = v.shape
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * (1.0 / math.sqrt(dh))
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = (kpos <= qpos) & (seg[0][:, None] == seg[0][None, :])
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return o.reshape(B, Sq, Hq, dv)


def packed_prefill_fn(cfg: ModelConfig):
    """Traceable packed prefill forward for one backbone config.

    ``f(params, tokens (1,S), seg (1,S), pos (1,S), last_idx (P,)) ->
    (logits (P, V), k (L,S,Hkv,dh), v (L,S,Hkv,dh))`` where ``S`` is the
    bucket, ``seg`` carries segment ids (pad = a distinct id), ``pos``
    restarts at 0 per segment (fed to RoPE), and ``last_idx`` points at each
    segment's final token (padded rows gather position 0 — callers ignore
    them). The body mirrors ``models.transformer`` layer math exactly; only
    the attention mask and explicit positions differ."""
    from repro.distributed import ctx
    from repro.models import layers as L
    from repro.models import transformer as T

    moe = cfg.n_experts > 0

    def _attn(p, x, seg, pos):
        h = L.apply_norm(cfg, p["norm"], x)
        q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
        if cfg.qkv_bias:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
        q = L.apply_rope(cfg, q, pos)
        k = L.apply_rope(cfg, k, pos)
        o = packed_attention(q, k, v, seg)
        y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        if cfg.attn_out_bias:
            y = y + p["bo"]
        return x + y, (k, v)

    def forward(params, tokens, seg, pos, last_idx):
        h = T.embed_tokens(cfg, params, tokens)

        def body(hh, lp):
            hh = ctx.constrain(hh)
            x, kv = _attn(lp["attn"], hh, seg, pos)
            x = T._mlp(cfg, lp["mlp_norm"], lp["mlp"], x, moe)
            return x, kv

        h, (k, v) = ctx.lscan(body, h, params["layers"])
        h = L.apply_norm(cfg, params["final_norm"], h)
        h_last = h[0][last_idx][:, None]            # (P, 1, D)
        logits = T.unembed(cfg, params, h_last)     # (P, 1, V)
        return logits[:, 0], k[:, 0], v[:, 0]

    return forward


# ----------------------------------------------------------------------
# The runner
# ----------------------------------------------------------------------

@dataclass
class PackedPrefill:
    """Result of one packed prefill call. ``logits`` rows beyond
    ``len(spans)`` are padding (ignore); ``k``/``v`` are the packed caches —
    slice with ``spans`` or scatter the whole bucket via ``scatter``."""
    logits: jax.Array                    # (max_segments, V)
    k: jax.Array                         # (L, S, Hkv, dh)
    v: jax.Array                         # (L, S, Hkv, dh)
    spans: List[Tuple[int, int]]         # per prompt: (offset, length)
    bucket: int


@dataclass
class PrefillHandoff:
    """Prefill state computed off-engine (a disaggregated prefill socket
    group) and attached to a ``Request`` before it reaches a decode engine:
    the first sampled token plus the prompt's K/V blocks, gathered
    contiguous from the prefill group's paged cache. The decode engine
    adopts it into a slot (``append`` + ``reserve``) instead of running its
    own prefill."""
    first_token: int
    k: np.ndarray                        # (L, S, Hkv, dh)
    v: np.ndarray                        # (L, S, Hkv, dh)

    def nbytes(self) -> int:
        return self.k.nbytes + self.v.nbytes


class PackedPrefillRunner:
    """Bucketed, packed, AOT-compiled prefill for one backbone config.

    One compiled executable per bucket (shared by every expert of the CoE —
    same backbone, §II), plus one donated pool-scatter per bucket.
    ``warmup(params, pool)`` lowers and compiles all of them ahead of time;
    after that a mixed-length burst triggers **zero** XLA compilations
    (every compile goes through ``record_compile``, so the claim is
    enforced by tests/test_prefill.py). Works unchanged with TP-sharded
    params/pools: the forward is plain ``jax.jit``, GSPMD partitions it
    along the captured input shardings exactly like the sequential
    ``prefill_kv`` path.
    """

    def __init__(self, cfg: ModelConfig, *, buckets: Sequence[int],
                 max_segments: int = 8):
        if cfg.family not in ("dense", "moe"):
            raise ValueError("packed prefill supports dense/moe families only")
        if cfg.sliding_window:
            raise ValueError("packed prefill does not support sliding windows")
        if cfg.first_dense_layers:
            raise ValueError("packed prefill: first_dense_layers unsupported")
        if not buckets or list(buckets) != sorted(set(buckets)):
            raise ValueError("buckets must be strictly increasing")
        if max_segments < 1:
            raise ValueError("max_segments must be >= 1")
        self.cfg = cfg
        self.buckets = tuple(int(b) for b in buckets)
        self.max_segments = int(max_segments)
        self._fn = packed_prefill_fn(cfg)
        self._fwd: Dict[int, jax.stages.Compiled] = {}
        self._scatter: Dict[int, jax.stages.Compiled] = {}

    # -- compile management -----------------------------------------------
    def _ensure_fwd(self, bucket: int, args):
        if bucket not in self._fwd:
            record_compile("packed_prefill")
            self._fwd[bucket] = jax.jit(self._fn).lower(*args).compile()
        return self._fwd[bucket]

    @staticmethod
    def _scatter_body(pk, pv, kn, vn, rows, offs):
        pk = pk.at[:, rows, offs].set(kn.astype(pk.dtype))
        pv = pv.at[:, rows, offs].set(vn.astype(pv.dtype))
        return pk, pv

    def _ensure_scatter(self, bucket: int, args):
        if bucket not in self._scatter:
            record_compile("packed_scatter")
            self._scatter[bucket] = jax.jit(
                self._scatter_body,
                donate_argnums=(0, 1)).lower(*args).compile()
        return self._scatter[bucket]

    def warmup(self, params, pool) -> None:
        """AOT-compile every bucket's forward and pool-scatter. ``params``
        is any expert of the composition (all share shapes/shardings);
        ``pool`` is the engine's ``PagedKVCache`` — its live arrays pin the
        scatter's input shardings. Executes each forward once on dummy
        tokens (cheap at bucket shapes, and it yields concrete K/V to lower
        the scatter against); the pool itself is never written."""
        scratch = pool.scratch_index if pool.scratch_index is not None else 0
        for b in self.buckets:
            toks = jnp.zeros((1, b), jnp.int32)
            seg = jnp.full((1, b), self.max_segments, jnp.int32)
            pos = jnp.asarray(np.arange(b, dtype=np.int32)[None])
            last = jnp.zeros((self.max_segments,), jnp.int32)
            fwd = self._ensure_fwd(b, (params, toks, seg, pos, last))
            _, k, v = fwd(params, toks, seg, pos, last)
            rows = jnp.full((b,), scratch, jnp.int32)
            offs = jnp.zeros((b,), jnp.int32)
            self._ensure_scatter(b, (pool.k, pool.v, k, v, rows, offs))

    # -- execution --------------------------------------------------------
    def pack(self, prompts: Sequence[np.ndarray]):
        """Build the packed host arrays for one call: tokens, segment ids
        (pad = ``max_segments``), per-segment restarting positions (pad
        positions restart too, so pad rows stay finite), last-token indices
        padded with 0, and the chosen bucket."""
        if not prompts:
            raise ValueError("pack: empty prompt list")
        if len(prompts) > self.max_segments:
            raise ValueError(
                f"pack: {len(prompts)} prompts > max_segments "
                f"{self.max_segments}")
        lens = [len(p) for p in prompts]
        bucket = bucket_for(sum(lens), self.buckets)
        toks = np.zeros((1, bucket), np.int32)
        seg = np.full((1, bucket), self.max_segments, np.int32)
        pos = np.zeros((1, bucket), np.int32)
        last = np.zeros((self.max_segments,), np.int32)
        spans: List[Tuple[int, int]] = []
        off = 0
        for i, p in enumerate(prompts):
            n = len(p)
            toks[0, off:off + n] = p
            seg[0, off:off + n] = i
            pos[0, off:off + n] = np.arange(n)
            last[i] = off + n - 1
            spans.append((off, n))
            off += n
        pos[0, off:] = np.arange(bucket - off)
        return toks, seg, pos, last, spans, bucket

    def __call__(self, params, prompts: Sequence[np.ndarray]) -> PackedPrefill:
        """Run one packed prefill over ``prompts`` (each a 1-D int token
        array). Compiles lazily if the bucket was never warmed."""
        toks, seg, pos, last, spans, bucket = self.pack(prompts)
        args = (params, jnp.asarray(toks), jnp.asarray(seg),
                jnp.asarray(pos), jnp.asarray(last))
        fwd = self._ensure_fwd(bucket, args)
        logits, k, v = fwd(*args)
        return PackedPrefill(logits=logits, k=k, v=v, spans=spans,
                             bucket=bucket)

    def scatter_into(self, pool, res: PackedPrefill, rids: Sequence[int],
                     extra_tokens: Optional[Sequence[int]] = None) -> None:
        """Open each ``rid`` in ``pool``, reserve its span (plus
        ``extra_tokens[i]`` future decode tokens), commit the span length,
        and land the whole packed K/V with ONE donated scatter. Pad
        positions (and nothing else) write the scratch block."""
        if len(rids) != len(res.spans):
            raise ValueError("rids/spans length mismatch")
        scratch = pool.scratch_index if pool.scratch_index is not None else 0
        rows = np.full((res.bucket,), scratch, np.int32)
        offs = np.zeros((res.bucket,), np.int32)
        for j, (rid, (off, n)) in enumerate(zip(rids, res.spans)):
            pool.open(rid)
            pool.reserve(rid, n + (extra_tokens[j] if extra_tokens else 0))
            tbl = np.asarray(pool.table(rid), np.int32)
            t = np.arange(n)
            rows[off:off + n] = tbl[t // pool.block]
            offs[off:off + n] = t % pool.block
            pool.advance(rid, n)
        self.scatter(pool, res, rows, offs)

    def scatter(self, pool, res: PackedPrefill, rows: np.ndarray,
                offs: np.ndarray) -> None:
        """Scatter the packed K/V into the paged pool with donated buffers
        (no copy of the pool). ``rows``/``offs`` are (bucket,) int32 — the
        pool row/offset of every packed position; pad positions must point
        at the scratch block. Reassigns ``pool.k``/``pool.v``."""
        args = (pool.k, pool.v, res.k, res.v,
                jnp.asarray(rows), jnp.asarray(offs))
        fn = self._ensure_scatter(res.bucket, args)
        pool.k, pool.v = fn(*args)
