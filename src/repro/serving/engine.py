"""Continuous-batching CoE serving engine over the paged KV pool.

The paper's deployment (§V-B, §VI-C) keeps the chip busy across expert
switches: requests are routed to experts, grouped, and the switching engine
hides DDR->HBM weight copies behind decode via next-expert prefetch. A
run-to-completion scheduler loses exactly that property under load — slots
idle while stragglers finish, and the queue waits for a full drain. This
engine instead keeps a persistent decode batch:

  * every decode slot's KV lives in ``PagedKVCache`` block tables — there is
    no dense per-group cache; admission, growth and recycling are block-table
    operations (``reserve``/``advance``/``free``);
  * one jit-compiled *paged extend* step (fixed ``(n_slots, g)`` shape,
    compiled once per engine) serves any subset of slots via an active-lane
    mask — inactive lanes scatter to the pool's scratch block;
  * per-step admission: newly-arrived requests for the active expert are
    prefilled into free slots while decode continues, so the batch refills
    the moment a slot recycles; when a group exhausts, the next expert is
    chosen preferring experts already resident in the ``HBMWeightCache``
    (switch = LRU hit); an aging counter admits any request stuck behind
    that preference, so no queued expert starves;
  * next-expert prefetch: each step the most-demanded non-resident expert is
    prefetched so the eventual switch overlaps decode (paper Fig 9);
  * decode policy is pluggable on the same slot machinery: ``GreedyDecode``
    (one token per round) or ``SpeculativeDecode`` (draft-verify, §VI-B).

``scheduler="run_to_completion"`` runs the OLD semantics — admit one expert
group, decode until every request completes, drain, repeat — on the same
paged substrate, so the two schedulers differ only in scheduling. That is
the baseline of ``benchmarks/run.py --sweep-arrival``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.coe import CompositionOfExperts
from repro.obs import flightrec, trace
from repro.obs.lifecycle import LifecycleTracker
from repro.obs.metrics import MetricsRegistry
from repro.obs.slo import SLOTracker
from repro.obs.stats import StatsView, counter_field
from repro.serving.kvcache import PagedKVCache, PrefixIndex
from repro.serving.prefill import (PackedPrefillRunner, PrefillHandoff,
                                   bucket_for, default_buckets, plan_packs)
from repro.serving.sessions import SessionManager
from repro.serving.speculative import SpecStats


@dataclass(eq=False)
class Request:
    rid: int
    tokens: np.ndarray          # (S,) prompt
    max_new_tokens: int
    arrival_s: float = field(default_factory=time.perf_counter)
    expert: Optional[str] = None        # routed at submit
    # prefill state computed off-engine (disaggregated prefill group);
    # admission adopts it into a slot instead of running a prefill
    handoff: Optional["PrefillHandoff"] = None
    prefill_done_s: Optional[float] = None
    first_token_s: Optional[float] = None
    done_s: Optional[float] = None
    output: Optional[np.ndarray] = None
    skipped: int = 0                    # admission passes survived unadmitted
    # tenancy (serving/frontend.py + sessions.py): multi-turn session id
    # (retained KV adopted across turns), per-tenant accounting, SLO-aware
    # admission priority, and streaming callbacks. Callbacks run on the
    # engine's thread — keep them cheap (the frontend just enqueues).
    session_id: Optional[str] = None
    tenant: str = "default"
    priority: int = 0
    slo_ttft_s: Optional[float] = None
    slo_tpot_s: Optional[float] = None  # mean inter-token deadline (obs.slo)
    on_token: Optional[Callable[["Request", int], None]] = None
    on_done: Optional[Callable[["Request"], None]] = None
    prefix_hit_tokens: int = 0          # prompt tokens adopted, not prefilled
    # lifecycle-plane stamps/attribution (obs.lifecycle): the engine stamps
    # submit_s/admit_s/last_token_s; route_s is the router forward's cost;
    # switch_stall_s is activation time this request's admission paid;
    # preemptions counts frontend pull-backs from the engine queue
    submit_s: Optional[float] = None
    admit_s: Optional[float] = None
    last_token_s: Optional[float] = None
    route_s: float = 0.0
    switch_stall_s: float = 0.0
    preemptions: int = 0

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.done_s is None else self.done_s - self.arrival_s


@dataclass
class _Slot:
    req: Request
    expert: str
    last_token: int                     # next decode input
    generated: List[int]
    admitted_step: int

    @property
    def remaining(self) -> int:
        return self.req.max_new_tokens - len(self.generated)


class ServeStats(StatsView):
    """Engine counters as a view over the metrics registry (``serve.*``
    series). Field semantics unchanged from the old dataclass."""

    PREFIX = "serve"
    DERIVED = ("tokens_per_second", "mean_occupancy")

    requests = counter_field()
    tokens_out = counter_field()
    admitted = counter_field()
    decode_rounds = counter_field()
    switches = counter_field()
    starvation_overrides = counter_field()
    prefix_hit_tokens = counter_field()  # prompt tokens served from shared KV
    occupancy_sum = counter_field(0.0)  # Σ active_slots/n_slots per round
    route_s = counter_field(0.0)
    switch_s = counter_field(0.0)
    prefill_s = counter_field(0.0)
    exec_s = counter_field(0.0)

    @property
    def tokens_per_second(self):
        t = self.switch_s + self.exec_s + self.prefill_s
        return self.tokens_out / t if t else 0.0

    @property
    def mean_occupancy(self):
        return self.occupancy_sum / max(self.decode_rounds, 1)


# ----------------------------------------------------------------------
# Paged model execution (compiled once per (n_slots, g) shape).
# The step bodies and the runner live in serving/backends.py behind the
# backend seam; the names are re-exported here for compatibility.
# ----------------------------------------------------------------------

from repro.serving.backends import (PagedDecodeRunner, make_runner,  # noqa: E402
                                    xla_paged_extend as _paged_extend)


class _DeviceTableCache:
    """Cached device uploads of the per-slot block tables / lengths.

    The decode loop used to rebuild and re-upload ``tables``/``lengths``
    host arrays every round even when no slot changed. The pool versions
    its host bookkeeping (``table_version``/``length_version``), so the
    device copies are rebuilt only when the backing state moved or the
    slot->request mapping changed. Steady-state greedy rounds re-upload
    only lengths; the speculative draft loop (gamma extends against
    unchanged tables, lengths offset device-side) hits the cache for both.
    Cached arrays are never donated by the extend step (only the pool
    arrays are), so reuse across rounds is safe."""

    def __init__(self, pool: PagedKVCache, max_blocks: int,
                 empty_table: np.ndarray):
        self.pool = pool
        self.max_blocks = max_blocks
        self._empty = empty_table
        self._tab_key = None
        self._len_key = None
        self._tables = None
        self._lengths = None

    def tables(self, rids: Tuple[Optional[int], ...]):
        key = (self.pool.table_version, rids)
        if key != self._tab_key:
            self._tables = jnp.asarray(np.stack([
                self.pool.padded_table(r, self.max_blocks)
                if r is not None else self._empty for r in rids]))
            self._tab_key = key
        return self._tables

    def lengths(self, rids: Tuple[Optional[int], ...]):
        key = (self.pool.length_version, rids)
        if key != self._len_key:
            self._lengths = jnp.asarray(np.array(
                [self.pool.length(r) if r is not None else 0 for r in rids],
                np.int32))
            self._len_key = key
        return self._lengths


# ----------------------------------------------------------------------
# Decode policies (pluggable on the slot machinery)
# ----------------------------------------------------------------------

class GreedyDecode:
    """One argmax token per active slot per round."""

    name = "greedy"
    reserve_slack = 0                   # extra tokens reserved beyond output

    def bind(self, engine: "ServingEngine"):
        self.engine = engine

    def on_admit(self, slot_idx: int, req: Request, params):
        pass

    def on_free(self, rid: int):
        pass

    def round(self, params, active: np.ndarray) -> Dict[int, List[int]]:
        eng = self.engine
        toks = np.zeros((eng.n_slots, 1), np.int32)
        for i in np.nonzero(active)[0]:
            # blocks were fully reserved at admission; only tokens needed
            toks[i, 0] = eng.slots[i].last_token
        tables, lengths = eng._device_tables()
        logits, pk, pv = eng.runner.extend(params, eng.pool.k, eng.pool.v,
                                           tables, lengths,
                                           eng._device_active(active), toks)
        eng.pool.k, eng.pool.v = pk, pv
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        return {int(i): [int(nxt[i])] for i in np.nonzero(active)[0]}


class SpeculativeDecode:
    """Draft-verify decode (paper §VI-B) on the paged slot machinery.

    A small shared draft expert proposes ``gamma`` tokens per slot; the
    target expert verifies all of them in ONE paged extend; the longest
    matching prefix plus one corrected token is emitted — with greedy
    acceptance the output is token-for-token identical to ``GreedyDecode``.
    The draft keeps its own paged pool, with block tables mirroring the
    target's slots. In a CoE the draft is simply another (small) composition
    member kept resident in HBM alongside the active target (§VI-B).

    Provisioning note: the draft pool (``d_pool``, same block count as the
    target pool but draft-sized blocks) and the draft weights are allocated
    IN ADDITION to the engine's pool — when planning an ``HBMBudget`` for a
    speculative deployment, count ``d_pool.capacity_bytes()`` and the draft
    weights against the tier yourself; the kv_reserve carve only covers the
    target pool.
    """

    name = "speculative"

    def __init__(self, draft_cfg: ModelConfig, draft_host_params,
                 gamma: int = 4):
        self.draft_cfg = draft_cfg
        self.gamma = gamma
        self.reserve_slack = gamma
        self._draft_host = draft_host_params
        self.stats = SpecStats()

    def bind(self, engine: "ServingEngine"):
        if self.draft_cfg.vocab_size != engine.cfg.vocab_size:
            raise ValueError("draft/target vocab mismatch")
        self.engine = engine
        self.d_params = jax.device_put(self._draft_host)
        self.d_pool = PagedKVCache(
            engine.pool.n_blocks, engine.block,
            self.draft_cfg.n_layers, self.draft_cfg.n_kv_heads,
            self.draft_cfg.head_dim, dtype=engine.pool.k.dtype, scratch=True)
        # the draft inherits the engine's backend, so a fused deployment
        # runs its single-token draft loop — the speculative hot path —
        # through the same Pallas kernels as the target
        self.d_runner = make_runner(self.draft_cfg, self.d_pool.scratch_index,
                                    backend=engine.runner.backend_name)
        self._d_dev = _DeviceTableCache(self.d_pool, engine.max_blocks,
                                        engine._empty_table)

    def on_admit(self, slot_idx: int, req: Request, params):
        # draft prefills the same prompt into its own pool
        self.d_pool.open(req.rid)
        _, k, v = self.d_runner.prefill_kv(self.d_params,
                                           jnp.asarray(req.tokens[None]))
        self.d_pool.append(req.rid, k, v)
        self.d_pool.reserve(req.rid, req.max_new_tokens + self.gamma)

    def on_free(self, rid: int):
        self.d_pool.free(rid)

    def round(self, params, active: np.ndarray) -> Dict[int, List[int]]:
        eng = self.engine
        B, g = eng.n_slots, self.gamma
        rows = np.nonzero(active)[0]
        cur = np.zeros((B, 1), np.int32)
        for i in rows:
            # both pools were fully reserved (incl. gamma slack) at admission
            cur[i, 0] = eng.slots[i].last_token

        tables, lengths = eng._device_tables()
        dact = eng._device_active(active)
        rids = eng._slot_rids()
        d_tables = self._d_dev.tables(rids)

        # --- draft proposes gamma tokens autoregressively
        props = np.zeros((B, g), np.int32)
        d_in = cur
        for t in range(g):
            lg, dk, dv = self.d_runner.extend(
                self.d_params, self.d_pool.k, self.d_pool.v,
                d_tables, lengths + t, dact, d_in)
            self.d_pool.k, self.d_pool.v = dk, dv
            d_in = np.asarray(jnp.argmax(lg[:, -1], -1), np.int32)[:, None]
            props[:, t] = d_in[:, 0]
            self.stats.draft_calls += 1

        # --- target verifies all gamma in one paged extend
        prop_inputs = np.concatenate([cur, props[:, :-1]], axis=1)   # (B,g)
        t_lg, pk, pv = eng.runner.extend(params, eng.pool.k, eng.pool.v,
                                         tables, lengths, dact, prop_inputs)
        eng.pool.k, eng.pool.v = pk, pv
        self.stats.target_calls += 1
        t_next = np.asarray(jnp.argmax(t_lg, -1), np.int32)          # (B,g)

        emits: Dict[int, List[int]] = {}
        for i in rows:
            match = props[i] == t_next[i]
            prefix = 0
            while prefix < g and match[prefix]:
                prefix += 1
            self.stats.proposed += g
            self.stats.accepted += prefix
            e = min(prefix + 1, g, eng.slots[i].remaining)
            emits[int(i)] = [int(x) for x in t_next[i, :e]]
            self.d_pool.advance(eng.slots[i].req.rid, e)
        return emits


# ----------------------------------------------------------------------
# The engine
# ----------------------------------------------------------------------

class ServingEngine:
    """Continuous-batching scheduler over the paged KV pool.

    ``step()`` is one scheduler iteration: pick/keep the active expert,
    admit newly-arrived requests into free slots (prefill), prefetch the
    next-most-demanded expert, run one decode round for the active expert's
    slots, recycle completed slots. ``drain()`` loops until idle.
    """

    def __init__(self, coe: CompositionOfExperts, cfg: ModelConfig, *,
                 max_len: int = 4096, n_slots: int = 8, block_size: int = 16,
                 kv_budget_bytes: Optional[int] = None,
                 policy=None, scheduler: str = "continuous",
                 switch_quantum: int = 8, starvation_limit: int = 16,
                 runner: Optional[PagedDecodeRunner] = None,
                 runner_factory=None,
                 backend: Optional[str] = None,
                 prefill_mode: str = "packed",
                 prefill_buckets: Optional[Sequence[int]] = None,
                 prefill_max_segments: Optional[int] = None,
                 prefix_sharing: bool = False,
                 session_max_bytes: Optional[int] = None,
                 kv_dtype=jnp.bfloat16,
                 registry: Optional[MetricsRegistry] = None,
                 obs_labels: Optional[Dict[str, Any]] = None):
        if scheduler not in ("continuous", "run_to_completion"):
            raise ValueError(f"unknown scheduler {scheduler!r}")
        if prefill_mode not in ("packed", "sequential"):
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        self.coe = coe
        self.cfg = cfg
        self.max_len = max_len
        self.n_slots = n_slots
        self.block = block_size
        self.scheduler = scheduler
        self.switch_quantum = switch_quantum
        self.starvation_limit = starvation_limit
        self.policy = policy or GreedyDecode()
        self.max_blocks = -(-(max_len + self.policy.reserve_slack)
                            // block_size)

        if kv_budget_bytes is None:
            # default: every slot can hold a full-length request, + scratch.
            # Under prefix sharing the index and retained sessions hold
            # blocks BETWEEN requests; sized only for the slots, retention
            # would compete with admission permanently (backpressure then
            # trickles admits in one at a time and decode occupancy
            # collapses), so the shared pool gets 2x the slot capacity —
            # retention lives in the slack and is still reclaimed, via the
            # pool's reclaimer protocol, whenever admission really needs it
            slot_blocks = self.n_slots * self.max_blocks
            pool_blocks = slot_blocks * (2 if prefix_sharing else 1) + 1
            kv_budget_bytes = coe.hbm_budget.kv_bytes or (
                pool_blocks * PagedKVCache.block_bytes(
                    block_size, cfg.n_layers, cfg.n_kv_heads, cfg.head_dim,
                    kv_dtype))
        # one registry backs the engine's ServeStats and the pool's
        # PagedStats (private unless the caller publishes a shared one —
        # serve.py --metrics-port and RDUNode, which labels per group)
        self._registry = registry if registry is not None else MetricsRegistry()
        self._obs_labels = dict(obs_labels or {})
        self.pool = PagedKVCache.for_budget(
            kv_budget_bytes, block_size, cfg.n_layers, cfg.n_kv_heads,
            cfg.head_dim, kv_dtype, scratch=True,
            registry=self._registry, labels=self._obs_labels)
        self._empty_table = np.full((self.max_blocks,),
                                    self.pool.scratch_index, np.int32)
        # runner_factory lets a caller supply a runner that needs the pool's
        # scratch row without duplicating the pool-sizing logic above (the
        # node subsystem injects its tensor-parallel runner this way);
        # backend selects the decode-step implementation ('xla'/'fused',
        # see serving/backends.py) and is forwarded to the factory
        if runner is None:
            factory = runner_factory or PagedDecodeRunner
            kw = {} if backend is None else {"backend": backend}
            self.runner = factory(cfg, self.pool.scratch_index, **kw)
        else:
            if backend is not None and runner.backend_name != backend:
                raise ValueError(
                    f"shared runner executes backend "
                    f"{runner.backend_name!r}, engine asked for {backend!r}")
            self.runner = runner
        if self.runner.scratch_row != self.pool.scratch_index:
            raise ValueError(
                "shared runner was compiled for a different pool size "
                f"(scratch row {self.runner.scratch_row} != "
                f"{self.pool.scratch_index})")
        self._dev_tables = _DeviceTableCache(self.pool, self.max_blocks,
                                             self._empty_table)
        self._active_cache: Optional[Tuple[np.ndarray, jnp.ndarray]] = None
        # packed prefill: bucketed AOT-compiled forwards (serving/prefill.py)
        # shared by every expert; admission batches pending admits into one
        # packed call per (expert, bucket) instead of N sequential prefills.
        # "sequential" keeps the per-prompt prefill_kv path (one jit per
        # novel length — the recompile-stall baseline the benchmark sweeps).
        self.prefill_mode = prefill_mode
        if prefill_mode == "packed":
            self.prefill_runner: Optional[PackedPrefillRunner] = \
                PackedPrefillRunner(
                    cfg,
                    buckets=prefill_buckets or default_buckets(max_len),
                    max_segments=prefill_max_segments or n_slots)
        else:
            self.prefill_runner = None
        # copy-on-write prefix sharing + multi-turn session retention: a
        # PrefixIndex over the pool's blocks dedups prompts shared across
        # requests; a SessionManager keeps finished turns' pages resident
        # for the session's next turn. Both hold pool blocks speculatively
        # and hand them back under admission pressure via the pool's
        # reclaimer protocol — KV pages competing for the HBM tier exactly
        # like expert weights compete in the weight cache.
        self.prefix_sharing = prefix_sharing
        if prefix_sharing:
            self.sessions: Optional[SessionManager] = SessionManager(
                self.pool, ledger=coe.cache.ledger,
                max_bytes=session_max_bytes)
            self.prefix_index: Optional[PrefixIndex] = PrefixIndex(self.pool)
            # sessions reclaim first: one conversation's pages are cheaper
            # to lose than a prefix shared across many live sessions
            self.pool.add_reclaimer(self.sessions)
            self.pool.add_reclaimer(self.prefix_index)
            # suffix prefill rides the decode extend at these widths
            self._suffix_buckets: Tuple[int, ...] = tuple(
                prefill_buckets or default_buckets(max_len))
        else:
            self.sessions = None
            self.prefix_index = None
        # TTFT (arrival -> first token) was stored per request but never
        # aggregated; it now lands in a P2 streaming histogram. TPOT (mean
        # inter-token seconds after the first token) is the decode-side
        # half of the SLO pair and gets its own histogram.
        self._ttft_hist = self._registry.histogram("serve.ttft_s",
                                                   labels=self._obs_labels)
        self._tpot_hist = self._registry.histogram("serve.tpot_s",
                                                   labels=self._obs_labels)
        # request-lifecycle plane: per-request phase ledger + SLO/goodput
        # accounting, both fed at _finish (obs.lifecycle / obs.slo)
        self.lifecycle = LifecycleTracker(self._registry,
                                          labels=self._obs_labels)
        self.slo = SLOTracker(self._registry, labels=self._obs_labels)
        # /readyz readiness: False until warmup() AOT-compiled the hot path
        self.warmed = False
        # info-style gauge: which decode backend this engine executes
        self._registry.gauge("serve.backend", labels={
            **self._obs_labels,
            "backend": self.runner.backend_name}).set(1.0)
        self.policy.bind(self)

        self.queue: List[Request] = []
        self.slots: List[Optional[_Slot]] = [None] * n_slots
        self.stats = ServeStats(registry=self._registry,
                                labels=self._obs_labels)
        self._active_expert: Optional[str] = None
        self._quantum_used = 0
        self._step_count = 0

    # -- public API -------------------------------------------------------
    def submit(self, req: Request):
        """Enqueue a request. An untagged request (``expert=None``) is routed
        through the composition's router once, at arrival (§II); a request
        already tagged by an upstream router (e.g. the node scheduler) keeps
        its tag — routing happens exactly once either way."""
        if req.submit_s is None:         # keep the first stamp on re-submits
            req.submit_s = time.perf_counter()
        S = len(req.tokens)
        need = S + req.max_new_tokens + self.policy.reserve_slack
        if need > self.max_blocks * self.block:
            raise ValueError(
                f"request {req.rid}: {need} tokens exceed engine max_len "
                f"{self.max_len}")
        if -(-need // self.block) > self.pool.n_blocks:
            raise ValueError(
                f"request {req.rid} needs more KV blocks than the pool owns")
        if req.expert is None:
            with trace.span("route", cat="engine", request_id=req.rid) as sp:
                req.expert, dt = self.coe.route_request(req.tokens)
                sp.add(expert=req.expert)
            self.stats.route_s += dt
            req.route_s = dt
        elif req.expert not in self.coe.experts:
            raise KeyError(
                f"request {req.rid}: unknown expert {req.expert!r}")
        # one async lane per request: submit -> ... -> done (closed by
        # _finish, possibly many scheduler steps later)
        trace.async_begin("request", id=req.rid, cat="engine",
                          expert=req.expert, prompt_tokens=len(req.tokens))
        self.queue.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def step(self) -> List[Request]:
        """One scheduler iteration; returns requests completed in it."""
        self._step_count += 1
        done: List[Request] = []
        with trace.span("step", cat="engine", step=self._step_count) as sp:
            name = self._pick_expert()
            if name is None:
                return done
            if name != self._active_expert:
                self._switch_to(name)
            self._admit(done)
            self._prefetch_next()
            active = np.array([s is not None
                               and s.expert == self._active_expert
                               for s in self.slots], bool)
            if active.any():
                self._decode_round(active, done)
            self._quantum_used += 1
            self.stats.requests += len(done)
            sp.add(expert=self._active_expert, completed=len(done))
        return done

    def drain(self, max_steps: int = 1_000_000) -> List[Request]:
        """Run until queue and slots are empty; returns all completions.
        (Per-request pool-fit is enforced at ``submit``, so every queued
        request is eventually admissible and the loop terminates.)"""
        out: List[Request] = []
        steps = 0
        while self.has_work:
            out.extend(self.step())
            steps += 1
            if steps >= max_steps:
                raise RuntimeError("drain: exceeded max_steps")
        return out

    def warmup(self, expert: Optional[str] = None) -> None:
        """AOT-compile the serving hot path before traffic arrives: every
        packed-prefill bucket + its donated pool scatter, and the greedy
        decode extend for this engine's slot shape. All experts share the
        backbone, so compiling against one expert's params covers the whole
        composition — after this, a mixed-length greedy burst triggers zero
        XLA compilations (tests/test_prefill.py enforces it via the
        ``prefill.record_compile`` hook). Speculative deployments still pay
        the draft model's own first-shape compiles."""
        names = self.coe.expert_names()
        if not names:
            raise RuntimeError("warmup: no experts registered")
        name = expert if expert is not None else (self._active_expert
                                                 or names[0])
        t0 = time.perf_counter()
        params = self.coe.cache.activate(name)
        self.stats.switch_s += time.perf_counter() - t0
        with trace.span("warmup", cat="engine", expert=name):
            if self.prefill_runner is not None:
                self.prefill_runner.warmup(params, self.pool)
            # one all-inactive extend compiles + runs the (n_slots, 1) step;
            # garbage K/V lands in the scratch block, the pool arrays are
            # donated and reassigned exactly like a real round
            tables = jnp.asarray(np.stack([self._empty_table] * self.n_slots))
            lengths = jnp.zeros((self.n_slots,), jnp.int32)
            active = jnp.zeros((self.n_slots,), bool)
            toks = np.zeros((self.n_slots, 1), np.int32)
            logits, pk, pv = self.runner.extend(
                params, self.pool.k, self.pool.v, tables, lengths, active,
                toks)
            self.pool.k, self.pool.v = pk, pv
            jnp.argmax(logits[:, -1], axis=-1).block_until_ready()
            if self.prefix_sharing:
                # suffix prefill (prefix hits) runs the decode extend at
                # bucket widths — compile each all-inactive now so a hit
                # never pays a mid-traffic XLA compile
                for g in self._suffix_buckets:
                    toks = np.zeros((self.n_slots, g), np.int32)
                    logits, pk, pv = self.runner.extend(
                        params, self.pool.k, self.pool.v, tables, lengths,
                        active, toks)
                    self.pool.k, self.pool.v = pk, pv
                    logits.block_until_ready()
        self.warmed = True               # /readyz flips to 200

    # -- scheduling internals --------------------------------------------
    def _blocks_for(self, req: Request) -> int:
        need = (len(req.tokens) + req.max_new_tokens
                + self.policy.reserve_slack)
        return -(-need // self.block)

    def _planned_blocks(self, req: Request) -> int:
        # +1 headroom under sharing: adopting a shared partial tail block
        # can COW-split into one extra fresh block beyond the request's own
        # need (a hit otherwise needs strictly fewer fresh blocks)
        return self._blocks_for(req) + (1 if self.prefix_sharing else 0)

    def _avail_blocks(self) -> int:
        # retained sessions and indexed prefixes hand blocks back under
        # pressure — gating admission on the free list alone would wedge
        # the scheduler the moment retention fills the pool
        n = self.pool.free_blocks
        if self.prefix_sharing:
            n += self.pool.reclaimable_blocks()
        return n

    def _any_active(self) -> bool:
        return any(s is not None for s in self.slots)

    def _pick_expert(self) -> Optional[str]:
        occupied: Dict[str, List[_Slot]] = {}
        for s in self.slots:
            if s is not None:
                occupied.setdefault(s.expert, []).append(s)
        if self.scheduler == "run_to_completion":
            if occupied:
                return self._active_expert
            return self.queue[0].expert if self.queue else None
        if self._active_expert in occupied:
            # rotate ONLY among experts with slots ready to decode — leaving
            # a live batch for a queue-only expert would abandon admitted
            # work and thrash the weight cache; queue-only experts get in
            # via admission prebatching or the starvation override.
            others = [e for e in occupied if e != self._active_expert]
            if self._quantum_used < self.switch_quantum or not others:
                return self._active_expert
            return min(others, key=lambda e: min(    # longest-waiting batch
                s.admitted_step for s in occupied[e]))
        if occupied:         # active expert drained: longest-waiting slots
            return min(occupied, key=lambda e: min(
                s.admitted_step for s in occupied[e]))
        if not self.queue:
            return None                  # no slots, no queue: idle
        # choose from the queue: starving first, then stall-free (resident
        # OR fully-landed prefetch — admission consults the async pipeline's
        # readiness, not just residency), then FIFO
        starving = [r for r in self.queue if r.skipped >= self.starvation_limit]
        if starving:
            self.stats.starvation_overrides += 1
            return starving[0].expert
        ready = [r for r in self.queue if self.coe.cache.ready(r.expert)]
        pick_from = ready or self.queue
        demand: Dict[str, int] = {}
        for r in pick_from:
            demand[r.expert] = demand.get(r.expert, 0) + 1
        return max(demand, key=demand.get)

    def _switch_to(self, name: str):
        t0 = time.perf_counter()
        with trace.span("switch", cat="engine", expert=name,
                        prev=self._active_expert):
            self._params = self.coe.cache.activate(name)
        dt = time.perf_counter() - t0
        self.stats.switch_s += dt
        flightrec.record("switch", expert=name, prev=self._active_expert,
                         stall_s=dt, **self._obs_labels)
        if self._active_expert is not None:
            self.stats.switches += 1
        self._active_expert = name
        self._quantum_used = 0

    def _admit(self, done: List[Request]):
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free or not self.queue:
            return
        if self.scheduler == "run_to_completion":
            if any(s is not None for s in self.slots):
                return                       # batch still running: no refill
            candidates = [r for r in self.queue
                          if r.expert == self._active_expert]
        else:
            # refill ONLY from the active expert's queue: one expert's
            # weights are live at a time, so a foreign-expert slot would sit
            # idle and shrink every decode batch it rides in. Other experts
            # get in when the active group exhausts (group selection in
            # _pick_expert prefers resident experts) — except requests aged
            # past the starvation limit, which are admitted unconditionally.
            starving = [r for r in self.queue
                        if r.skipped >= self.starvation_limit]
            active_reqs = [r for r in self.queue
                           if r.expert == self._active_expert
                           and r not in starving]
            candidates = starving + active_reqs
        # backpressure always admits at least one request while the engine
        # is otherwise idle: under sharing the conservative reclaimable
        # estimate can undercount cascade reclaim (session eviction exposes
        # index leaves), and stalling an idle engine would never recover
        if self.prefill_runner is None:
            admitted = []
            for r in candidates:
                if not free:
                    break
                if (self._planned_blocks(r) > self._avail_blocks()
                        and (admitted or self._any_active())):
                    break                    # KV backpressure: stop admitting
                if r.handoff is not None:
                    self._adopt_into_slot(free.pop(0), r, done)
                else:
                    m = self._match_prefix(r)
                    if m is not None:
                        t0 = time.perf_counter()
                        params = self.coe.cache.activate(r.expert)
                        if (r.expert != self._active_expert
                                and self._active_expert is not None):
                            self._params = self.coe.cache.activate(
                                self._active_expert)
                        dt = time.perf_counter() - t0
                        self.stats.switch_s += dt
                        r.switch_stall_s += dt
                        self._prefill_suffix([(r, m[0], m[1])], params,
                                             free, done)
                    else:
                        self._prefill_into_slot(free.pop(0), r, done)
                admitted.append(r)
        else:
            # packed admission: select this step's admits first (slot count
            # + planned-block backpressure, same break semantics as the
            # sequential loop), then run ONE packed prefill per
            # (expert, bucket-capacity chunk) instead of N sequential calls
            admitted = []
            planned = 0
            for r in candidates:
                if len(admitted) >= len(free):
                    break
                need = self._planned_blocks(r)
                if (planned + need > self._avail_blocks()
                        and (admitted or self._any_active())):
                    break                    # KV backpressure: stop admitting
                admitted.append(r)
                planned += need
            if admitted:
                self._admit_packed(admitted, free, done)
        if admitted:
            # age only requests passed over while the active group consumed
            # admission capacity — idle tail steps (free slots, nothing to
            # admit) are not preference and must not trip the override
            for r in self.queue:
                if r not in admitted:
                    r.skipped += 1
        self.queue = [r for r in self.queue if r not in admitted]

    def _prefill_into_slot(self, slot_idx: int, req: Request,
                           done: List[Request]):
        req.admit_s = time.perf_counter()
        trace.instant("admit", cat="engine", request_id=req.rid,
                      expert=req.expert, slot=slot_idx)
        flightrec.record("admit", rid=req.rid, expert=req.expert,
                         slot=slot_idx, **self._obs_labels)
        t0 = time.perf_counter()
        params = self.coe.cache.activate(req.expert)
        if (req.expert != self._active_expert
                and self._active_expert is not None):
            # a foreign (starving) admission may have evicted the decoding
            # expert; re-activate so residency, LRU order and the hit/miss
            # stats keep describing what is actually executing
            self._params = self.coe.cache.activate(self._active_expert)
        dt = time.perf_counter() - t0
        self.stats.switch_s += dt
        req.switch_stall_s += dt
        t0 = time.perf_counter()
        S = len(req.tokens)
        with trace.span("prefill", cat="engine", request_id=req.rid,
                        expert=req.expert, prompt_tokens=S,
                        **{"prefill.bucket": S, "prefill.packed": 0}):
            last, k, v = self.runner.prefill_kv(params,
                                                jnp.asarray(req.tokens[None]))
            first = int(jnp.argmax(last))
            self.pool.open(req.rid)
            self.pool.append(req.rid, k, v)
            # commit the request's whole block budget now so admission's
            # free_blocks check can never over-admit into mid-decode
            # exhaustion
            self.pool.reserve(req.rid,
                              req.max_new_tokens + self.policy.reserve_slack)
        self.stats.prefill_s += time.perf_counter() - t0
        # sequential prefill runs at the raw prompt length: the bucket
        # label IS the length (the packed path labels real buckets)
        self._registry.counter("serve.prefill_bucket", labels={
            **self._obs_labels, "bucket": S}).inc()
        self._slot_ready(slot_idx, req, int(first), params, done)

    def _admit_packed(self, reqs: List[Request], free: List[int],
                      done: List[Request]):
        """Admit this step's selected requests via packed prefill: adopt
        handed-off state first (no forward needed), then group the rest by
        expert (selection order preserved — starving before active) and run
        one packed call per bucket-capacity chunk."""
        now = time.perf_counter()
        for r in reqs:
            if r.admit_s is None:
                r.admit_s = now
        todo: List[Request] = []
        for r in reqs:
            if r.handoff is not None:
                self._adopt_into_slot(free.pop(0), r, done)
            else:
                todo.append(r)
        groups: Dict[str, List[Request]] = {}
        for r in todo:
            groups.setdefault(r.expert, []).append(r)
        foreign = False
        pr = self.prefill_runner
        for expert, rs in groups.items():
            t0 = time.perf_counter()
            params = self.coe.cache.activate(expert)
            dt = time.perf_counter() - t0
            self.stats.switch_s += dt
            for r in rs:                 # activation stall split pro rata
                r.switch_stall_s += dt / len(rs)
            if expert != self._active_expert:
                foreign = True
            # prefix hits prefill only their un-shared suffix (one extend
            # per n_slots-sized chunk); misses take the packed-bucket path
            hits: List[Tuple[Request, List[int], int]] = []
            misses: List[Request] = []
            for r in rs:
                m = self._match_prefix(r)
                if m is not None:
                    hits.append((r, m[0], m[1]))
                else:
                    misses.append(r)
            for c in range(0, len(hits), self.n_slots):
                self._prefill_suffix(hits[c:c + self.n_slots], params,
                                     free, done)
            for idx in plan_packs([len(r.tokens) for r in misses],
                                  pr.buckets, pr.max_segments):
                self._prefill_chunk([misses[i] for i in idx], params, free,
                                    done)
        if foreign and self._active_expert is not None:
            # a foreign (starving) admission may have evicted the decoding
            # expert; re-activate once for the whole batch (same invariant
            # as the sequential path, minus per-request churn)
            t0 = time.perf_counter()
            self._params = self.coe.cache.activate(self._active_expert)
            self.stats.switch_s += time.perf_counter() - t0

    def _prefill_chunk(self, reqs: List[Request], params, free: List[int],
                       done: List[Request]):
        """One packed prefill call: forward at the bucket shape, per-request
        pool bookkeeping, one donated scatter for the whole bucket."""
        for r in reqs:
            trace.instant("admit", cat="engine", request_id=r.rid,
                          expert=r.expert, slot=-1)
            flightrec.record("admit", rid=r.rid, expert=r.expert,
                             packed=len(reqs), **self._obs_labels)
        t0 = time.perf_counter()
        with trace.span("prefill", cat="engine",
                        request_ids=",".join(str(r.rid) for r in reqs),
                        expert=reqs[0].expert,
                        prompt_tokens=sum(len(r.tokens) for r in reqs),
                        **{"prefill.packed": len(reqs)}) as sp:
            res = self.prefill_runner(params, [r.tokens for r in reqs])
            sp.add(**{"prefill.bucket": res.bucket})
            firsts = np.asarray(jnp.argmax(res.logits[:len(reqs)], axis=-1),
                                np.int32)
            # reserve prompt + whole output budget up front (same
            # over-admission guard as the sequential path)
            self.prefill_runner.scatter_into(
                self.pool, res, [r.rid for r in reqs],
                extra_tokens=[r.max_new_tokens + self.policy.reserve_slack
                              for r in reqs])
        self.stats.prefill_s += time.perf_counter() - t0
        self._registry.counter("serve.prefill_bucket", labels={
            **self._obs_labels, "bucket": res.bucket}).inc(len(reqs))
        for i, r in enumerate(reqs):
            self._slot_ready(free.pop(0), r, int(firsts[i]), params, done)

    def _match_prefix(
            self, req: Request) -> Optional[Tuple[List[int], int]]:
        """Longest reusable KV prefix for this request: its own session's
        retained pages first (the whole previous conversation — the longest
        possible match), then the cross-request prefix index. Returns
        PINNED ``(blocks, n_tokens)`` (``_prefill_suffix`` adopts then
        unpins) or ``None``."""
        if not self.prefix_sharing or req.handoff is not None:
            return None
        if len(req.tokens) < 2:
            return None      # nothing shareable: >= 1 suffix token must run
        if req.session_id is not None:
            m = self.sessions.adopt(req.session_id, req.expert, req.tokens)
            if m is not None:
                return m
        return self.prefix_index.match(req.expert, req.tokens)

    def _prefill_suffix(self,
                        items: List[Tuple[Request, List[int], int]],
                        params, free: List[int], done: List[Request]):
        """Admit prefix-hit requests by prefilling ONLY the un-shared
        suffix: each request is seated read-only on its adopted blocks
        (first tail write COW-splits) and the suffixes run through the
        decode extend at the smallest bucket covering the longest one —
        the shared tokens' forward is skipped entirely, the tentpole win.

        ``items`` holds up to ``n_slots`` ``(req, blocks, n_adopted)``
        triples for ONE expert, blocks pinned by ``_match_prefix``. Lanes
        past a short suffix write garbage K/V — beyond the reserved blocks
        it lands in table padding (the scratch row); inside the reserved
        slack it sits past the committed length, where decode overwrites
        before it ever attends (scatter-then-attend)."""
        t0 = time.perf_counter()
        lanes: List[Tuple[Request, int, int]] = []
        for req, blocks, n in items:
            if req.admit_s is None:
                req.admit_s = t0
            flightrec.record("admit", rid=req.rid, expert=req.expert,
                             prefix_hit=n, **self._obs_labels)
            self.pool.open(req.rid, adopt=blocks, adopt_len=n)
            self.pool.unpin(blocks)
            si = len(req.tokens) - n
            # whole remaining budget up front, same over-admission guard as
            # the full-prefill paths; reserve COW-splits a shared tail
            self.pool.reserve(req.rid, si + req.max_new_tokens
                              + self.policy.reserve_slack)
            lanes.append((req, n, si))
        g = bucket_for(max(si for _, _, si in lanes), self._suffix_buckets)
        toks = np.zeros((self.n_slots, g), np.int32)
        lengths = np.zeros((self.n_slots,), np.int32)
        tables = np.stack([self._empty_table] * self.n_slots)
        active = np.zeros((self.n_slots,), bool)
        for i, (req, n, si) in enumerate(lanes):
            toks[i, :si] = req.tokens[n:]
            lengths[i] = n
            tables[i] = self.pool.padded_table(req.rid, self.max_blocks)
            active[i] = True
        with trace.span("prefill_suffix", cat="engine",
                        request_ids=",".join(str(r.rid)
                                             for r, _, _ in lanes),
                        expert=lanes[0][0].expert,
                        shared_tokens=sum(n for _, n, _ in lanes),
                        **{"prefill.bucket": g,
                           "prefill.packed": len(lanes)}):
            logits, pk, pv = self.runner.extend(
                params, self.pool.k, self.pool.v, jnp.asarray(tables),
                jnp.asarray(lengths), jnp.asarray(active), toks)
            self.pool.k, self.pool.v = pk, pv
            nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self.stats.prefill_s += time.perf_counter() - t0
        self._registry.counter("serve.prefill_bucket", labels={
            **self._obs_labels, "bucket": g}).inc(len(lanes))
        for i, (req, n, si) in enumerate(lanes):
            self.pool.advance(req.rid, si)
            req.prefix_hit_tokens = n
            self.stats.prefix_hit_tokens += n
            self._slot_ready(free.pop(0), req, int(nxt[i, si - 1]),
                             params, done)

    def _adopt_into_slot(self, slot_idx: int, req: Request,
                         done: List[Request]):
        """Adopt prefill state computed by a disaggregated prefill group:
        append the handed-off K/V blocks into this engine's pool and seat
        the request as if it had just been prefilled locally. No forward
        runs and no expert activation is needed — the handoff already
        carries the first token."""
        trace.instant("admit", cat="engine", request_id=req.rid,
                      expert=req.expert, slot=slot_idx, handoff=1)
        h = req.handoff
        t0 = time.perf_counter()
        if req.admit_s is None:
            req.admit_s = t0
        flightrec.record("handoff", rid=req.rid, expert=req.expert,
                         slot=slot_idx, kv_bytes=h.nbytes(),
                         **self._obs_labels)
        with trace.span("adopt_handoff", cat="engine", request_id=req.rid,
                        expert=req.expert, prompt_tokens=len(req.tokens),
                        kv_bytes=h.nbytes()):
            self.pool.open(req.rid)
            self.pool.append(req.rid, jnp.asarray(h.k), jnp.asarray(h.v))
            self.pool.reserve(req.rid,
                              req.max_new_tokens + self.policy.reserve_slack)
        self.stats.prefill_s += time.perf_counter() - t0
        req.handoff = None                   # blocks landed; drop the copy
        self._slot_ready(slot_idx, req, h.first_token, None, done)

    def _slot_ready(self, slot_idx: int, req: Request, first: int, params,
                    done: List[Request]):
        """Shared admission tail: timestamps, TTFT histogram, slot seating,
        policy callback, immediate finish for max_new_tokens == 1."""
        now = time.perf_counter()
        if req.admit_s is None:              # paths that bypassed _admit
            req.admit_s = now
        if req.prefill_done_s is None:       # handoffs carry their own stamp
            req.prefill_done_s = now
        if req.first_token_s is None:
            req.first_token_s = now
            self._ttft_hist.observe(req.first_token_s - req.arrival_s)
        req.last_token_s = now               # watchdog stall baseline
        self.stats.admitted += 1
        self.stats.tokens_out += 1
        if req.on_token is not None:
            req.on_token(req, first)
        slot = _Slot(req=req, expert=req.expert, last_token=first,
                     generated=[first], admitted_step=self._step_count)
        # admit on the policy before any possible _finish: on_free must only
        # ever see rids that on_admit opened (e.g. the speculative draft pool)
        self.policy.on_admit(slot_idx, req, params)
        if slot.remaining == 0:              # max_new_tokens == 1
            self._finish(slot, done)
            return
        self.slots[slot_idx] = slot

    def _prefetch_next(self):
        """One-ahead prefetch of the next switch target so the eventual
        switch overlaps decode (paper §V-B / Fig 9): the longest-waiting
        foreign batch if one is ready (that is what rotation picks), else
        the most-demanded queued expert (that is what group selection
        picks). The load (store read + H2D copy) runs on the cache's
        background executor — this call never blocks the decode loop; the
        switch consumes the in-flight future via ``activate``. Already
        resident/in-flight -> nothing to do; prefetching anything else
        would just thrash the LRU cache."""
        waiting: Dict[str, int] = {}
        for s in self.slots:
            if s is not None and s.expert != self._active_expert:
                waiting[s.expert] = min(waiting.get(s.expert, 1 << 30),
                                        s.admitted_step)
        if waiting:
            name = min(waiting, key=waiting.get)
        else:
            demand: Dict[str, int] = {}
            for r in self.queue:
                if r.expert != self._active_expert:
                    demand[r.expert] = demand.get(r.expert, 0) + 1
            if not demand:
                return
            name = max(demand, key=demand.get)
        if self.coe.cache.resident(name):
            return
        need = self.coe.experts[name].nbytes
        active_bytes = (self.coe.experts[self._active_expert].nbytes
                        if self._active_expert else 0)
        if need + active_bytes <= self.coe.cache.capacity:
            self.coe.cache.prefetch(name)

    def _slot_rids(self) -> Tuple[Optional[int], ...]:
        return tuple(s.req.rid if s is not None else None
                     for s in self.slots)

    def _device_tables(self):
        """Device copies of the per-slot block tables and lengths, re-uploaded
        only when the pool bookkeeping or slot mapping changed (see
        ``_DeviceTableCache``)."""
        rids = self._slot_rids()
        return self._dev_tables.tables(rids), self._dev_tables.lengths(rids)

    def _device_active(self, active: np.ndarray):
        """Device copy of the active mask, reused while the mask is stable
        (steady-state decode keeps the same lanes active for many rounds)."""
        if (self._active_cache is None
                or not np.array_equal(self._active_cache[0], active)):
            self._active_cache = (active.copy(), jnp.asarray(active))
        return self._active_cache[1]

    def _decode_round(self, active: np.ndarray, done: List[Request]):
        t0 = time.perf_counter()
        with trace.span("decode", cat="engine", expert=self._active_expert,
                        active_slots=int(active.sum())):
            emits = self.policy.round(self._params, active)
        now = time.perf_counter()
        for i, toks in emits.items():
            slot = self.slots[i]
            n = len(toks)
            if n == 0:
                continue
            self.pool.advance(slot.req.rid, n)
            slot.generated.extend(toks)
            slot.last_token = toks[-1]
            slot.req.last_token_s = now
            self.stats.tokens_out += n
            if slot.req.on_token is not None:
                for t in toks:
                    slot.req.on_token(slot.req, int(t))
            if slot.remaining <= 0:
                self._finish(slot, done)
                self.slots[i] = None         # immediate slot recycling
        self.stats.exec_s += time.perf_counter() - t0
        self.stats.decode_rounds += 1
        self.stats.occupancy_sum += float(active.sum()) / self.n_slots

    def _finish(self, slot: _Slot, done: List[Request]):
        req = slot.req
        req.output = np.asarray(slot.generated[: req.max_new_tokens],
                                np.int32)
        req.done_s = time.perf_counter()
        req.last_token_s = req.done_s
        if self.prefix_sharing:
            # the pool holds KV for every *committed* position (the final
            # emitted token's KV was never written — decode stopped first),
            # so index/retain exactly that much of prompt + output
            seq = np.concatenate(
                [req.tokens, req.output])[: self.pool.length(req.rid)]
            self.prefix_index.insert(req.expert, seq,
                                     self.pool.table(req.rid))
            if req.session_id is not None:
                # retention takes over the rid; the session's next turn
                # adopts these pages instead of re-prefilling the history
                self.sessions.retain(req.session_id, req.rid, req.expert,
                                     seq)
            else:
                self.pool.free(req.rid)
        else:
            self.pool.free(req.rid)
        self.policy.on_free(req.rid)
        if req.on_done is not None:
            req.on_done(req)
        trace.async_end("request", id=req.rid, cat="engine",
                        tokens_out=len(req.output),
                        latency_s=req.latency_s)
        if len(req.output) > 1 and req.first_token_s is not None:
            self._tpot_hist.observe((req.done_s - req.first_token_s)
                                    / (len(req.output) - 1))
        self.lifecycle.complete(req)
        self.slo.observe(req)
        flightrec.record("done", rid=req.rid, expert=req.expert,
                         tokens_out=len(req.output), **self._obs_labels)
        done.append(req)

    # -- tenancy accounting ----------------------------------------------
    def release_shared(self) -> None:
        """Drop every retained session and indexed prefix (their pool
        references with them). After a drain this returns the pool to
        ``blocks_in_use == 0`` — the leak check of the tenancy tests."""
        if self.sessions is not None:
            self.sessions.evict_all()
        if self.prefix_index is not None:
            self.prefix_index.clear()

    def hbm_in_budget(self) -> bool:
        """Weights + live KV inside this engine's HBM tier right now: the
        weight cache within its capacity and — when the budget carves a KV
        share — the pool within that carve and the two tiers' live bytes
        within the total. Retained session pages and indexed prefixes count
        as live KV, which is the point: they compete with weights."""
        cache = self.coe.cache
        if cache.used_bytes > cache.capacity:
            return False
        b = self.coe.hbm_budget
        if b.kv_bytes:
            if self.pool.capacity_bytes() > b.kv_bytes:
                return False
            return (cache.used_bytes + self.pool.bytes_in_use()
                    <= b.total_bytes)
        return True

    # -- debug snapshots (/debug/* endpoints, flight-recorder state) -------
    def debug_slots(self) -> Dict[str, Any]:
        """Live decode-slot table: what every slot is doing right now."""
        now = time.perf_counter()
        slots = []
        for idx, s in enumerate(self.slots):
            if s is None:
                slots.append({"slot": idx, "state": "free"})
                continue
            r = s.req
            last = r.last_token_s or r.first_token_s or r.arrival_s
            slots.append({
                "slot": idx, "state": "decoding", "rid": r.rid,
                "expert": s.expert, "tenant": r.tenant,
                "generated": len(s.generated), "remaining": s.remaining,
                "since_last_token_s": now - last,
                "admitted_step": s.admitted_step})
        return {"active_expert": self._active_expert,
                "queue_depth": len(self.queue),
                "queued_rids": [r.rid for r in self.queue],
                "slots": slots}

    def debug_pool(self) -> Dict[str, Any]:
        """KV pool books: occupancy, refcounts, and the invariant audit."""
        p = self.pool
        return {"n_blocks": p.n_blocks, "block_size": p.block,
                "free_blocks": p.free_blocks,
                "blocks_in_use": p.stats.blocks_in_use,
                "shared_blocks": p.stats.shared_blocks,
                "bytes_in_use": p.bytes_in_use(),
                "capacity_bytes": p.capacity_bytes(),
                "open_rids": list(p.open_rids()),
                "reclaimable_blocks": p.reclaimable_blocks(),
                "invariant_violations": p.check_invariants()}

    def debug_sessions(self) -> Dict[str, Any]:
        """Retained-session table (empty when sessions are disabled)."""
        if self.sessions is None:
            return {"sessions": [], "bytes_retained": 0}
        sm = self.sessions
        return {"bytes_retained": sm.bytes_retained(),
                "max_bytes": sm.max_bytes,
                "evictions": sm.evictions,
                "sessions": [
                    {"sid": sid, "rid": s.rid, "expert": s.expert,
                     "tokens": int(len(s.tokens)), "last_use": s.last_use}
                    for sid, s in sm._sessions.items()]}

    def debug_providers(self) -> Dict[str, Any]:
        """Name -> zero-arg snapshot fn; serve.py mounts these on the
        metrics httpd (``/debug/<name>``) and registers them as flight-
        recorder state providers."""
        return {"slots": self.debug_slots, "pool": self.debug_pool,
                "sessions": self.debug_sessions}
