"""Serving engine: batched request scheduling over the CoE.

The paper's deployment (§V-B, §VI-C): requests arrive, the router assigns an
expert, prompts are grouped per expert, the switching engine activates
experts through the HBM LRU cache with next-expert prefetch, and each group
runs prefill + decode. This engine adds the production pieces around the
CoE core: a request queue, jit-compiled per-(config, batch-shape) step
functions (compiled once, reused across experts — all experts share the
backbone config, the paper's §II setup), padding to batch buckets, timeout
re-dispatch of straggling groups, and per-request latency accounting.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.coe import CompositionOfExperts
from repro.models import get_model


@dataclass
class Request:
    rid: int
    tokens: np.ndarray          # (S,)
    max_new_tokens: int
    arrival_s: float = field(default_factory=time.perf_counter)
    done_s: Optional[float] = None
    output: Optional[np.ndarray] = None
    expert: Optional[str] = None


class CompiledExpertRunner:
    """Caches jit-compiled prefill/decode for a (config, batch, seqlen)
    bucket — compiled once, shared by every expert with that backbone."""

    def __init__(self, cfg: ModelConfig, max_len: int):
        self.cfg = cfg
        self.model = get_model(cfg)
        self.max_len = max_len
        self._prefill = {}
        self._decode = jax.jit(
            lambda p, c, t, pos: self.model.decode_step(p, c, t, pos),
            donate_argnums=(1,))

    def prefill(self, params, tokens):
        key = tokens.shape
        if key not in self._prefill:
            self._prefill[key] = jax.jit(
                lambda p, t: self.model.prefill(p, {"tokens": t}, self.max_len))
        return self._prefill[key](params, tokens)

    def decode(self, params, cache, tokens, pos):
        return self._decode(params, cache, tokens, pos)


@dataclass
class ServeStats:
    requests: int = 0
    tokens_out: int = 0
    switch_s: float = 0.0
    exec_s: float = 0.0
    route_s: float = 0.0
    retries: int = 0

    @property
    def tokens_per_second(self):
        t = self.switch_s + self.exec_s
        return self.tokens_out / t if t else 0.0


class ServingEngine:
    def __init__(self, coe: CompositionOfExperts, cfg: ModelConfig,
                 max_len: int = 4096, batch_buckets=(1, 4, 8),
                 group_timeout_s: float = 120.0):
        self.coe = coe
        self.runner = CompiledExpertRunner(cfg, max_len)
        self.queue: List[Request] = []
        self.stats = ServeStats()
        self.buckets = tuple(sorted(batch_buckets))
        self.group_timeout_s = group_timeout_s

    def submit(self, req: Request):
        self.queue.append(req)

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def step(self) -> List[Request]:
        """Serve everything currently queued; returns completed requests."""
        if not self.queue:
            return []
        reqs, self.queue = self.queue, []
        S = max(len(r.tokens) for r in reqs)
        toks = np.zeros((len(reqs), S), np.int32)
        for i, r in enumerate(reqs):
            toks[i, S - len(r.tokens):] = r.tokens     # left-pad

        t0 = time.perf_counter()
        eidx = self.coe.route(toks) % len(self.coe.expert_names())
        self.stats.route_s += time.perf_counter() - t0
        names = self.coe.expert_names()

        groups: Dict[int, List[int]] = {}
        for i, e in enumerate(eidx):
            groups.setdefault(int(e), []).append(i)

        done: List[Request] = []
        glist = sorted(groups.items())
        for gi, (e, rows) in enumerate(glist):
            name = names[e]
            t0 = time.perf_counter()
            params = self.coe.cache.activate(name)
            self.stats.switch_s += time.perf_counter() - t0
            if gi + 1 < len(glist):
                self.coe.cache.prefetch(names[glist[gi + 1][0]])

            n_new = max(reqs[i].max_new_tokens for i in rows)
            bucket = self._bucket(len(rows))
            sub = np.zeros((bucket, S), np.int32)
            sub[: len(rows)] = toks[rows]

            t0 = time.perf_counter()
            attempts = 0
            while True:
                attempts += 1
                try:
                    out = self._run_group(params, jnp.asarray(sub), S, n_new)
                    break
                except Exception:
                    # straggler / transient failure mitigation: re-dispatch
                    # once (on real clusters: to a spare replica)
                    self.stats.retries += 1
                    if attempts >= 2:
                        raise
            self.stats.exec_s += time.perf_counter() - t0

            for j, i in enumerate(rows):
                r = reqs[i]
                r.output = out[j, : r.max_new_tokens]
                r.expert = name
                r.done_s = time.perf_counter()
                self.stats.tokens_out += int(r.max_new_tokens)
                done.append(r)
        self.stats.requests += len(done)
        return done

    def _run_group(self, params, tokens, S, n_new) -> np.ndarray:
        last, cache = self.runner.prefill(params, tokens)
        tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
        outs = [tok]
        for t in range(n_new - 1):
            lg, cache = self.runner.decode(params, cache, tok[:, None],
                                           jnp.int32(S + t))
            tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            outs.append(tok)
        return np.asarray(jax.device_get(jnp.stack(outs, axis=1)))
