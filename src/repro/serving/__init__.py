from repro.serving.engine import ServingEngine, Request, ServeStats, CompiledExpertRunner
from repro.serving.speculative import SpeculativeDecoder, SpecStats, extend_step
from repro.serving.kvcache import PagedKVCache, PagedStats

__all__ = ["ServingEngine", "Request", "ServeStats", "CompiledExpertRunner",
           "SpeculativeDecoder", "SpecStats", "extend_step",
           "PagedKVCache", "PagedStats"]
