"""Serving layer: continuous-batching CoE engine over the paged KV pool.

``ServingEngine`` (engine.py) schedules a persistent decode batch whose
slots are block tables in ``PagedKVCache`` (kvcache.py); decode policy is
pluggable (``GreedyDecode`` / ``SpeculativeDecode``). ``SpeculativeDecoder``
(speculative.py) is the standalone dense-cache reference implementation of
draft-verify decoding that the engine policy is tested against. The decode
step itself is a pluggable backend (backends.py): ``XlaPagedBackend`` is the
pure-XLA reference, ``FusedPagedBackend`` runs each layer as paged-native
Pallas kernels; select via ``make_runner(cfg, scratch_row, backend=...)`` or
``ServingEngine(backend=...)``. Prompt processing is bucketed packed prefill
(prefill.py): ``PackedPrefillRunner`` AOT-compiles one forward per
power-of-two length bucket at ``ServingEngine.warmup()`` and packs several
prompts into each call via segment ids — after warmup a mixed-length burst
triggers zero XLA compilations (``compile_count`` counts them).

Tenancy (``ServingEngine(prefix_sharing=True)``): ``PrefixIndex``
(kvcache.py) dedups shared prompt prefixes across requests with
copy-on-write block refcounts, ``SessionManager`` (sessions.py) retains
finished turns' KV for multi-turn sessions under an HBM-budget-aware
eviction policy, and ``StreamingFrontend`` (frontend.py) puts per-tenant
quotas, SLO-aware priority/preemption, and asyncio token streaming in
front of the engine.
"""
from repro.serving.backends import (PagedBackend, XlaPagedBackend,
                                    FusedPagedBackend, make_backend,
                                    make_runner, PagedDecodeRunner)
from repro.serving.engine import (ServingEngine, Request, ServeStats,
                                  GreedyDecode, SpeculativeDecode)
from repro.serving.prefill import (PackedPrefillRunner, PrefillHandoff,
                                   default_buckets, bucket_for, plan_packs,
                                   compile_count, compile_counts,
                                   record_compile, reset_compile_counts)
from repro.serving.speculative import SpeculativeDecoder, SpecStats, extend_step
from repro.serving.kvcache import PagedKVCache, PagedStats, PrefixIndex
from repro.serving.sessions import SessionManager
from repro.serving.frontend import (StreamingFrontend, TenantQuota,
                                    TokenStream, QuotaExceeded)

__all__ = ["ServingEngine", "Request", "ServeStats", "PagedDecodeRunner",
           "PagedBackend", "XlaPagedBackend", "FusedPagedBackend",
           "make_backend", "make_runner",
           "GreedyDecode", "SpeculativeDecode",
           "PackedPrefillRunner", "PrefillHandoff",
           "default_buckets", "bucket_for", "plan_packs",
           "compile_count", "compile_counts", "record_compile",
           "reset_compile_counts",
           "SpeculativeDecoder", "SpecStats", "extend_step",
           "PagedKVCache", "PagedStats", "PrefixIndex",
           "SessionManager", "StreamingFrontend", "TenantQuota",
           "TokenStream", "QuotaExceeded"]
