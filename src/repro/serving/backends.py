"""Pluggable decode-step backends behind one paged-runner seam.

The engine's decode hot path is one jitted *paged extend* (scatter new K/V
into ``PagedKVCache`` block tables, attend, project). HOW that step executes
is now a backend choice:

  * ``XlaPagedBackend``   — the original pure-XLA body (``xla_paged_extend``;
    contiguous ``pool[tables]`` gather + masked softmax). Runs everywhere,
    bit-stable, and is the correctness reference every fused result is
    tested against.
  * ``FusedPagedBackend`` — the paper's streaming-dataflow claim (§III,
    Fig 6) realized with the repo's own Pallas kernels: per layer, a
    RMSNorm+QKV+RoPE prologue (``kernels/fused_decode.qkv_rope_paged``), a
    block-sparse paged flash-decode that gathers K/V straight from the block
    tables (``kernels/flash_attention.decode_paged`` — no contiguous cache
    copy ever materializes), and an out-proj+SwiGLU epilogue
    (``oproj_ffn_swiglu``) that keeps the inter-op activations in VMEM.
    Supported for the dense RMSNorm/SwiGLU/full-RoPE family; the
    single-token step (g=1 — greedy decode and the speculative draft loop)
    is fused, multi-token verify steps (g>1) fall back to the XLA body
    inside the same runner.

Select with ``make_runner(cfg, scratch_row, backend="fused")`` or any of the
threaded surfaces: ``ServingEngine(backend=)``, ``RDUNode(backend=)`` /
``node.execution.make_group_engine(backend=)``, ``launch/serve.py
--backend``, ``benchmarks/run.py --sweep-arrival --backend``.

Every compiled step is wrapped in a ``decode_kernel`` trace span (labelled
with the backend) and exposes ``step_cost_analysis()`` — the measured
HBM-traffic side of the Fig-6 fused-vs-unfused sweep.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.obs import trace
from repro.serving.prefill import record_compile


# ----------------------------------------------------------------------
# XLA reference body
# ----------------------------------------------------------------------

def xla_paged_extend(cfg: ModelConfig, params, pk, pv, tables, lengths,
                     active, tokens, scratch_row: int):
    """g-token extend step against the paged pool (pure-XLA reference).

    pk/pv   (L, rows, block, Hkv, dh) pool arrays (rows includes scratch)
    tables  (B, maxb) int32 per-slot block tables (padded with scratch)
    lengths (B,) int32 tokens already cached per slot
    active  (B,) bool — lanes actually decoding this round; inactive lanes
            scatter their (garbage) K/V to the scratch block and their
            logits are ignored by the caller
    tokens  (B, g) int32 inputs at positions lengths..lengths+g-1
    Returns (logits (B,g,V), pk, pv).
    """
    from repro.models import layers as L
    from repro.models import transformer as T

    B, g = tokens.shape
    block = pk.shape[2]
    maxb = tables.shape[1]
    S = maxb * block
    h = T.embed_tokens(cfg, params, tokens)                       # (B,g,D)
    positions = lengths[:, None] + jnp.arange(g, dtype=jnp.int32)[None]
    blk_idx = jnp.minimum(positions // block, maxb - 1)
    rows = jnp.take_along_axis(tables, blk_idx, axis=1)           # (B,g)
    rows = jnp.where(active[:, None], rows, jnp.int32(scratch_row))
    off = positions % block
    kpos = jnp.arange(S, dtype=jnp.int32)
    mask = kpos[None, None, :] <= positions[:, :, None]           # (B,g,S)
    moe = cfg.n_experts > 0
    Hq, dh = cfg.n_heads, cfg.head_dim

    def body(hh, xs):
        lp, kp, vp = xs                    # kp (rows, block, Hkv, dh)
        p = lp["attn"]
        hn = L.apply_norm(cfg, p["norm"], hh)
        q = jnp.einsum("bsd,dhk->bshk", hn, p["wq"])
        k = jnp.einsum("bsd,dhk->bshk", hn, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", hn, p["wv"])
        if cfg.qkv_bias:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
        q = L.apply_rope(cfg, q, positions)
        k = L.apply_rope(cfg, k, positions)
        kp = kp.at[rows, off].set(k.astype(kp.dtype))
        vp = vp.at[rows, off].set(v.astype(vp.dtype))
        kc = kp[tables].reshape(B, S, *kp.shape[2:])              # (B,S,Hkv,dh)
        vc = vp[tables].reshape(B, S, *vp.shape[2:])
        Hkv = kc.shape[2]
        qg = q.reshape(B, g, Hkv, Hq // Hkv, dh)
        s = jnp.einsum("bqhgd,bshd->bhgqs", qg, kc,
                       preferred_element_type=jnp.float32) / math.sqrt(dh)
        s = jnp.where(mask[:, None, None], s, -jnp.inf)
        pa = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqs,bshd->bqhgd", pa.astype(vc.dtype), vc,
                       preferred_element_type=jnp.float32)
        o = o.reshape(B, g, Hq, dh).astype(hh.dtype)
        y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        if cfg.attn_out_bias:
            y = y + p["bo"]
        hh = hh + y
        hh = T._mlp(cfg, lp["mlp_norm"], lp["mlp"], hh, moe)
        return hh, (kp, vp)

    h, (pk, pv) = jax.lax.scan(body, h, (params["layers"], pk, pv))
    h = L.apply_norm(cfg, params["final_norm"], h)
    logits = T.unembed(cfg, params, h)
    return logits, pk, pv


# ----------------------------------------------------------------------
# Fused Pallas body (g = 1)
# ----------------------------------------------------------------------

def fused_paged_extend(cfg: ModelConfig, params, pk, pv, tables, lengths,
                       active, tokens, scratch_row: int,
                       interpret: Optional[bool] = None):
    """Single-token paged extend where every decoder layer runs as three
    Pallas calls: qkv_rope_paged -> decode_paged -> oproj_ffn_swiglu, with
    only the K/V scatter (one dynamic row write) left to XLA. Semantics are
    identical to ``xla_paged_extend`` with g=1 — including the masking
    convention: a lane attends positions ``kpos <= lengths``, i.e. ``len1 =
    lengths + 1`` valid cache slots after this step's scatter; inactive and
    empty lanes compute finite garbage the caller ignores."""
    from repro.kernels.fused_decode.kernel import (qkv_rope_paged,
                                                   oproj_ffn_swiglu)
    from repro.kernels.flash_attention.ops import decode_paged
    from repro.kernels.runtime import resolve_interpret
    from repro.models import layers as L
    from repro.models import transformer as T

    B, g = tokens.shape
    assert g == 1, "fused_paged_extend is the single-token hot path"
    block = pk.shape[2]
    maxb = tables.shape[1]
    Hq, dh, D = cfg.n_heads, cfg.head_dim, cfg.d_model
    F = cfg.d_ff
    bf = math.gcd(F, 512)              # largest MXU-friendly divisor of F
    it = resolve_interpret(interpret)

    h = T.embed_tokens(cfg, params, tokens)[:, 0]                 # (B, D)
    pos = lengths                                                 # (B,)
    blk_idx = jnp.minimum(pos // block, maxb - 1)
    rows = jnp.take_along_axis(tables, blk_idx[:, None], axis=1)[:, 0]
    rows = jnp.where(active, rows, jnp.int32(scratch_row))
    off = pos % block
    len1 = lengths + 1

    def body(hh, xs):
        lp, kp, vp = xs                    # kp (rows, block, Hkv, dh)
        p = lp["attn"]
        q, k, v = qkv_rope_paged(hh, p["norm"]["scale"], p["wq"], p["wk"],
                                 p["wv"], pos, theta=cfg.rope_theta,
                                 interpret=it)
        kp = kp.at[rows, off].set(k.astype(kp.dtype))
        vp = vp.at[rows, off].set(v.astype(vp.dtype))
        o = decode_paged(q, kp, vp, tables, len1, interpret=it)   # (B,Hq,dh)
        hh = oproj_ffn_swiglu(hh, o.reshape(B, Hq * dh),
                              p["wo"].reshape(Hq * dh, D),
                              lp["mlp_norm"]["scale"], lp["mlp"]["wi_gate"],
                              lp["mlp"]["wi_up"], lp["mlp"]["wo"],
                              block_f=bf, interpret=it)
        return hh, (kp, vp)

    h, (pk, pv) = jax.lax.scan(body, h, (params["layers"], pk, pv))
    h = L.apply_norm(cfg, params["final_norm"], h)[:, None]       # (B,1,D)
    logits = T.unembed(cfg, params, h)
    return logits, pk, pv


def fused_kernel_hbm_bytes(cfg: ModelConfig, batch: int, maxb: int,
                           block: int, kv_itemsize: int = 2,
                           p_itemsize: int = 4,
                           act_itemsize: int = 4) -> int:
    """Exact analytic HBM bytes streamed by the Pallas kernels in ONE fused
    extend step (g=1): grid x BlockSpec tile sizes, deduplicated wherever an
    index map is constant or clamped (Pallas re-DMAs a tile only when its
    mapped index changes). XLA's cost model treats custom calls as opaque,
    so the sweep's measured-traffic column adds this term for the fused
    backend."""
    B = batch
    Hq, Hkv, dh, D, F = (cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                         cfg.d_model, cfg.d_ff)
    H = Hq + 2 * Hkv
    rot = dh - dh % 2
    # prologue: activations/scale/pos/inv once; each weight head once; out
    prologue = (B * D + D + B + rot // 2) * act_itemsize \
        + D * H * dh * p_itemsize + H * B * dh * act_itemsize
    # paged attention: per (b, kv-head) the q group tile; per (b, h, j) one
    # K and one V pool tile; output group tile
    G = Hq // Hkv
    attn = (B * Hkv * G * dh * act_itemsize * 2          # q in + o out
            + B * Hkv * maxb * block * dh * kv_itemsize * 2)
    # epilogue: x/attn/wo/scale once; gate/up/down streamed once; out
    epilogue = (B * D + B * Hq * dh + D) * act_itemsize \
        + (Hq * dh * D + 3 * D * F) * p_itemsize + B * D * act_itemsize
    return cfg.n_layers * (prologue + attn + epilogue)


# ----------------------------------------------------------------------
# Backend objects + the runner
# ----------------------------------------------------------------------

class PagedBackend:
    """One way to execute the paged extend step. Subclasses supply
    ``extend_fn(B, g)`` -> a traceable ``f(params, pk, pv, tables, lengths,
    active, tokens)`` the runner jits (with pool donation) per shape."""

    name = "?"

    def __init__(self, cfg: ModelConfig, scratch_row: int):
        self.cfg = cfg
        self.scratch_row = scratch_row

    def extend_fn(self, batch: int, g: int):
        raise NotImplementedError


class XlaPagedBackend(PagedBackend):
    """Today's pure-XLA step — the correctness reference."""

    name = "xla"

    def extend_fn(self, batch: int, g: int):
        cfg, scratch = self.cfg, self.scratch_row
        return lambda p, pk, pv, tb, ln, ac, tk: xla_paged_extend(
            cfg, p, pk, pv, tb, ln, ac, tk, scratch)


class FusedPagedBackend(PagedBackend):
    """Pallas fused decode path (see module docstring). g=1 steps fuse;
    g>1 (speculative verify) runs the XLA body under the same runner."""

    name = "fused"

    def __init__(self, cfg: ModelConfig, scratch_row: int,
                 interpret: Optional[bool] = None):
        super().__init__(cfg, scratch_row)
        self.interpret = interpret
        unsupported = []
        if cfg.n_experts > 0:
            unsupported.append("MoE FFN")
        if cfg.norm != "rms":
            unsupported.append(f"norm={cfg.norm!r}")
        if cfg.act != "swiglu":
            unsupported.append(f"act={cfg.act!r}")
        if cfg.rope_style != "full":
            unsupported.append(f"rope_style={cfg.rope_style!r}")
        if cfg.qkv_bias or cfg.attn_out_bias or cfg.mlp_bias:
            unsupported.append("attention/MLP biases")
        if unsupported:
            raise ValueError(
                "backend='fused' supports the dense RMSNorm/SwiGLU/full-RoPE "
                f"decoder family only; {cfg.name!r} needs "
                f"{', '.join(unsupported)} — use backend='xla'")

    def extend_fn(self, batch: int, g: int):
        cfg, scratch, it = self.cfg, self.scratch_row, self.interpret
        if g > 1:
            return lambda p, pk, pv, tb, ln, ac, tk: xla_paged_extend(
                cfg, p, pk, pv, tb, ln, ac, tk, scratch)
        return lambda p, pk, pv, tb, ln, ac, tk: fused_paged_extend(
            cfg, p, pk, pv, tb, ln, ac, tk, scratch, interpret=it)

    def kernel_hbm_bytes(self, batch: int, maxb: int, block: int,
                         kv_itemsize: int = 2) -> int:
        return fused_kernel_hbm_bytes(self.cfg, batch, maxb, block,
                                      kv_itemsize=kv_itemsize)


BACKENDS = {"xla": XlaPagedBackend, "fused": FusedPagedBackend}


def make_backend(backend, cfg: ModelConfig, scratch_row: int) -> PagedBackend:
    """'xla' / 'fused' / an already-built ``PagedBackend``."""
    if isinstance(backend, PagedBackend):
        return backend
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r} "
                         f"(choose from {sorted(BACKENDS)})")
    return BACKENDS[backend](cfg, scratch_row)


class PagedDecodeRunner:
    """jit-compiled paged prefill / extend for one backbone config.

    All experts of a Samba-CoE share the backbone (paper §II), so one runner
    — one compiled extend per (n_slots, g) — serves every expert. Shareable
    across engines to reuse the compile cache (the benchmark sweep does).
    The extend body comes from the selected ``PagedBackend``; every compiled
    call runs under a ``decode_kernel`` trace span labelled with it.
    """

    def __init__(self, cfg: ModelConfig, scratch_row: int, backend="xla"):
        if cfg.family not in ("dense", "moe"):
            raise ValueError("paged serving supports dense/moe families only")
        if cfg.sliding_window:
            raise ValueError("paged serving does not support sliding windows")
        if cfg.first_dense_layers:
            raise ValueError("paged serving: first_dense_layers unsupported")
        self.cfg = cfg
        self.scratch_row = scratch_row
        self.backend = make_backend(backend, cfg, scratch_row)
        self._prefill = {}                 # S -> jitted forward
        self._extend = {}                  # (B, g) -> jitted extend
        self._abstract: Dict[Tuple[int, int], tuple] = {}
        self._last_key: Optional[Tuple[int, int]] = None

    @property
    def backend_name(self) -> str:
        return self.backend.name

    def prefill_kv(self, params, tokens):
        """tokens (1,S) -> (last logits (V,), k, v each (L,S,Hkv,dh))."""
        from repro.models import transformer as T
        S = tokens.shape[1]
        if S not in self._prefill:
            record_compile("prefill_kv")
            cfg = self.cfg
            self._prefill[S] = jax.jit(lambda p, t: T.forward(
                cfg, p, {"tokens": t}, return_cache=True, last_only=True))
        logits, caches = self._prefill[S](params, tokens)
        k, v = caches[-1]
        return logits[:, -1][0], k[:, 0], v[:, 0]

    def _extend_jit(self, key):
        if key not in self._extend:
            record_compile("extend")
            self._extend[key] = jax.jit(self.backend.extend_fn(*key),
                                        donate_argnums=(1, 2))
        return self._extend[key]

    def extend(self, params, pk, pv, tables, lengths, active, tokens):
        key = tokens.shape
        fn = self._extend_jit(key)
        args = (params, pk, pv, jnp.asarray(tables), jnp.asarray(lengths),
                jnp.asarray(active), jnp.asarray(tokens))
        if key not in self._abstract:
            self._abstract[key] = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(jnp.shape(x),
                                               jnp.asarray(x).dtype), args)
        self._last_key = key
        with trace.span("decode_kernel", cat="kernel",
                        backend=self.backend.name, batch=key[0], g=key[1]):
            return fn(*args)

    def step_cost_analysis(self, key=None) -> Optional[dict]:
        """XLA cost analysis ('bytes accessed', 'flops', ...) of a compiled
        extend step — the measured side of the Fig-6 sweep. ``key`` is a
        ``tokens.shape``; defaults to the most recent. Returns None when the
        step never ran or the backend offers no cost model. NOTE: Pallas
        kernels appear as opaque custom calls to XLA's model — add
        ``FusedPagedBackend.kernel_hbm_bytes`` for their traffic."""
        key = key or self._last_key
        if key is None or key not in self._abstract:
            return None
        try:
            compiled = self._extend_jit(key).lower(
                *self._abstract[key]).compile()
            cost = compiled.cost_analysis()
        except Exception:
            return None
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else None
        return dict(cost) if cost else None


def make_runner(cfg: ModelConfig, scratch_row: int,
                backend="xla") -> PagedDecodeRunner:
    """The backend-selection seam: a single-device paged runner executing
    the chosen backend. (The TP analogue is
    ``node.execution.TPPagedDecodeRunner(cfg, scratch_row, mesh,
    backend=...)``.)"""
    return PagedDecodeRunner(cfg, scratch_row, backend=backend)
