"""Multi-turn session KV retention over the paged pool.

A chat session's next turn re-sends the whole conversation so far; without
retention every turn re-prefills it. ``SessionManager`` keeps a finished
request's block table open in the pool (the rid stays seated, nothing is
copied) keyed by session id, and the next turn adopts the common prefix via
the same pin → ``open(adopt=)`` path a ``PrefixIndex`` hit uses.

Retained pages compete with expert weights for the HBM tier — exactly the
paper's three-tier tradeoff (§IV): the manager holds at most ``max_bytes``
of pages, evicts LRU-by-cost beyond that, and registers with the pool as a
*reclaimer* so admission pressure (new requests needing blocks) can force
sessions out. Every eviction lands in the ``TransferLedger``: blocks only
this session referenced are a ``writeback`` edge (those bytes would move to
a colder tier in a real system); blocks that survive via other references
(prefix index, concurrent requests) are ``elided`` — dropping a reference
moves no bytes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.obs import flightrec
from repro.serving.kvcache import PagedKVCache


@dataclass
class _Session:
    rid: int                  # the pool rid still holding the pages
    expert: str
    tokens: np.ndarray        # committed token ids, len == pool.length(rid)
    last_use: int = 0


class SessionManager:
    """LRU+cost retention of finished requests' KV pages, per session id."""

    def __init__(self, pool: PagedKVCache, ledger: Optional[Any] = None,
                 max_bytes: Optional[int] = None):
        self.pool = pool
        self.ledger = ledger
        # default: retained sessions may hold at most half the pool, so
        # fresh admissions always have headroom before reclaim kicks in
        self.max_bytes = (pool.capacity_bytes() // 2
                          if max_bytes is None else int(max_bytes))
        self._sessions: Dict[str, _Session] = {}
        self._clock = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._sessions)

    def __contains__(self, sid: str) -> bool:
        return sid in self._sessions

    def _bytes(self, s: _Session) -> int:
        return len(self.pool.table(s.rid)) * self.pool._per_block_bytes()

    def bytes_retained(self) -> int:
        return sum(self._bytes(s) for s in self._sessions.values())

    # -- write path --------------------------------------------------------
    def retain(self, sid: str, rid: int, expert: str,
               tokens: np.ndarray) -> None:
        """Keep ``rid``'s pages resident for the session's next turn. The
        manager takes over ownership of the rid — the engine must NOT call
        ``pool.free(rid)`` afterwards. A session's previous turn is evicted
        first (the new turn's pages subsume it)."""
        self._clock += 1
        if sid in self._sessions:
            self.evict(sid, cause="session_replace")
        self._sessions[sid] = _Session(
            rid=rid, expert=expert,
            tokens=np.ascontiguousarray(tokens[: self.pool.length(rid)],
                                        np.int32),
            last_use=self._clock)
        self._enforce_cap()

    # -- read path ---------------------------------------------------------
    def adopt(self, sid: str,
              expert: str,
              tokens: np.ndarray) -> Optional[Tuple[List[int], int]]:
        """Hand the session's pages to its next turn. Returns PINNED
        ``(blocks, n_tokens)`` covering the longest common prefix of the
        retained sequence and the new prompt (capped at ``len(tokens) - 1``
        so the suffix forward still produces logits), or ``None``. The
        retained rid is freed — adopted blocks survive through the pin, and
        a partially-consumed tail block stays position-exact (the adopter's
        first write COW-splits it if anything else still references it)."""
        s = self._sessions.get(sid)
        if s is None:
            return None
        if s.expert != expert:
            # routed to a different expert this turn: the KV is useless
            self.evict(sid, cause="session_reroute")
            return None
        new = np.ascontiguousarray(tokens, np.int32)
        m = min(len(s.tokens), len(new))
        n = int(np.cumprod(s.tokens[:m] == new[:m]).sum()) if m else 0
        n = min(n, len(new) - 1)
        if n <= 0:
            self.evict(sid, cause="session_mismatch")
            return None
        B = self.pool.block
        blocks = self.pool.table(s.rid)[: -(-n // B)]
        self.pool.pin(blocks)
        del self._sessions[sid]
        self.pool.free(s.rid)
        return blocks, n

    # -- eviction ----------------------------------------------------------
    def evict(self, sid: str, cause: str = "session_evict") -> int:
        """Release one session's pages. Returns blocks actually freed."""
        s = self._sessions.pop(sid)
        tbl = self.pool.table(s.rid)
        per = self.pool._per_block_bytes()
        orphan = sum(1 for b in tbl if self.pool.refcount(b) == 1)
        shared = len(tbl) - orphan
        if self.ledger is not None:
            if orphan:
                self.ledger.record("writeback", orphan * per, cause=cause)
            if shared:
                self.ledger.record("elided", shared * per, cause=cause)
        before = self.pool.free_blocks
        self.pool.free(s.rid)
        self.evictions += 1
        freed = self.pool.free_blocks - before
        flightrec.record("evict", sid=sid, rid=s.rid, expert=s.expert,
                         cause=cause, freed_blocks=freed)
        return freed

    def _victim(self) -> Optional[str]:
        """Highest age-per-byte session: old AND cheap-to-rebuild goes
        first; a long recent conversation (expensive to re-prefill) stays."""
        if not self._sessions:
            return None
        return max(self._sessions,
                   key=lambda sid: ((self._clock
                                     - self._sessions[sid].last_use)
                                    / max(len(self._sessions[sid].tokens), 1)))

    def _enforce_cap(self) -> None:
        while len(self._sessions) > 1 and self.bytes_retained() > self.max_bytes:
            self.evict(self._victim(), cause="session_cap")

    # -- pool reclaimer protocol -------------------------------------------
    def reclaimable(self) -> int:
        """Lower bound on blocks an eviction sweep would free (only blocks
        with no other reference actually return to the free list)."""
        return sum(1 for s in self._sessions.values()
                   for b in self.pool.table(s.rid)
                   if self.pool.refcount(b) == 1)

    def reclaim(self, need_blocks: int) -> int:
        freed = 0
        while freed < need_blocks and self._sessions:
            freed += self.evict(self._victim(), cause="session_pressure")
        return freed

    def evict_all(self, cause: str = "session_drain") -> None:
        while self._sessions:
            self.evict(next(iter(self._sessions)), cause=cause)
