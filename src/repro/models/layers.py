"""Shared neural-net layers: norms, RoPE variants, attention, FFN, MoE.

Attention uses a *streaming block* formulation (`block_attention`): the set of
valid (q-block, kv-block) pairs is enumerated statically in Python (causal /
sliding-window), and a `lax.scan` streams through them with an online-softmax
accumulator. This is the pure-JAX analogue of the paper's streaming-dataflow
pipeline (and of the Pallas flash kernel in kernels/flash_attention): it does
exactly the useful FLOPs — masked-out blocks are never computed — and bounds
activation memory to one (block x block) tile.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

# ----------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------

def rms_norm(x, scale, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def layer_norm(x, scale, bias, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * scale + bias


def apply_norm(cfg: ModelConfig, p, x):
    if cfg.norm == "rms":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def norm_specs(cfg: ModelConfig, d=None):
    from repro.models.common import spec
    d = d or cfg.d_model
    out = {"scale": spec((d,), ("embed",), init="ones")}
    if cfg.norm == "ln":
        out["bias"] = spec((d,), ("embed",), init="zeros")
    return out


# ----------------------------------------------------------------------
# RoPE (full / partial / m-rope)
# ----------------------------------------------------------------------

def _rope_angles(positions, rot_dim, theta):
    """positions (..., S) -> cos/sin of shape (..., S, rot_dim//2)."""
    inv_freq = 1.0 / (theta ** (np.arange(0, rot_dim, 2) / rot_dim))
    ang = positions[..., None].astype(jnp.float32) * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def _rotate_half(x, cos, sin):
    # llama-style: split last dim in halves
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(cfg: ModelConfig, x, positions):
    """x: (B, S, H, dh). positions: (B, S) int32, or (3, B, S) for m-rope."""
    if cfg.rope_style == "none":
        return x
    dh = x.shape[-1]
    rot_dim = int(dh * cfg.rope_fraction) if cfg.rope_style == "partial" else dh
    rot_dim -= rot_dim % 2
    xr, xp = x[..., :rot_dim], x[..., rot_dim:]
    dt = x.dtype

    if cfg.rope_style == "mrope":
        if positions.ndim == 2:  # text-only: same stream for all 3 sections
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        cos, sin = _rope_angles(positions, rot_dim, cfg.rope_theta)  # (3,B,S,rot/2)
        secs = cfg.mrope_sections
        assert sum(secs) == rot_dim // 2, (secs, rot_dim)
        cos = jnp.concatenate(
            [cos[i, ..., sum(secs[:i]):sum(secs[: i + 1])] for i in range(3)], axis=-1
        )
        sin = jnp.concatenate(
            [sin[i, ..., sum(secs[:i]):sum(secs[: i + 1])] for i in range(3)], axis=-1
        )
    else:
        cos, sin = _rope_angles(positions, rot_dim, cfg.rope_theta)  # (B,S,rot/2)

    cos = cos[..., None, :].astype(jnp.float32)  # (B,S,1,rot/2)
    sin = sin[..., None, :].astype(jnp.float32)
    xr = _rotate_half(xr.astype(jnp.float32), cos, sin).astype(dt)
    return jnp.concatenate([xr, xp], axis=-1) if xp.shape[-1] else xr


# ----------------------------------------------------------------------
# Attention
# ----------------------------------------------------------------------

def _gqa_scores(qb, kb, scale):
    # qb (B,bq,Hkv,G,dh), kb (B,bk,Hkv,dh) -> (B,Hkv,G,bq,bk) fp32
    return jnp.einsum(
        "bqhgd,bkhd->bhgqk", qb, kb, preferred_element_type=jnp.float32
    ) * scale


def naive_attention(q, k, v, *, causal=True, window=0, q_offset=0):
    """Oracle quadratic attention. q (B,Sq,Hq,dh), k/v (B,Sk,Hkv,dh)."""
    B, Sq, Hq, dh = q.shape
    _, Sk, Hkv, dv = v.shape
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, dh)
    s = _gqa_scores(qg, k, 1.0 / math.sqrt(dh))      # (B,Hkv,G,Sq,Sk)
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return o.reshape(B, Sq, Hq, dv)


def _block_pairs(nq, nk, block, *, causal, window, q_offset_blocks=0):
    """Statically enumerate valid (qi, kj) block pairs."""
    pairs = []
    for i in range(nq):
        gi = i + q_offset_blocks
        for j in range(nk):
            if causal and j > gi:
                continue
            if window and (gi - j) * block >= window + block:
                continue
            pairs.append((i, j))
    return pairs


def block_attention(q, k, v, *, causal=True, window=0, block=1024, q_offset=0):
    """Streaming-block attention with online softmax; exact-FLOP causal/SWA.

    Shapes as naive_attention. S must be divisible by block (shapes in this
    framework are powers of two; block defaults to 1024).
    """
    B, Sq, Hq, dh = q.shape
    _, Sk, Hkv, dv = v.shape
    if (Sq <= 2 * block and Sk <= 2 * block) or Sq % block or Sk % block:
        # small, or non-block-aligned (e.g. cross-attention to 1500 frames)
        return naive_attention(q, k, v, causal=causal, window=window, q_offset=q_offset)
    nq, nk = Sq // block, Sk // block
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(dh)
    pairs = _block_pairs(nq, nk, block, causal=causal, window=window,
                         q_offset_blocks=q_offset // block)
    ii = jnp.array([p[0] for p in pairs], jnp.int32)
    jj = jnp.array([p[1] for p in pairs], jnp.int32)

    qg = q.reshape(B, Sq, Hkv, G, dh)
    acc0 = jnp.zeros((B, Sq, Hkv, G, dv), jnp.float32)
    m0 = jnp.full((B, Hkv, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)

    def step(carry, ij):
        acc, m, l = carry
        i, j = ij
        qb = jax.lax.dynamic_slice_in_dim(qg, i * block, block, axis=1)
        kb = jax.lax.dynamic_slice_in_dim(k, j * block, block, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, j * block, block, axis=1)
        s = _gqa_scores(qb, kb, scale)                    # (B,Hkv,G,bq,bk)
        qpos = i * block + jnp.arange(block)[:, None] + q_offset
        kpos = j * block + jnp.arange(block)[None, :]
        mask = jnp.ones((block, block), bool)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, -jnp.inf)

        mb = jax.lax.dynamic_slice_in_dim(m, i * block, block, axis=3)
        lb = jax.lax.dynamic_slice_in_dim(l, i * block, block, axis=3)
        ab = jax.lax.dynamic_slice_in_dim(acc, i * block, block, axis=1)

        m_new = jnp.maximum(mb, s.max(axis=-1))
        # guard fully-masked rows (can't happen for valid pairs, but keep safe)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(jnp.isfinite(mb), jnp.exp(mb - m_safe), 0.0)
        l_new = lb * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), vb,
                        preferred_element_type=jnp.float32)
        a_new = ab * alpha.transpose(0, 3, 1, 2)[..., None] + pv

        acc = jax.lax.dynamic_update_slice_in_dim(acc, a_new, i * block, axis=1)
        m = jax.lax.dynamic_update_slice_in_dim(m, m_new, i * block, axis=3)
        l = jax.lax.dynamic_update_slice_in_dim(l, l_new, i * block, axis=3)
        return (acc, m, l), None

    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), (ii, jj))
    l = jnp.maximum(l, 1e-30)
    out = acc / l.transpose(0, 3, 1, 2)[..., None]
    return out.reshape(B, Sq, Hq, dv).astype(q.dtype)


def attention(cfg: ModelConfig, q, k, v, *, causal=True, window=0, q_offset=0):
    block = cfg.attn_chunk
    if q.shape[1] > 2 * block:
        return block_attention(q, k, v, causal=causal, window=window,
                               block=block, q_offset=q_offset)
    return naive_attention(q, k, v, causal=causal, window=window, q_offset=q_offset)


def decode_attention(q, k_cache, v_cache, valid_mask):
    """Single-token decode. q (B,1,Hq,dh); caches (B,S,Hkv,dh);
    valid_mask (B,S) bool."""
    B, _, Hq, dh = q.shape
    _, S, Hkv, dv = v_cache.shape
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, dh)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                   preferred_element_type=jnp.float32) / math.sqrt(dh)
    s = jnp.where(valid_mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, Hq, dv).astype(q.dtype)


# ----------------------------------------------------------------------
# FFN
# ----------------------------------------------------------------------

def ffn_specs(cfg: ModelConfig, d_ff=None):
    from repro.models.common import spec
    D, F = cfg.d_model, d_ff or cfg.d_ff
    p = {}
    if cfg.act in ("swiglu", "geglu"):
        p["wi_gate"] = spec((D, F), ("embed", "ffn"))
        p["wi_up"] = spec((D, F), ("embed", "ffn"))
    else:
        p["wi"] = spec((D, F), ("embed", "ffn"))
    p["wo"] = spec((F, D), ("ffn", "embed"))
    if cfg.mlp_bias:
        if cfg.act in ("swiglu", "geglu"):
            p["bi_gate"] = spec((F,), ("ffn",), init="zeros")
            p["bi_up"] = spec((F,), ("ffn",), init="zeros")
        else:
            p["bi"] = spec((F,), ("ffn",), init="zeros")
        p["bo"] = spec((D,), ("embed",), init="zeros")
    return p


def _act(cfg, x):
    if cfg.act == "swiglu":
        return jax.nn.silu(x)
    return jax.nn.gelu(x, approximate=True)


def ffn_apply(cfg: ModelConfig, p, x):
    if cfg.act in ("swiglu", "geglu"):
        g = x @ p["wi_gate"]
        u = x @ p["wi_up"]
        if cfg.mlp_bias:
            g = g + p["bi_gate"]
            u = u + p["bi_up"]
        h = _act(cfg, g) * u
    else:
        h = x @ p["wi"]
        if cfg.mlp_bias:
            h = h + p["bi"]
        h = _act(cfg, h)
    y = h @ p["wo"]
    if cfg.mlp_bias:
        y = y + p["bo"]
    return y


# ----------------------------------------------------------------------
# MoE (sort-based token dispatch — O(T*k*D), no quadratic einsum dispatch)
# ----------------------------------------------------------------------

def moe_specs(cfg: ModelConfig):
    from repro.models.common import spec
    D, F, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    p = {
        "router": spec((D, E), ("embed", "experts_r"), dtype=jnp.float32),
        "experts": {
            "wi_gate": spec((E, D, F), ("experts", "embed", "expert_ffn"),
                            fan_in_axes=(1,)),
            "wi_up": spec((E, D, F), ("experts", "embed", "expert_ffn"),
                          fan_in_axes=(1,)),
            "wo": spec((E, F, D), ("experts", "expert_ffn", "embed"),
                       fan_in_axes=(1,)),
        },
    }
    if cfg.n_shared_experts:
        Fs = cfg.moe_d_ff * cfg.n_shared_experts
        p["shared"] = {
            "wi_gate": spec((D, Fs), ("embed", "ffn")),
            "wi_up": spec((D, Fs), ("embed", "ffn")),
            "wo": spec((Fs, D), ("ffn", "embed")),
        }
    return p


def moe_apply_ep_local(cfg: ModelConfig, p, x, mesh):
    """Expert-parallel MoE with *local* dispatch (beyond-paper §Perf).

    Insight: under tensor parallelism the activations entering the MoE are
    already replicated across the 'model' axis. With experts sharded over
    'model', every model-rank can therefore select/rank/scatter the tokens
    bound for ITS local experts entirely locally — no global sort, no
    cross-device scatter. The only collective is one psum of the combined
    output over 'model' (same shape/cost as the TP FFN all-reduce it
    replaces). GSPMD's gather-heavy lowering of the global sort-based
    dispatch disappears.
    """
    E, K = cfg.n_experts, cfg.top_k
    msize = mesh.shape["model"]
    assert E % msize == 0
    E_loc = E // msize
    B, S, D = x.shape
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    router_w = p["router"]
    wg, wu, wo = (p["experts"]["wi_gate"], p["experts"]["wi_up"],
                  p["experts"]["wo"])

    def body(xt, rw, wg_l, wu_l, wo_l):
        # xt (B_loc, S, D) model-replicated; expert weights local (E_loc,...)
        Bl, Sl, Dl = xt.shape
        T = Bl * Sl
        xf = xt.reshape(T, Dl)
        rank = jax.lax.axis_index("model")
        my_first = rank * E_loc

        logits = xf.astype(jnp.float32) @ rw
        gates = jax.nn.softmax(logits, axis=-1)
        topw, topi = jax.lax.top_k(gates, K)
        if cfg.family == "moe":
            topw = topw / topw.sum(-1, keepdims=True)
        topw = topw * cfg.routed_scale

        from repro.distributed import ctx as _ctx
        cap = _ctx.perf().capacity_factor or cfg.capacity_factor
        C = max(1, int(math.ceil(T * K / E * cap)))
        TK = T * K
        eid = topi.reshape(TK)
        tid = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
        w = topw.reshape(TK)

        mine = (eid >= my_first) & (eid < my_first + E_loc)
        eloc = jnp.where(mine, eid - my_first, E_loc)      # E_loc = drop row
        order = jnp.argsort(eloc, stable=True)             # local sort
        el_s, tid_s, w_s = eloc[order], tid[order], w[order]
        counts = jnp.sum(jax.nn.one_hot(el_s, E_loc + 1, dtype=jnp.int32),
                         axis=0)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(TK, dtype=jnp.int32) - starts[el_s]
        keep = (el_s < E_loc) & (pos < C)
        pos_c = jnp.where(keep, pos, C)
        row = jnp.where(keep, el_s, E_loc)

        xe = jnp.zeros((E_loc + 1, C + 1, Dl), xt.dtype)
        xe = xe.at[row, pos_c].set(jnp.where(keep[:, None], xf[tid_s], 0))
        xe = xe[:E_loc, :C]

        h_g = jnp.einsum("ecd,edf->ecf", xe, wg_l)
        h_u = jnp.einsum("ecd,edf->ecf", xe, wu_l)
        h = _act(cfg, h_g) * h_u
        ye = jnp.einsum("ecf,efd->ecd", h, wo_l)

        yc = ye[jnp.minimum(row, E_loc - 1), jnp.minimum(pos_c, C - 1)]
        yc = yc * (w_s * keep.astype(w_s.dtype))[:, None].astype(yc.dtype)
        out = jnp.zeros((T, Dl), jnp.float32).at[tid_s].add(
            yc.astype(jnp.float32))
        out = jax.lax.psum(out, "model")                   # the only collective
        return out.astype(xt.dtype).reshape(Bl, Sl, Dl)

    from repro.distributed.ctx import shard_map
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(jax.sharding.PartitionSpec(dp if dp else None, None, None),
                  jax.sharding.PartitionSpec(None, None),
                  jax.sharding.PartitionSpec("model", None, None),
                  jax.sharding.PartitionSpec("model", None, None),
                  jax.sharding.PartitionSpec("model", None, None)),
        out_specs=jax.sharding.PartitionSpec(dp if dp else None, None, None),
        check_vma=False,
    )
    out = fn(x, router_w, wg, wu, wo)
    if cfg.n_shared_experts:
        out = out + ffn_apply(cfg, p["shared"], x)
    return out


def moe_apply(cfg: ModelConfig, p, x):
    """x (B,S,D) -> (B,S,D). Top-k routing with capacity, sort-based dispatch."""
    from repro.distributed import ctx as _c
    mesh = _c.current_mesh()
    if (_c.perf().moe_ep_local and mesh is not None
            and "model" in mesh.axis_names
            and cfg.n_experts % mesh.shape["model"] == 0):
        return moe_apply_ep_local(cfg, p, x, mesh)
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32) @ p["router"])          # (T,E)
    gates = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(gates, K)                     # (T,K)
    if cfg.family == "moe":            # mixtral renormalizes over top-k
        topw = topw / topw.sum(-1, keepdims=True)
    topw = topw * cfg.routed_scale

    from repro.distributed import ctx as _ctx
    cap = _ctx.perf().capacity_factor or cfg.capacity_factor
    C = max(1, int(math.ceil(T * K / E * cap)))
    TK = T * K
    eid = topi.reshape(TK)
    tid = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    w = topw.reshape(TK)

    order = jnp.argsort(eid, stable=True)
    eid_s, tid_s, w_s = eid[order], tid[order], w[order]
    # rank within expert = own index - start of this expert's run
    counts = jnp.sum(jax.nn.one_hot(eid_s, E, dtype=jnp.int32), axis=0)  # (E,)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(TK, dtype=jnp.int32) - starts[eid_s]
    keep = pos < C
    pos_c = jnp.where(keep, pos, C)                          # overflow slot C

    # scatter tokens -> (E, C+1, D); slot C collects dropped tokens
    xe = jnp.zeros((E, C + 1, D), x.dtype)
    xe = xe.at[eid_s, pos_c].set(jnp.where(keep[:, None], xt[tid_s], 0))
    xe = xe[:, :C]                                           # (E,C,D)
    xe = _ctx.constrain_named("moe_dispatch", xe)

    h_g = jnp.einsum("ecd,edf->ecf", xe, p["experts"]["wi_gate"])
    h_u = jnp.einsum("ecd,edf->ecf", xe, p["experts"]["wi_up"])
    h = _act(cfg, h_g) * h_u
    ye = jnp.einsum("ecf,efd->ecd", h, p["experts"]["wo"])   # (E,C,D)
    ye = _ctx.constrain_named("moe_dispatch", ye)

    # gather back + combine
    yc = ye[eid_s, jnp.minimum(pos_c, C - 1)]                # (TK,D)
    yc = yc * (w_s * keep.astype(w_s.dtype))[:, None].astype(yc.dtype)
    out = jnp.zeros((T, D), jnp.float32).at[tid_s].add(yc.astype(jnp.float32))
    out = out.astype(x.dtype)

    if cfg.n_shared_experts:
        out = out + ffn_apply(cfg, p["shared"], xt)
    return out.reshape(B, S, D)
