"""xLSTM family: mLSTM blocks (chunkwise-parallel) + sLSTM blocks (sequential).

48 blocks = 6 scanned groups of (7 mLSTM + 1 sLSTM) for xlstm-1.3b
(slstm_every=8). The mLSTM matrix-memory recurrence is computed chunkwise
(linear-attention form): intra-chunk quadratic with decay weights, inter-chunk
via the carried (C, n) state — O(S·c·d) instead of O(S·d²) materialization.

Numerics note (DESIGN.md): input-gate logits are clipped to [-10, 10] instead
of carrying the xLSTM max-stabilizer through the chunkwise path; forget gates
are sigmoid (log f <= 0) so no exponent can overflow. The sLSTM path keeps the
exact max-stabilizer (it is cheap there).
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import ctx
from repro.models import layers as L
from repro.models.common import spec
from repro.models.rglru import causal_conv

_CHUNK = 256
_ILOG_CLIP = 10.0


def _dims(cfg: ModelConfig):
    D = cfg.d_model
    Di = int(cfg.mlstm_proj_factor * D)
    H = cfg.n_heads
    dh = Di // H
    Fs = ((4 * D // 3) // 128) * 128      # sLSTM post-FFN hidden
    return D, Di, H, dh, Fs


# ----------------------------------------------------------------------
# specs
# ----------------------------------------------------------------------

def _mlstm_specs(cfg: ModelConfig):
    D, Di, H, dh, _ = _dims(cfg)
    cw = cfg.conv_width
    return {
        "norm": L.norm_specs(cfg),
        "w_up": spec((D, Di), ("embed", "mlstm_v")),
        "w_z": spec((D, Di), ("embed", "mlstm_v")),
        "conv_w": spec((cw, Di), ("conv", "mlstm_v"), fan_in_axes=(0,)),
        "conv_b": spec((Di,), ("mlstm_v",), init="zeros"),
        "wq": spec((H, dh, dh), ("heads", "head_in", "mlstm_vh"), fan_in_axes=(1,)),
        "wk": spec((H, dh, dh), ("heads", "head_in", "mlstm_vh"), fan_in_axes=(1,)),
        "wv": spec((H, dh, dh), ("heads", "head_in", "mlstm_vh"), fan_in_axes=(1,)),
        "w_i": spec((Di, H), ("mlstm_v", "heads")),
        "b_i": spec((H,), ("heads",), init="zeros"),
        "w_f": spec((Di, H), ("mlstm_v", "heads")),
        "b_f": spec((H,), ("heads",), init="ones"),
        "gn": spec((Di,), ("mlstm_v",), init="ones"),
        "w_down": spec((Di, D), ("mlstm_v", "embed")),
    }


def _slstm_specs(cfg: ModelConfig):
    D, _, _, _, Fs = _dims(cfg)
    H = cfg.slstm_heads
    dh = D // H
    gates = {}
    for g in ("z", "i", "f", "o"):
        gates[f"w_{g}"] = spec((D, D), ("embed", "slstm_d"))
        gates[f"r_{g}"] = spec((H, dh, dh), ("heads", "head_in", "slstm_dh"),
                               fan_in_axes=(1,))
        gates[f"b_{g}"] = spec((D,), ("slstm_d",), init="zeros")
    return {
        "norm": L.norm_specs(cfg),
        **gates,
        "ffn_norm": L.norm_specs(cfg),
        "w_up": spec((D, Fs), ("embed", "ffn")),
        "w_dn": spec((Fs, D), ("ffn", "embed")),
    }


def _stack(tree, n):
    return jax.tree.map(
        lambda s: s._replace(shape=(n,) + s.shape, axes=("layers",) + s.axes,
                             fan_in_axes=tuple(a + 1 for a in s.fan_in_axes)),
        tree,
        is_leaf=lambda x: hasattr(x, "axes") and not isinstance(x, dict),
    )


def _group_counts(cfg: ModelConfig):
    per = cfg.slstm_every
    assert cfg.n_layers % per == 0, "n_layers must divide into slstm groups"
    return cfg.n_layers // per, per - 1   # (groups, mlstm per group)


def param_specs(cfg: ModelConfig):
    G, n_m = _group_counts(cfg)
    group = {"mlstm": _stack(_mlstm_specs(cfg), n_m), "slstm": _slstm_specs(cfg)}
    return {
        "embed": {"tok": spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                              fan_in_axes=())},
        "groups": _stack(group, G),
        "final_norm": L.norm_specs(cfg),
        "lm_head": spec((cfg.d_model, cfg.vocab_size), ("embed", "vocab")),
    }


# ----------------------------------------------------------------------
# mLSTM
# ----------------------------------------------------------------------

def _mh_rms(x, scale):
    """Per-head RMS norm: x (B,S,H,dh), scale (H*dh,)."""
    B, S, H, dh = x.shape
    y = L.rms_norm(x.reshape(B, S, H, dh).astype(jnp.float32),
                   jnp.ones((dh,), jnp.float32))
    return (y.reshape(B, S, H * dh) * scale).astype(jnp.bfloat16)


def mlstm_chunkwise(q, k, v, ilog, flog, state=None, chunk=_CHUNK):
    """q,k,v (B,S,H,dh); ilog/flog (B,S,H) fp32 (flog <= 0).

    state: {'C': (B,H,dh,dh) f32, 'n': (B,H,dh) f32} or None.
    Returns (h (B,S,H,dh) f32, new_state).
    """
    B, S, H, dh = q.shape
    c = min(chunk, S)
    assert S % c == 0
    nc = S // c
    scale = 1.0 / math.sqrt(dh)
    from repro.distributed import ctx as _ctx
    cdt = jnp.bfloat16 if _ctx.perf().mlstm_bf16 else jnp.float32
    qf = (q.astype(cdt) * jnp.asarray(scale, cdt)).reshape(B, nc, c, H, dh)
    kf = k.astype(cdt).reshape(B, nc, c, H, dh)
    vf = v.astype(cdt).reshape(B, nc, c, H, dh)
    il = ilog.reshape(B, nc, c, H)
    fl = flog.reshape(B, nc, c, H)

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32) if state is None else state["C"]
    n0 = jnp.zeros((B, H, dh), jnp.float32) if state is None else state["n"]

    def body(carry, xs):
        C, n = carry
        qc, kc, vc, ic, fc = xs          # (B,c,H,dh), gates (B,c,H)
        cum = jnp.cumsum(fc, axis=1)                        # inclusive logsum f
        # inter-chunk: h_t += exp(cum_t) * q_t C ; n_t += exp(cum_t) * n
        dec_t = jnp.exp(cum)                                # (B,c,H)
        h_inter = jnp.einsum("bthd,bhde->bthe", qc.astype(jnp.float32),
                             C) * dec_t[..., None]
        n_inter = n[:, None] * dec_t[..., None]
        # intra-chunk decay: w_tj = exp(cum_t - cum_j + il_j), j <= t
        g = ic - cum                                        # (B,c,H)
        wmat = jnp.exp(cum[:, :, None] + g[:, None, :])     # (B,t,j,H)
        tri = jnp.tril(jnp.ones((c, c), bool))
        wmat = jnp.where(tri[None, :, :, None], wmat, 0.0)
        wmat_c = wmat.astype(qc.dtype)
        s = jnp.einsum("bthd,bjhd->btjh", qc, kc,
                       preferred_element_type=jnp.float32) * wmat
        h_intra = jnp.einsum("btjh,bjhd->bthd", s.astype(qc.dtype), vc,
                             preferred_element_type=jnp.float32)
        n_intra = jnp.einsum("btjh,bjhd->bthd", wmat_c, kc,
                             preferred_element_type=jnp.float32)
        den = jnp.abs(jnp.einsum("bthd,bthd->bth", qc.astype(jnp.float32),
                                 n_inter + n_intra))
        h = (h_inter + h_intra) / jnp.maximum(den, 1.0)[..., None]
        # state update
        last = cum[:, -1]                                   # (B,H)
        wj = jnp.exp(last[:, None] + g)                     # (B,c,H)
        C_new = C * jnp.exp(last)[..., None, None] + jnp.einsum(
            "bjh,bjhd,bjhe->bhde", wj.astype(qc.dtype), kc, vc,
            preferred_element_type=jnp.float32)
        n_new = n * jnp.exp(last)[..., None] + jnp.einsum(
            "bjh,bjhd->bhd", wj.astype(qc.dtype), kc,
            preferred_element_type=jnp.float32)
        return (C_new, n_new), h

    (C, n), h = jax.lax.scan(
        body, (C0, n0),
        (qf.transpose(1, 0, 2, 3, 4), kf.transpose(1, 0, 2, 3, 4),
         vf.transpose(1, 0, 2, 3, 4), il.transpose(1, 0, 2, 3),
         fl.transpose(1, 0, 2, 3)))
    h = h.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dh)
    return h, {"C": C, "n": n}


def mlstm_step(q, k, v, ilog, flog, state):
    """Single decode step. q,k,v (B,H,dh); gates (B,H) fp32."""
    f = jnp.exp(flog)[..., None]
    i = jnp.exp(ilog)[..., None]
    C = state["C"] * f[..., None] + i[..., None] * (k[..., :, None] * v[..., None, :])
    n = state["n"] * f + i * k
    dh = q.shape[-1]
    qs = q * (1.0 / math.sqrt(dh))
    h = jnp.einsum("bhd,bhde->bhe", qs, C)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", qs, n))
    h = h / jnp.maximum(den, 1.0)[..., None]
    return h, {"C": C, "n": n}


def mlstm_block(cfg, p, x, state=None):
    """x (B,S,D). state {'C','n','conv'} or None. Returns (y, new_state)."""
    B, S, D = x.shape
    _, Di, H, dh, _ = _dims(cfg)
    h = L.apply_norm(cfg, p["norm"], x)
    u = h @ p["w_up"]                                        # (B,S,Di)
    z = h @ p["w_z"]
    conv_state = state["conv"] if state is not None else None
    cpre, new_conv = causal_conv(u, p["conv_w"], p["conv_b"], conv_state)
    cact = jax.nn.silu(cpre)
    ch = cact.reshape(B, S, H, dh)
    uh = u.reshape(B, S, H, dh)
    q = jnp.einsum("bshd,hde->bshe", ch, p["wq"])
    k = jnp.einsum("bshd,hde->bshe", ch, p["wk"])
    v = jnp.einsum("bshd,hde->bshe", uh, p["wv"])
    ilog = jnp.clip((cact @ p["w_i"] + p["b_i"]).astype(jnp.float32),
                    -_ILOG_CLIP, _ILOG_CLIP)
    flog = jax.nn.log_sigmoid((cact @ p["w_f"] + p["b_f"]).astype(jnp.float32))

    if state is None or S > 1:
        st = None if state is None else {"C": state["C"], "n": state["n"]}
        hs, new_st = mlstm_chunkwise(q, k, v, ilog, flog, st)
    else:
        hs, new_st = mlstm_step(q[:, 0], k[:, 0], v[:, 0], ilog[:, 0], flog[:, 0],
                                {"C": state["C"], "n": state["n"]})
        hs = hs[:, None]
    hn = _mh_rms(hs, p["gn"])                                # (B,S,Di)
    out = (hn * jax.nn.silu(z)) @ p["w_down"]
    new_state = {"C": new_st["C"], "n": new_st["n"], "conv": new_conv}
    return x + out, new_state


# ----------------------------------------------------------------------
# sLSTM
# ----------------------------------------------------------------------

def _slstm_rec(p, h_prev, x_t):
    """One recurrent matmul bundle: x_t (B,D), h_prev (B,D)."""
    H = p["r_z"].shape[0]
    B, D = x_t.shape
    dh = D // H
    hh = h_prev.reshape(B, H, dh)
    outs = {}
    for g in ("z", "i", "f", "o"):
        rec = jnp.einsum("bhd,hde->bhe", hh.astype(jnp.bfloat16), p[f"r_{g}"])
        outs[g] = (x_t @ p[f"w_{g}"] + rec.reshape(B, D) + p[f"b_{g}"]).astype(
            jnp.float32)
    return outs


def slstm_apply(cfg, p, x, state=None):
    """Sequential sLSTM with exact max-stabilizer. x (B,S,D)."""
    B, S, D = x.shape
    xn = L.apply_norm(cfg, p["norm"], x)
    if state is None:
        z = jnp.zeros((B, D), jnp.float32)
        state = {"h": z, "c": z, "n": z + 1e-6, "m": z}

    def step(st, x_t):
        o = _slstm_rec(p, st["h"], x_t)
        zt = jnp.tanh(o["z"])
        logi = jnp.clip(o["i"], -_ILOG_CLIP, _ILOG_CLIP)
        logf = jax.nn.log_sigmoid(o["f"])
        m_new = jnp.maximum(logf + st["m"], logi)
        i_s = jnp.exp(logi - m_new)
        f_s = jnp.exp(logf + st["m"] - m_new)
        c = f_s * st["c"] + i_s * zt
        n = f_s * st["n"] + i_s
        h = jax.nn.sigmoid(o["o"]) * c / jnp.maximum(n, 1e-6)
        ns = {"h": h, "c": c, "n": n, "m": m_new}
        return ns, h

    new_state, hs = jax.lax.scan(step, state, xn.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(x.dtype)
    out = x + y
    hn = L.apply_norm(cfg, p["ffn_norm"], out)
    out = out + (jax.nn.gelu(hn @ p["w_up"], approximate=True) @ p["w_dn"])
    return out, new_state


# ----------------------------------------------------------------------
# model API
# ----------------------------------------------------------------------

def _take(tree, i):
    return jax.tree.map(lambda x: x[i], tree)


def _group_apply(cfg, gp, x, states=None):
    G_m = gp["mlstm"]["w_i"].shape[0]
    new_m = []
    for i in range(G_m):
        st = None if states is None else _take(states["mlstm"], i)
        x, ns = mlstm_block(cfg, _take(gp["mlstm"], i), x, st)
        new_m.append(ns)
    s_st = None if states is None else states["slstm"]
    x, s_new = slstm_apply(cfg, gp["slstm"], x, s_st)
    m_states = jax.tree.map(lambda *xs: jnp.stack(xs), *new_m)
    return x, {"mlstm": m_states, "slstm": s_new}


def forward(cfg: ModelConfig, params, batch, *, remat=False, last_only=False,
            return_states=False):
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = params["embed"]["tok"][tokens]

    def body(hh, gp):
        hh = ctx.constrain(hh)
        y, st = _group_apply(cfg, gp, hh)
        return y, st

    if remat:
        body = jax.checkpoint(body)
    h, states = ctx.lscan(body, h, params["groups"])
    h = L.apply_norm(cfg, params["final_norm"], h)
    if last_only:
        h = h[:, -1:]
    logits = h @ params["lm_head"]
    if return_states:
        return logits, states
    return logits


def cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    G, n_m = _group_counts(cfg)
    D, Di, H, dh, _ = _dims(cfg)
    cw = cfg.conv_width
    f32 = jnp.float32
    return {
        "mlstm": {
            "C": jax.ShapeDtypeStruct((G, n_m, batch, H, dh, dh), f32),
            "n": jax.ShapeDtypeStruct((G, n_m, batch, H, dh), f32),
            "conv": jax.ShapeDtypeStruct((G, n_m, batch, cw - 1, Di), jnp.bfloat16),
        },
        "slstm": {
            k: jax.ShapeDtypeStruct((G, batch, D), f32)
            for k in ("h", "c", "n", "m")
        },
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    c = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                     cache_spec(cfg, batch, max_len))
    c["slstm"]["n"] = c["slstm"]["n"] + 1e-6
    return c


def prefill(cfg: ModelConfig, params, tokens, max_len: int):
    B, S = tokens.shape
    logits, states = forward(cfg, params, {"tokens": tokens}, last_only=True,
                             return_states=True)
    return logits[:, -1], states


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    B = tokens.shape[0]
    h = params["embed"]["tok"][tokens]

    def body(hh, xs):
        gp, st = xs
        y, ns = _group_apply(cfg, gp, hh, st)
        return y, ns

    h, states = ctx.lscan(body, h, (params["groups"], cache))
    h = L.apply_norm(cfg, params["final_norm"], h)
    logits = (h @ params["lm_head"])[:, 0]
    return logits, states
