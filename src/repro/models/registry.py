"""Uniform Model API over all architecture families.

    model = get_model(cfg)
    specs  = model.param_specs()
    params = model.init(rng)
    logits = model.forward(params, batch)
    last, cache = model.prefill(params, batch, max_len)
    logits, cache = model.decode_step(params, cache, tokens, pos)
    batch = model.input_specs(cell)      # ShapeDtypeStructs for the dry-run
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import common


@dataclass
class Model:
    cfg: ModelConfig
    _mod: Any

    # ---- params ----
    def param_specs(self):
        return self._mod.param_specs(self.cfg)

    def abstract_params(self):
        return common.abstract_params(self.param_specs())

    def init(self, rng):
        return common.init_params(rng, self.param_specs())

    # ---- compute ----
    def forward(self, params, batch, *, remat=False, last_only=False):
        return self._mod.forward(self.cfg, params, batch, remat=remat,
                                 last_only=last_only)

    def prefill(self, params, batch, max_len):
        if self.cfg.family == "encdec":
            return self._mod.prefill(self.cfg, params, batch, max_len)
        return self._mod.prefill(self.cfg, params, batch["tokens"], max_len)

    def decode_step(self, params, cache, tokens, pos):
        return self._mod.decode_step(self.cfg, params, cache, tokens, pos)

    def cache_spec(self, batch, max_len):
        return self._mod.cache_spec(self.cfg, batch, max_len)

    def init_cache(self, batch, max_len):
        return self._mod.init_cache(self.cfg, batch, max_len)

    # ---- dry-run inputs ----
    def input_specs(self, cell: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input of a shape cell."""
        cfg = self.cfg
        B, S = cell.global_batch, cell.seq_len
        tok = lambda b, s: jax.ShapeDtypeStruct((b, s), jnp.int32)
        if cell.kind == "train":
            batch = {"tokens": tok(B, S), "targets": tok(B, S)}
            if cfg.family == "encdec":
                batch["enc_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
            return batch
        if cell.kind == "prefill":
            batch = {"tokens": tok(B, S)}
            if cfg.family == "encdec":
                batch["enc_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
            return batch
        # decode: one new token against a cache of length S
        return {"tokens": tok(B, 1)}


_FAMILY_MODULES = {}


def _family_module(family: str):
    if family not in _FAMILY_MODULES:
        if family in ("dense", "moe", "mla_moe"):
            from repro.models import transformer as m
        elif family == "encdec":
            from repro.models import encdec as m
        elif family == "rglru":
            from repro.models import rglru as m
        elif family == "xlstm":
            from repro.models import xlstm as m
        else:
            raise KeyError(f"unknown family {family!r}")
        _FAMILY_MODULES[family] = m
    return _FAMILY_MODULES[family]


def get_model(cfg: ModelConfig) -> Model:
    return Model(cfg, _family_module(cfg.family))
