from repro.models.registry import Model, get_model
from repro.models.common import (
    ParamSpec, spec, abstract_params, init_params, param_count, param_bytes,
)

__all__ = ["Model", "get_model", "ParamSpec", "spec", "abstract_params",
           "init_params", "param_count", "param_bytes"]
