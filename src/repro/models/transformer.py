"""Decoder-only transformer family: dense / moe / mla_moe.

Layer params are stacked along a leading 'layers' axis and iterated with
``lax.scan`` (keeps HLO size and compile time bounded at 64 layers). Prefill
emits per-layer K/V as scan outputs — they *are* the KV cache.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import ctx
from repro.models import layers as L
from repro.models.common import spec


# ----------------------------------------------------------------------
# Param specs
# ----------------------------------------------------------------------

def _attn_specs(cfg: ModelConfig):
    D, Hq, Hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.family == "mla_moe":
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        p = {
            "norm": L.norm_specs(cfg),
            "wq": spec((D, Hq, qk), ("embed", "q_heads", "head_dim")),
            "w_dkv": spec((D, cfg.kv_lora_rank + cfg.qk_rope_dim),
                          ("embed", "kv_lora")),
            "kv_norm": {"scale": spec((cfg.kv_lora_rank,), ("kv_lora",), init="ones")},
            "w_uk": spec((cfg.kv_lora_rank, Hq, cfg.qk_nope_dim),
                         ("kv_lora", "q_heads", "head_dim")),
            "w_uv": spec((cfg.kv_lora_rank, Hq, cfg.v_head_dim),
                         ("kv_lora", "q_heads", "head_dim")),
            "wo": spec((Hq, cfg.v_head_dim, D), ("q_heads", "head_dim", "embed"),
                       fan_in_axes=(0, 1)),
        }
        return p
    p = {
        "norm": L.norm_specs(cfg),
        "wq": spec((D, Hq, dh), ("embed", "q_heads", "head_dim")),
        "wk": spec((D, Hkv, dh), ("embed", "kv_heads", "head_dim")),
        "wv": spec((D, Hkv, dh), ("embed", "kv_heads", "head_dim")),
        "wo": spec((Hq, dh, D), ("q_heads", "head_dim", "embed"), fan_in_axes=(0, 1)),
    }
    if cfg.qkv_bias:
        p["bq"] = spec((Hq, dh), ("q_heads", "head_dim"), init="zeros")
        p["bk"] = spec((Hkv, dh), ("kv_heads", "head_dim"), init="zeros")
        p["bv"] = spec((Hkv, dh), ("kv_heads", "head_dim"), init="zeros")
    if cfg.attn_out_bias:
        p["bo"] = spec((D,), ("embed",), init="zeros")
    return p


def _layer_specs(cfg: ModelConfig, moe: bool):
    p = {"attn": _attn_specs(cfg), "mlp_norm": L.norm_specs(cfg)}
    if moe:
        p["mlp"] = L.moe_specs(cfg)
    else:
        p["mlp"] = L.ffn_specs(cfg)
    return p


def _stack(tree, n):
    return jax.tree.map(
        lambda s: s._replace(shape=(n,) + s.shape, axes=("layers",) + s.axes,
                             fan_in_axes=tuple(a + 1 for a in s.fan_in_axes)),
        tree,
        is_leaf=lambda x: hasattr(x, "axes") and not isinstance(x, dict),
    )


def param_specs(cfg: ModelConfig):
    moe = cfg.n_experts > 0
    n_moe_layers = cfg.n_layers - cfg.first_dense_layers
    p: Dict[str, Any] = {
        "embed": {"tok": spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                              fan_in_axes=())},
        "final_norm": L.norm_specs(cfg),
    }
    if cfg.first_dense_layers:
        p["dense_layers"] = _stack(_layer_specs(cfg, moe=False), cfg.first_dense_layers)
    p["layers"] = _stack(_layer_specs(cfg, moe=moe), n_moe_layers)
    if not cfg.tie_embeddings:
        p["lm_head"] = spec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return p


# ----------------------------------------------------------------------
# Blocks
# ----------------------------------------------------------------------

def _dense_attn(cfg, p, x, positions, *, window):
    h = L.apply_norm(cfg, p["norm"], x)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = L.apply_rope(cfg, q, positions)
    k = L.apply_rope(cfg, k, positions)
    o = L.attention(cfg, q, k, v, window=window)
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if cfg.attn_out_bias:
        y = y + p["bo"]
    return x + y, (k, v)


def _mla_attn(cfg, p, x, positions):
    """Train/prefill MLA: expand compressed KV to per-head K/V."""
    B, S, D = x.shape
    H = cfg.n_heads
    h = L.apply_norm(cfg, p["norm"], x)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    q_nope, q_rope = q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    q_rope = L.apply_rope(cfg, q_rope, positions)

    ckv_full = h @ p["w_dkv"]                                     # (B,S,lora+rope)
    c_kv = L.rms_norm(ckv_full[..., : cfg.kv_lora_rank], p["kv_norm"]["scale"])
    k_rope = ckv_full[..., cfg.kv_lora_rank:][:, :, None, :]      # (B,S,1,rope)
    k_rope = L.apply_rope(cfg, k_rope, positions)

    k_nope = jnp.einsum("bsc,chk->bshk", c_kv, p["w_uk"])
    v = jnp.einsum("bsc,chv->bshv", c_kv, p["w_uv"])
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, cfg.qk_rope_dim))],
                        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = L.attention(cfg, q, k, v)
    y = jnp.einsum("bshv,hvd->bsd", o, p["wo"])
    return x + y, (c_kv, k_rope[:, :, 0, :])


def _mla_attn_decode(cfg, p, x, ckv_cache, krope_cache, pos, valid):
    """Absorbed MLA decode: attention runs in the compressed c_kv space.

    Beyond-paper optimization: avoids re-expanding per-head K/V every step —
    per-token work is O(S*(lora+rope)) instead of O(S*H*dh).
    """
    B = x.shape[0]
    h = L.apply_norm(cfg, p["norm"], x)                            # (B,1,D)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    q_nope, q_rope = q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    posv = jnp.broadcast_to(pos[None, None], (B, 1))
    q_rope = L.apply_rope(cfg, q_rope, posv)

    ckv_full = h[:, 0] @ p["w_dkv"]                                # (B,lora+rope)
    c_new = L.rms_norm(ckv_full[:, : cfg.kv_lora_rank], p["kv_norm"]["scale"])
    kr_new = L.apply_rope(cfg, ckv_full[:, None, None, cfg.kv_lora_rank:], posv)[:, 0, 0]

    from repro.distributed import ctx as _ctx
    ckv_cache = _ctx.constrain_named(
        "cache_mla", jax.lax.dynamic_update_slice_in_dim(ckv_cache, c_new[:, None], pos, 1))
    krope_cache = _ctx.constrain_named(
        "cache_mla", jax.lax.dynamic_update_slice_in_dim(krope_cache, kr_new[:, None], pos, 1))

    q_c = jnp.einsum("bihn,chn->bihc", q_nope, p["w_uk"])          # absorb W_UK
    s = jnp.einsum("bihc,bsc->bhs", q_c, ckv_cache, preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bihr,bsr->bhs", q_rope, krope_cache,
                       preferred_element_type=jnp.float32)
    s = s / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    s = jnp.where(valid[:, None, :], s, -jnp.inf)
    pattn = jax.nn.softmax(s, axis=-1)
    ctx_c = jnp.einsum("bhs,bsc->bhc", pattn.astype(ckv_cache.dtype), ckv_cache)
    ctx = jnp.einsum("bhc,chv->bhv", ctx_c, p["w_uv"])             # absorb W_UV
    y = jnp.einsum("bhv,hvd->bd", ctx, p["wo"])[:, None]
    return x + y, ckv_cache, krope_cache


def _mlp(cfg, p_norm, p_mlp, x, moe: bool):
    h = L.apply_norm(cfg, p_norm, x)
    y = L.moe_apply(cfg, p_mlp, h) if moe else L.ffn_apply(cfg, p_mlp, h)
    return x + y


def _layer(cfg, lp, x, positions, *, moe: bool):
    if cfg.family == "mla_moe":
        x, kv = _mla_attn(cfg, lp["attn"], x, positions)
    else:
        x, kv = _dense_attn(cfg, lp["attn"], x, positions, window=cfg.sliding_window)
    x = _mlp(cfg, lp["mlp_norm"], lp["mlp"], x, moe)
    return x, kv


# ----------------------------------------------------------------------
# Model API
# ----------------------------------------------------------------------

def embed_tokens(cfg, params, tokens):
    return params["embed"]["tok"][tokens]


def unembed(cfg, params, h):
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", h, params["embed"]["tok"])
    return h @ params["lm_head"]


def forward(cfg: ModelConfig, params, batch, *, remat: bool = False,
            return_cache: bool = False, last_only: bool = False):
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = embed_tokens(cfg, params, tokens)
    if "patch_embeds" in batch:   # VLM stub: prefix replaced by patch embeds
        pe = batch["patch_embeds"].astype(h.dtype)
        h = jnp.concatenate([pe, h[:, pe.shape[1]:]], axis=1)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    moe = cfg.n_experts > 0

    def dense_body(hh, lp):
        hh = ctx.constrain(hh)
        y, kv = _layer(cfg, lp, hh, positions, moe=False)
        return y, kv

    def body(hh, lp):
        hh = ctx.constrain(hh)
        y, kv = _layer(cfg, lp, hh, positions, moe=moe)
        return y, kv

    if remat:
        dense_body = jax.checkpoint(dense_body)
        body = jax.checkpoint(body)

    caches = []
    if cfg.first_dense_layers:
        h, kv0 = ctx.lscan(dense_body, h, params["dense_layers"])
        caches.append(kv0)
    h, kv = ctx.lscan(body, h, params["layers"])
    caches.append(kv)

    h = L.apply_norm(cfg, params["final_norm"], h)
    if last_only:
        h = h[:, -1:]
    logits = unembed(cfg, params, h)
    if return_cache:
        return logits, caches
    return logits


# ---------------------------- serving --------------------------------

def cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    """ShapeDtypeStruct tree for the decode cache."""
    dt = jnp.bfloat16
    if cfg.sliding_window:
        max_len = min(max_len, cfg.sliding_window)
    Lm = cfg.n_layers - cfg.first_dense_layers
    if cfg.family == "mla_moe":
        mk = lambda l, d: jax.ShapeDtypeStruct((l, batch, max_len, d), dt)
        c = {"ckv": mk(Lm, cfg.kv_lora_rank), "krope": mk(Lm, cfg.qk_rope_dim)}
        if cfg.first_dense_layers:
            c["ckv0"] = mk(cfg.first_dense_layers, cfg.kv_lora_rank)
            c["krope0"] = mk(cfg.first_dense_layers, cfg.qk_rope_dim)
        return c
    sh = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(sh, dt), "v": jax.ShapeDtypeStruct(sh, dt)}


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, max_len))


def prefill(cfg: ModelConfig, params, tokens, max_len: int):
    """Run full forward, return (last-token logits, cache filled to S)."""
    B, S = tokens.shape
    logits, caches = forward(cfg, params, {"tokens": tokens}, return_cache=True,
                             last_only=True)
    cache = init_cache(cfg, B, max_len)
    W = cfg.sliding_window
    if cfg.family == "mla_moe":
        if cfg.first_dense_layers:
            (c0, kr0), (c1, kr1) = caches
            cache["ckv0"] = cache["ckv0"].at[:, :, :S].set(c0)
            cache["krope0"] = cache["krope0"].at[:, :, :S].set(kr0)
        else:
            (c1, kr1) = caches[0]
        cache["ckv"] = cache["ckv"].at[:, :, :S].set(c1)
        cache["krope"] = cache["krope"].at[:, :, :S].set(kr1)
    else:
        k, v = caches[0]
        if W and S > W:       # keep last W positions, ring-aligned
            k, v = k[:, :, S - W:], v[:, :, S - W:]
            roll = (S - W) % W
            k = jnp.roll(k, roll, axis=2)
            v = jnp.roll(v, roll, axis=2)
            cache["k"], cache["v"] = k, v
        else:
            cache["k"] = cache["k"].at[:, :, :S].set(k)
            cache["v"] = cache["v"].at[:, :, :S].set(v)
    return logits[:, -1], cache


def _decode_dense_layer(cfg, lp, hh, kc, vc, idx, posv, valid, moe):
    p = lp["attn"]
    hn = L.apply_norm(cfg, p["norm"], hh)
    q = jnp.einsum("bsd,dhk->bshk", hn, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", hn, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", hn, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = L.apply_rope(cfg, q, posv)
    k = L.apply_rope(cfg, k, posv)
    kc = ctx.constrain_named("cache_kv",
        jax.lax.dynamic_update_slice_in_dim(kc, k, idx, 1))
    vc = ctx.constrain_named("cache_kv",
        jax.lax.dynamic_update_slice_in_dim(vc, v, idx, 1))
    o = L.decode_attention(q, kc, vc, valid)
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if cfg.attn_out_bias:
        y = y + p["bo"]
    hh = hh + y
    hh = _mlp(cfg, lp["mlp_norm"], lp["mlp"], hh, moe)
    return hh, (kc, vc)


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """tokens (B,1) int32, pos scalar int32 (next position index).

    Returns (logits (B,V), new cache).
    """
    B = tokens.shape[0]
    h = embed_tokens(cfg, params, tokens)
    moe = cfg.n_experts > 0
    W = cfg.sliding_window
    posv = jnp.broadcast_to(pos[None, None], (B, 1))

    if cfg.family == "mla_moe":
        S = cache["ckv"].shape[2]
        valid = (jnp.arange(S)[None] <= pos) & jnp.ones((B, 1), bool)

        def body(hh, xs):
            lp, ckv, kr = xs
            y, ckv2, kr2 = _mla_attn_decode(cfg, lp["attn"], hh, ckv, kr, pos, valid)
            y = _mlp(cfg, lp["mlp_norm"], lp["mlp"], y, moe)
            return y, (ckv2, kr2)

        def body_dense(hh, xs):
            lp, ckv, kr = xs
            y, ckv2, kr2 = _mla_attn_decode(cfg, lp["attn"], hh, ckv, kr, pos, valid)
            y = _mlp(cfg, lp["mlp_norm"], lp["mlp"], y, moe=False)
            return y, (ckv2, kr2)

        if cfg.first_dense_layers:
            h, (c0, r0) = ctx.lscan(
                body_dense, h, (params["dense_layers"], cache["ckv0"], cache["krope0"]))
            cache = dict(cache, ckv0=c0, krope0=r0)
        h, (c1, r1) = ctx.lscan(body, h, (params["layers"], cache["ckv"], cache["krope"]))
        cache = dict(cache, ckv=c1, krope=r1)
    else:
        S = cache["k"].shape[2]
        idx = jnp.mod(pos, S) if W else pos
        valid = (jnp.arange(S)[None] < jnp.minimum(pos + 1, S)) & jnp.ones((B, 1), bool)

        def body(hh, xs):
            lp, kc, vc = xs
            return _decode_dense_layer(cfg, lp, hh, kc, vc, idx, posv, valid,
                                       moe)

        if ctx.perf().decode_cache_carry:
            # carry the full stacked cache; per-layer in-place slice updates
            def body_carry(carry, xs):
                hh, kfull, vfull = carry
                lp, li = xs
                kc = jax.lax.dynamic_index_in_dim(kfull, li, 0, keepdims=False)
                vc = jax.lax.dynamic_index_in_dim(vfull, li, 0, keepdims=False)
                hh, (kc2, vc2) = _decode_dense_layer(
                    cfg, lp, hh, kc, vc, idx, posv, valid, moe)
                kfull = jax.lax.dynamic_update_index_in_dim(kfull, kc2, li, 0)
                vfull = jax.lax.dynamic_update_index_in_dim(vfull, vc2, li, 0)
                return (hh, kfull, vfull), None

            (h, kfull, vfull), _ = ctx.lscan(
                body_carry, (h, cache["k"], cache["v"]),
                (params["layers"], jnp.arange(cfg.n_layers, dtype=jnp.int32)))
            cache = dict(cache, k=kfull, v=vfull)
        else:
            h, (kc, vc) = ctx.lscan(body, h, (params["layers"], cache["k"],
                                              cache["v"]))
            cache = dict(cache, k=kc, v=vc)

    h = L.apply_norm(cfg, params["final_norm"], h)
    logits = unembed(cfg, params, h)[:, 0]
    return logits, cache
