"""RecurrentGemma / Griffin family: RG-LRU recurrent blocks + local attention.

Pattern ('rec','rec','attn') cycles over n_layers; full groups are scanned,
the remainder (38 = 12*3 + 2 → two trailing rec layers) is a second scan.
The RG-LRU linear recurrence uses ``lax.associative_scan`` for train/prefill
(parallel, log-depth) and a single fused step for decode.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import ctx
from repro.models import layers as L
from repro.models.common import spec

_C_RGLRU = 8.0


# ----------------------------------------------------------------------
# specs
# ----------------------------------------------------------------------

def _rec_specs(cfg: ModelConfig):
    D, Dr, cw = cfg.d_model, cfg.d_rnn, cfg.conv_width
    return {
        "norm": L.norm_specs(cfg),
        "w_gate": spec((D, Dr), ("embed", "rnn")),
        "w_branch": spec((D, Dr), ("embed", "rnn")),
        "conv_w": spec((cw, Dr), ("conv", "rnn"), fan_in_axes=(0,)),
        "conv_b": spec((Dr,), ("rnn",), init="zeros"),
        "w_rg": spec((Dr, Dr), ("rnn_in", "rnn")),
        "b_rg": spec((Dr,), ("rnn",), init="zeros"),
        "w_ig": spec((Dr, Dr), ("rnn_in", "rnn")),
        "b_ig": spec((Dr,), ("rnn",), init="zeros"),
        "lam": spec((Dr,), ("rnn",), init="ones"),
        "w_out": spec((Dr, D), ("rnn", "embed")),
    }


def _attn_specs(cfg: ModelConfig):
    from repro.models.transformer import _attn_specs as dense_attn_specs
    return dense_attn_specs(cfg)


def _mlp_specs(cfg: ModelConfig):
    return L.ffn_specs(cfg)


def _stack(tree, n):
    return jax.tree.map(
        lambda s: s._replace(shape=(n,) + s.shape, axes=("layers",) + s.axes,
                             fan_in_axes=tuple(a + 1 for a in s.fan_in_axes)),
        tree,
        is_leaf=lambda x: hasattr(x, "axes") and not isinstance(x, dict),
    )


def _group_counts(cfg: ModelConfig):
    plen = len(cfg.block_pattern)
    return cfg.n_layers // plen, cfg.n_layers % plen


def param_specs(cfg: ModelConfig):
    G, tail = _group_counts(cfg)
    n_rec_in_group = sum(1 for b in cfg.block_pattern if b == "rec")
    group = {
        "rec": _stack(_rec_specs(cfg), n_rec_in_group),
        "rec_mlp": _stack(_mlp_specs(cfg), n_rec_in_group),
        "attn": _attn_specs(cfg),
        "attn_mlp": _mlp_specs(cfg),
    }
    p = {
        "embed": {"tok": spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                              fan_in_axes=())},
        "groups": _stack(group, G),
        "final_norm": L.norm_specs(cfg),
        "lm_head": spec((cfg.d_model, cfg.vocab_size), ("embed", "vocab")),
    }
    if tail:
        assert all(b == "rec" for b in cfg.block_pattern[:tail])
        p["tail_rec"] = _stack(_rec_specs(cfg), tail)
        p["tail_mlp"] = _stack(_mlp_specs(cfg), tail)
    return p


# ----------------------------------------------------------------------
# RG-LRU block
# ----------------------------------------------------------------------

def causal_conv(u, w, b, state=None):
    """Depthwise causal conv. u (B,S,Dr), w (cw,Dr). Returns (y, new_state)."""
    B, S, Dr = u.shape
    cw = w.shape[0]
    if state is None:
        state = jnp.zeros((B, cw - 1, Dr), u.dtype)
    ext = jnp.concatenate([state, u], axis=1)
    y = sum(ext[:, i:i + S] * w[i] for i in range(cw))
    new_state = ext[:, S:] if cw > 1 else state
    return y + b, new_state


def _lru_coeffs(p, u):
    r = jax.nn.sigmoid((u @ p["w_rg"] + p["b_rg"]).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ p["w_ig"] + p["b_ig"]).astype(jnp.float32))
    log_a = -_C_RGLRU * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * (
        i * u.astype(jnp.float32))
    return a, b


def rec_block(cfg, p, x, state=None):
    """x (B,S,D). state = {'h': (B,Dr), 'conv': (B,cw-1,Dr)} or None.
    Returns (y, new_state)."""
    h = L.apply_norm(cfg, p["norm"], x)
    gate = jax.nn.gelu(h @ p["w_gate"], approximate=True)
    u = h @ p["w_branch"]
    conv_state = state["conv"] if state is not None else None
    u, new_conv = causal_conv(u, p["conv_w"], p["conv_b"], conv_state)
    a, b = _lru_coeffs(p, u)

    if state is None:
        hid = jax.lax.associative_scan(
            lambda c1, c2: (c1[0] * c2[0], c2[0] * c1[1] + c2[1]), (a, b), axis=1)[1]
        new_h = hid[:, -1]
    else:
        new_h = a[:, 0] * state["h"] + b[:, 0]
        hid = new_h[:, None]
    y = (gate * hid.astype(gate.dtype)) @ p["w_out"]
    return x + y, {"h": new_h, "conv": new_conv}


def _attn_block(cfg, p, x, positions):
    from repro.models.transformer import _dense_attn
    return _dense_attn(cfg, p, x, positions, window=cfg.sliding_window)


def _mlp_block(cfg, pn_mlp, x):
    # geglu MLP with its own pre-norm folded into ffn params via mlp norm spec
    return x + L.ffn_apply(cfg, pn_mlp["ffn"], L.apply_norm(cfg, pn_mlp["norm"], x))


def _mlp_specs_full(cfg):
    return {"norm": L.norm_specs(cfg), "ffn": _mlp_specs(cfg)}


# patch group spec to carry norms with mlps
def _rebuild_group_specs(cfg):
    n_rec = sum(1 for b in cfg.block_pattern if b == "rec")
    return {
        "rec": _stack(_rec_specs(cfg), n_rec),
        "rec_mlp": _stack(_mlp_specs_full(cfg), n_rec),
        "attn": _attn_specs(cfg),
        "attn_mlp": _mlp_specs_full(cfg),
    }


def param_specs(cfg: ModelConfig):   # noqa: F811 (final definition)
    G, tail = _group_counts(cfg)
    p = {
        "embed": {"tok": spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                              fan_in_axes=())},
        "groups": _stack(_rebuild_group_specs(cfg), G),
        "final_norm": L.norm_specs(cfg),
        "lm_head": spec((cfg.d_model, cfg.vocab_size), ("embed", "vocab")),
    }
    if tail:
        assert all(b == "rec" for b in cfg.block_pattern[:tail])
        p["tail_rec"] = _stack(_rec_specs(cfg), tail)
        p["tail_mlp"] = _stack(_mlp_specs_full(cfg), tail)
    return p


# ----------------------------------------------------------------------
# forward / prefill / decode
# ----------------------------------------------------------------------

def _take(tree, i):
    return jax.tree.map(lambda x: x[i], tree)


def _group_apply(cfg, gp, x, positions, states=None):
    """Apply one (rec, rec, attn) group. states: group state dict or None."""
    n_rec = gp["rec"]["lam"].shape[0]
    new_rec_states = []
    kv = None
    li = 0
    for b in cfg.block_pattern:
        if b == "rec":
            st = None if states is None else _take(states["rec"], li)
            x, ns = rec_block(cfg, _take(gp["rec"], li), x, st)
            x = _mlp_block(cfg, _take(gp["rec_mlp"], li), x)
            new_rec_states.append(ns)
            li += 1
        else:
            x, kv = _attn_block(cfg, gp["attn"], x, positions)
            x = _mlp_block(cfg, gp["attn_mlp"], x)
    rec_states = jax.tree.map(lambda *xs: jnp.stack(xs), *new_rec_states)
    return x, rec_states, kv


def forward(cfg: ModelConfig, params, batch, *, remat=False, last_only=False,
            return_states=False):
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = params["embed"]["tok"][tokens]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(hh, gp):
        hh = ctx.constrain(hh)
        y, rec_states, kv = _group_apply(cfg, gp, hh, positions)
        return y, (rec_states, kv)

    if remat:
        body = jax.checkpoint(body)
    h, (rec_states, kvs) = ctx.lscan(body, h, params["groups"])

    tail_states = None
    if "tail_rec" in params:
        def tail_body(hh, xs):
            rp, mp = xs
            y, ns = rec_block(cfg, rp, hh)
            y = _mlp_block(cfg, mp, y)
            return y, ns
        if remat:
            tail_body = jax.checkpoint(tail_body)
        h, tail_states = ctx.lscan(tail_body, h,
                                      (params["tail_rec"], params["tail_mlp"]))

    h = L.apply_norm(cfg, params["final_norm"], h)
    if last_only:
        h = h[:, -1:]
    logits = h @ params["lm_head"]
    if return_states:
        return logits, (rec_states, kvs, tail_states)
    return logits


def cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    G, tail = _group_counts(cfg)
    n_rec = sum(1 for b in cfg.block_pattern if b == "rec")
    W = min(cfg.sliding_window, max_len)
    dt = jnp.bfloat16
    f32 = jnp.float32
    c = {
        "rec": {
            "h": jax.ShapeDtypeStruct((G, n_rec, batch, cfg.d_rnn), f32),
            "conv": jax.ShapeDtypeStruct((G, n_rec, batch, cfg.conv_width - 1,
                                          cfg.d_rnn), dt),
        },
        "k": jax.ShapeDtypeStruct((G, batch, W, cfg.n_kv_heads, cfg.head_dim), dt),
        "v": jax.ShapeDtypeStruct((G, batch, W, cfg.n_kv_heads, cfg.head_dim), dt),
    }
    if tail:
        c["tail"] = {
            "h": jax.ShapeDtypeStruct((tail, batch, cfg.d_rnn), f32),
            "conv": jax.ShapeDtypeStruct((tail, batch, cfg.conv_width - 1,
                                          cfg.d_rnn), dt),
        }
    return c


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, max_len))


def prefill(cfg: ModelConfig, params, tokens, max_len: int):
    B, S = tokens.shape
    logits, (rec_states, kvs, tail_states) = forward(
        cfg, params, {"tokens": tokens}, last_only=True, return_states=True)
    cache = init_cache(cfg, B, max_len)
    cache["rec"]["h"] = rec_states["h"].astype(jnp.float32)
    cache["rec"]["conv"] = rec_states["conv"].astype(jnp.bfloat16)
    k, v = kvs
    W = cache["k"].shape[2]
    if S > W:
        k, v = k[:, :, S - W:], v[:, :, S - W:]
        roll = (S - W) % W
        k = jnp.roll(k, roll, axis=2)
        v = jnp.roll(v, roll, axis=2)
        cache["k"], cache["v"] = k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)
    else:
        cache["k"] = cache["k"].at[:, :, :S].set(k)
        cache["v"] = cache["v"].at[:, :, :S].set(v)
    if tail_states is not None:
        cache["tail"]["h"] = tail_states["h"].astype(jnp.float32)
        cache["tail"]["conv"] = tail_states["conv"].astype(jnp.bfloat16)
    return logits[:, -1], cache


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    B = tokens.shape[0]
    h = params["embed"]["tok"][tokens]
    posv = jnp.broadcast_to(pos[None, None], (B, 1))
    W = cache["k"].shape[2]
    idx = jnp.mod(pos, W)
    valid = (jnp.arange(W)[None] < jnp.minimum(pos + 1, W)) & jnp.ones((B, 1), bool)

    def body(hh, xs):
        gp, rec_st, kc, vc = xs
        li = 0
        new_rec = []
        for b in cfg.block_pattern:
            if b == "rec":
                st = _take(rec_st, li)
                hh, ns = rec_block(cfg, _take(gp["rec"], li), hh,
                                   {"h": st["h"], "conv": st["conv"]})
                hh = _mlp_block(cfg, _take(gp["rec_mlp"], li), hh)
                new_rec.append(ns)
                li += 1
            else:
                p = gp["attn"]
                hn = L.apply_norm(cfg, p["norm"], hh)
                q = jnp.einsum("bsd,dhk->bshk", hn, p["wq"])
                k = jnp.einsum("bsd,dhk->bshk", hn, p["wk"])
                v = jnp.einsum("bsd,dhk->bshk", hn, p["wv"])
                q = L.apply_rope(cfg, q, posv)
                k = L.apply_rope(cfg, k, posv)
                kc = ctx.constrain_named("cache_kv",
                    jax.lax.dynamic_update_slice_in_dim(kc, k, idx, 1))
                vc = ctx.constrain_named("cache_kv",
                    jax.lax.dynamic_update_slice_in_dim(vc, v, idx, 1))
                o = L.decode_attention(q, kc, vc, valid)
                hh = hh + jnp.einsum("bshk,hkd->bsd", o, p["wo"])
                hh = _mlp_block(cfg, gp["attn_mlp"], hh)
        rec_states = jax.tree.map(lambda *xs: jnp.stack(xs), *new_rec)
        return hh, (rec_states, kc, vc)

    h, (rec_states, kc, vc) = ctx.lscan(
        body, h, (params["groups"], cache["rec"], cache["k"], cache["v"]))
    cache = dict(cache, rec=rec_states, k=kc, v=vc)

    if "tail_rec" in params:
        def tail_body(hh, xs):
            rp, mp, st = xs
            y, ns = rec_block(cfg, rp, hh, {"h": st["h"], "conv": st["conv"]})
            y = _mlp_block(cfg, mp, y)
            return y, ns
        h, tail_states = ctx.lscan(
            tail_body, h, (params["tail_rec"], params["tail_mlp"], cache["tail"]))
        cache = dict(cache, tail=tail_states)

    h = L.apply_norm(cfg, params["final_norm"], h)
    logits = (h @ params["lm_head"])[:, 0]
    return logits, cache
