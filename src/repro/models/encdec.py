"""Encoder-decoder family (whisper-small backbone).

The audio frontend (mel conv stack) is a stub per the assignment: inputs are
precomputed frame embeddings (B, enc_seq, d_model). Positions are sinusoidal
(no learned table → any sequence length lowers cleanly).
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import ctx
from repro.models import layers as L
from repro.models.common import spec


def sinusoid_pos(S, D, offset=0):
    pos = np.arange(S) if isinstance(S, int) else S
    pos = jnp.asarray(pos, jnp.float32) + offset
    inv = 1.0 / (10000 ** (np.arange(0, D, 2) / D))
    ang = pos[:, None] * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(jnp.bfloat16)


def _attn_specs(cfg, cross=False):
    D, H, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    p = {
        "norm": L.norm_specs(cfg),
        "wq": spec((D, H, dh), ("embed", "q_heads", "head_dim")),
        "wk": spec((D, H, dh), ("embed", "kv_heads", "head_dim")),
        "wv": spec((D, H, dh), ("embed", "kv_heads", "head_dim")),
        "wo": spec((H, dh, D), ("q_heads", "head_dim", "embed"), fan_in_axes=(0, 1)),
    }
    if cfg.qkv_bias:
        p["bq"] = spec((H, dh), ("q_heads", "head_dim"), init="zeros")
        p["bv"] = spec((H, dh), ("kv_heads", "head_dim"), init="zeros")
    if cfg.attn_out_bias:
        p["bo"] = spec((D,), ("embed",), init="zeros")
    return p


def _stack(tree, n):
    return jax.tree.map(
        lambda s: s._replace(shape=(n,) + s.shape, axes=("layers",) + s.axes,
                             fan_in_axes=tuple(a + 1 for a in s.fan_in_axes)),
        tree,
        is_leaf=lambda x: hasattr(x, "axes") and not isinstance(x, dict),
    )


def param_specs(cfg: ModelConfig):
    enc_layer = {"attn": _attn_specs(cfg), "mlp_norm": L.norm_specs(cfg),
                 "mlp": L.ffn_specs(cfg)}
    dec_layer = {"self_attn": _attn_specs(cfg), "cross_attn": _attn_specs(cfg),
                 "mlp_norm": L.norm_specs(cfg), "mlp": L.ffn_specs(cfg)}
    return {
        "embed": {"tok": spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                              fan_in_axes=())},
        "enc_layers": _stack(enc_layer, cfg.n_encoder_layers),
        "enc_final_norm": L.norm_specs(cfg),
        "dec_layers": _stack(dec_layer, cfg.n_layers),
        "final_norm": L.norm_specs(cfg),
    }


def _proj_qkv(cfg, p, xq, xkv):
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"])
    if cfg.qkv_bias:
        q, v = q + p["bq"], v + p["bv"]
    return q, k, v


def _out(cfg, p, o):
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if cfg.attn_out_bias:
        y = y + p["bo"]
    return y


def encode(cfg: ModelConfig, params, enc_embeds):
    B, S, D = enc_embeds.shape
    h = enc_embeds.astype(jnp.bfloat16) + sinusoid_pos(S, D)[None]

    def body(hh, lp):
        hn = L.apply_norm(cfg, lp["attn"]["norm"], hh)
        q, k, v = _proj_qkv(cfg, lp["attn"], hn, hn)
        o = L.attention(cfg, q, k, v, causal=False)
        hh = hh + _out(cfg, lp["attn"], o)
        hn = L.apply_norm(cfg, lp["mlp_norm"], hh)
        hh = hh + L.ffn_apply(cfg, lp["mlp"], hn)
        return hh, None

    h, _ = ctx.lscan(body, h, params["enc_layers"])
    return L.apply_norm(cfg, params["enc_final_norm"], h)


def _decoder(cfg, params, tokens, enc_out, *, return_cache=False, last_only=False):
    B, S = tokens.shape
    D = cfg.d_model
    h = params["embed"]["tok"][tokens] + sinusoid_pos(S, D)[None]

    def body(hh, lp):
        hn = L.apply_norm(cfg, lp["self_attn"]["norm"], hh)
        q, k, v = _proj_qkv(cfg, lp["self_attn"], hn, hn)
        o = L.attention(cfg, q, k, v, causal=True)
        hh = hh + _out(cfg, lp["self_attn"], o)
        hn = L.apply_norm(cfg, lp["cross_attn"]["norm"], hh)
        qc, kc, vc = _proj_qkv(cfg, lp["cross_attn"], hn, enc_out)
        oc = L.attention(cfg, qc, kc, vc, causal=False)
        hh = hh + _out(cfg, lp["cross_attn"], oc)
        hn = L.apply_norm(cfg, lp["mlp_norm"], hh)
        hh = hh + L.ffn_apply(cfg, lp["mlp"], hn)
        return hh, (k, v, kc, vc)

    h, kv = ctx.lscan(body, h, params["dec_layers"])
    h = L.apply_norm(cfg, params["final_norm"], h)
    if last_only:
        h = h[:, -1:]
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"]["tok"])
    if return_cache:
        return logits, kv
    return logits


def forward(cfg: ModelConfig, params, batch, *, remat=False, last_only=False):
    enc_out = encode(cfg, params, batch["enc_embeds"])
    return _decoder(cfg, params, batch["tokens"], enc_out, last_only=last_only)


def cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    dt = jnp.bfloat16
    Ld, H, dh, Se = cfg.n_layers, cfg.n_heads, cfg.head_dim, cfg.encoder_seq
    return {
        "k": jax.ShapeDtypeStruct((Ld, batch, max_len, H, dh), dt),
        "v": jax.ShapeDtypeStruct((Ld, batch, max_len, H, dh), dt),
        "cross_k": jax.ShapeDtypeStruct((Ld, batch, Se, H, dh), dt),
        "cross_v": jax.ShapeDtypeStruct((Ld, batch, Se, H, dh), dt),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, max_len))


def prefill(cfg: ModelConfig, params, batch, max_len: int):
    tokens = batch["tokens"]
    B, S = tokens.shape
    enc_out = encode(cfg, params, batch["enc_embeds"])
    logits, (k, v, kc, vc) = _decoder(cfg, params, tokens, enc_out,
                                      return_cache=True, last_only=True)
    cache = init_cache(cfg, B, max_len)
    cache["k"] = cache["k"].at[:, :, :S].set(k)
    cache["v"] = cache["v"].at[:, :, :S].set(v)
    cache["cross_k"], cache["cross_v"] = kc, vc
    return logits[:, -1], cache


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    B = tokens.shape[0]
    D = cfg.d_model
    S = cache["k"].shape[2]
    h = params["embed"]["tok"][tokens] + sinusoid_pos(jnp.full((1,), pos), D)[None]
    valid = (jnp.arange(S)[None] < pos + 1) & jnp.ones((B, 1), bool)
    ev = jnp.ones((B, cache["cross_k"].shape[2]), bool)

    def body(hh, xs):
        lp, kc, vc, ck, cv = xs
        hn = L.apply_norm(cfg, lp["self_attn"]["norm"], hh)
        q, k, v = _proj_qkv(cfg, lp["self_attn"], hn, hn)
        kc = ctx.constrain_named("cache_kv",
            jax.lax.dynamic_update_slice_in_dim(kc, k, pos, 1))
        vc = ctx.constrain_named("cache_kv",
            jax.lax.dynamic_update_slice_in_dim(vc, v, pos, 1))
        o = L.decode_attention(q, kc, vc, valid)
        hh = hh + _out(cfg, lp["self_attn"], o)
        hn = L.apply_norm(cfg, lp["cross_attn"]["norm"], hh)
        qc = jnp.einsum("bsd,dhk->bshk", hn, lp["cross_attn"]["wq"])
        if cfg.qkv_bias:
            qc = qc + lp["cross_attn"]["bq"]
        oc = L.decode_attention(qc, ck, cv, ev)
        hh = hh + _out(cfg, lp["cross_attn"], oc)
        hn = L.apply_norm(cfg, lp["mlp_norm"], hh)
        hh = hh + L.ffn_apply(cfg, lp["mlp"], hn)
        return hh, (kc, vc)

    h, (kc, vc) = ctx.lscan(
        body, h, (params["dec_layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    cache = dict(cache, k=kc, v=vc)
    h = L.apply_norm(cfg, params["final_norm"], h)
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"]["tok"])[:, 0]
    return logits, cache
