"""Param-spec machinery shared by all model families.

Models declare their parameters as a pytree of :class:`ParamSpec` (shape,
dtype, *logical axes*, init). From that single declaration we derive:
  * abstract params   (ShapeDtypeStruct — used by the multi-pod dry-run),
  * materialized init (used by smoke tests / examples),
  * PartitionSpecs    (distributed/partitioning.py maps logical→mesh axes).
"""
from __future__ import annotations

import hashlib
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class ParamSpec(NamedTuple):
    shape: Tuple[int, ...]
    dtype: Any
    axes: Tuple[str, ...]      # logical axis names, len == ndim
    init: str = "normal"       # 'normal' | 'zeros' | 'ones'
    fan_in_axes: Tuple[int, ...] = ()   # dims contributing to fan-in scaling


def spec(shape, axes, dtype=jnp.bfloat16, init="normal", fan_in_axes=None):
    shape = tuple(int(s) for s in shape)
    axes = tuple(axes)
    assert len(shape) == len(axes), (shape, axes)
    if fan_in_axes is None:
        # default: all but the last axis feed the output axis
        fan_in_axes = tuple(range(len(shape) - 1)) if init == "normal" else ()
    return ParamSpec(shape, dtype, axes, init, tuple(fan_in_axes))


def abstract_params(specs):
    """ShapeDtypeStruct tree for .lower() — never allocates."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _path_str(path) -> str:
    out = []
    for p in path:
        out.append(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))))
    return "/".join(out)


def init_params(rng, specs):
    """Materialize parameters. Deterministic per tree-path."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    leaves = []
    for path, s in flat:
        h = int.from_bytes(hashlib.sha256(_path_str(path).encode()).digest()[:4], "big")
        key = jax.random.fold_in(rng, h)
        if s.init == "zeros":
            leaves.append(jnp.zeros(s.shape, s.dtype))
        elif s.init == "ones":
            leaves.append(jnp.ones(s.shape, s.dtype))
        else:
            fan_in = int(np.prod([s.shape[i] for i in s.fan_in_axes])) or 1
            std = 1.0 / np.sqrt(fan_in)
            leaves.append(
                (jax.random.normal(key, s.shape, jnp.float32) * std).astype(s.dtype)
            )
    return jax.tree_util.tree_unflatten(treedef, leaves)


def param_count(specs) -> int:
    return int(
        sum(
            int(np.prod(s.shape))
            for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
        )
    )


def param_bytes(specs) -> int:
    return int(
        sum(
            int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
            for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
        )
    )
