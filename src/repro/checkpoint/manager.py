"""Atomic, mesh-elastic checkpointing (fault tolerance / elastic scaling).

Checkpoints are keyed by the *logical* parameter tree, not by mesh layout:
arrays are gathered to host and written per-leaf as .npy inside a staging
dir, then atomically renamed. Restore re-shards onto whatever mesh the new
job runs (different chip count, different topology) — the elastic-restart
path. A retention policy keeps the last K checkpoints; a 'latest' marker
file is written last so a crash mid-write can never corrupt restore.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._async_thread = None

    # -- async save (training never blocks on the filesystem) -------------
    def save_async(self, step: int, state: Any, extra: Optional[Dict] = None):
        """Device->host transfer happens now (cheap, async dispatch); the
        filesystem write runs on a background thread. Joins any previous
        in-flight save first (at most one outstanding)."""
        import threading
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)
        self.wait()
        self._async_thread = threading.Thread(
            target=self.save, args=(step, host_state, extra), daemon=True)
        self._async_thread.start()

    def wait(self):
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    # -- save ------------------------------------------------------------
    def save(self, step: int, state: Any, extra: Optional[Dict] = None):
        stage = self.dir / f".tmp-{step}-{os.getpid()}"
        final = self.dir / f"step-{step:09d}"
        if stage.exists():
            shutil.rmtree(stage)
        stage.mkdir(parents=True)
        flat, _ = _flatten(state)
        manifest = {"step": step, "keys": [], "time": time.time(),
                    "extra": extra or {}}
        for key, leaf in flat.items():
            host = np.asarray(jax.device_get(leaf))
            logical_dtype = str(host.dtype)
            if host.dtype.kind == "V" or "bfloat16" in logical_dtype:
                # numpy has no native bfloat16: persist the bit pattern
                logical_dtype = "bfloat16"
                host = host.view(np.uint16)
            fn = key.replace("/", "__") + ".npy"
            np.save(stage / fn, host)
            manifest["keys"].append({"key": key, "file": fn,
                                     "shape": list(host.shape),
                                     "dtype": logical_dtype})
        (stage / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        stage.rename(final)                       # atomic publish
        (self.dir / "latest").write_text(final.name)
        self._gc()
        return final

    def _gc(self):
        ckpts = sorted(self.dir.glob("step-*"))
        for old in ckpts[: max(0, len(ckpts) - self.keep)]:
            shutil.rmtree(old)

    # -- restore -----------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        marker = self.dir / "latest"
        if not marker.exists():
            return None
        name = marker.read_text().strip()
        if not (self.dir / name).exists():
            ckpts = sorted(self.dir.glob("step-*"))
            if not ckpts:
                return None
            name = ckpts[-1].name
        return int(name.split("-")[1])

    def restore(self, step: Optional[int], like: Any, shardings: Any = None):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs). ``shardings`` (same structure) re-shards onto the
        current mesh — elastic restore onto any topology."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        final = self.dir / f"step-{step:09d}"
        manifest = json.loads((final / "manifest.json").read_text())
        by_key = {e["key"]: e for e in manifest["keys"]}
        flat_like, treedef = _flatten(like)
        leaves = {}
        for key, leaf in flat_like.items():
            ent = by_key[key]
            host = np.load(final / ent["file"])
            if ent["dtype"] == "bfloat16":
                import ml_dtypes
                host = host.view(ml_dtypes.bfloat16)
            leaves[key] = host
        flat_sh = _flatten(shardings)[0] if shardings is not None else None
        ordered = []
        flat2, treedef2 = jax.tree_util.tree_flatten_with_path(like)
        for path, _ in flat2:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            host = leaves[key]
            if flat_sh is not None:
                ordered.append(jax.device_put(host, flat_sh[key]))
            else:
                ordered.append(jax.numpy.asarray(host))
        return jax.tree_util.tree_unflatten(treedef2, ordered), manifest

    def restore_state(self, like, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, 0
        state, manifest = self.restore(step, like, shardings)
        return state, manifest["step"]
