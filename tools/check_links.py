#!/usr/bin/env python3
"""Docs link check: every relative markdown link must resolve to a file.

Scans the repo's top-level *.md plus docs/ for ``[text](target)`` links,
ignores absolute URLs and pure anchors, and fails (exit 1) listing every
dangling target. Run from anywhere:

    python tools/check_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def md_files():
    yield from ROOT.glob("*.md")
    yield from (ROOT / "docs").glob("**/*.md")


def main() -> int:
    bad = []
    for md in sorted(md_files()):
        for target in LINK.findall(md.read_text()):
            if target.startswith(SKIP_PREFIXES):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            if not (md.parent / path).exists():
                bad.append(f"{md.relative_to(ROOT)}: dangling link -> {target}")
    if bad:
        print("\n".join(bad))
        return 1
    print(f"docs link check: OK ({len(list(md_files()))} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
