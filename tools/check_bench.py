"""CI benchmark-regression gate.

Compares the flat ``metrics`` dict of one or more benchmark result JSONs
(``results/bench_arrival.json``, ``results/bench_switching.json`` — written
by ``benchmarks/run.py --sweep-arrival / --sweep-switching``) against the
committed reference in ``benchmarks/baseline.json``. Metrics are
higher-is-better by default (throughput, overlap ratios); the gate fails
when

    current < baseline_value * (1 - threshold)

i.e. a >``threshold`` regression (default 30%). Baseline entries are either
a bare number or ``{"value": x, "threshold": y}`` for a per-metric band;
a dict entry may also set ``"higher_is_better": false`` (latency, stall
seconds), flipping the gate to fail when

    current > baseline_value * (1 + threshold)

A baseline metric missing from the results is a failure too — a silently
dropped benchmark must not pass the gate.

    python tools/check_bench.py [--baseline benchmarks/baseline.json]
        [--threshold 0.30] results/bench_arrival.json results/bench_switching.json

``--update-baseline`` rewrites the baseline's values from the measured
results instead of gating: each already-gated metric keeps its per-metric
``threshold`` and ``higher_is_better`` (only ``value`` changes), metrics
new to the results are added with the default band, baseline metrics the
results did not produce are left untouched, and the ``comment`` block is
preserved. Result arguments may be directories — every ``bench_*.json``
inside is merged.

Exit code 0 = pass, 1 = regression/missing metric, 2 = bad invocation.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def expand_result_paths(paths):
    """Expand directory arguments into their ``bench_*.json`` files."""
    out = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            found = sorted(p.glob("bench_*.json"))
            if not found:
                raise FileNotFoundError(f"no bench_*.json under {p}")
            out.extend(found)
        else:
            out.append(p)
    return out


def load_metrics(paths):
    merged = {}
    for p in paths:
        doc = json.loads(Path(p).read_text())
        metrics = doc.get("metrics", {})
        dup = set(metrics) & set(merged)
        if dup:
            raise SystemExit(f"duplicate metric keys across inputs: {dup}")
        merged.update(metrics)
    return merged


def update_baseline(base_doc: dict, current: dict,
                    default_threshold: float) -> list:
    """Rewrite baseline values in place from measured ``current`` metrics;
    returns report lines. Per-metric bands and gate directions survive the
    update — only the reference values move."""
    baseline = base_doc.setdefault("metrics", {})
    lines = []
    for name in sorted(current):
        cur = round(float(current[name]), 6)
        ref = baseline.get(name)
        if ref is None:
            baseline[name] = {"value": cur, "threshold": default_threshold}
            lines.append(f"{'added':10s} {name}: {cur:g} "
                         f"(band {default_threshold:.0%})")
        elif isinstance(ref, dict):
            old = ref.get("value")
            ref["value"] = cur
            lines.append(f"{'updated':10s} {name}: {old:g} -> {cur:g} "
                         f"(band/direction kept)")
        else:
            baseline[name] = cur
            lines.append(f"{'updated':10s} {name}: {float(ref):g} -> {cur:g}")
    for name in sorted(set(baseline) - set(current)):
        lines.append(f"{'kept':10s} {name}: not in results, unchanged")
    return lines


def check(current: dict, baseline: dict, threshold: float):
    """Returns (failures, lines): failure strings + a full report."""
    failures, lines = [], []
    for name, ref in sorted(baseline.items()):
        higher = True
        if isinstance(ref, dict):
            ref_value, band = float(ref["value"]), float(
                ref.get("threshold", threshold))
            higher = bool(ref.get("higher_is_better", True))
        else:
            ref_value, band = float(ref), threshold
        if name not in current:
            # a baseline-named metric absent from the produced JSON means a
            # benchmark was renamed/dropped and silently stopped being
            # gated — fail loudly, in the report body AND the failure list
            lines.append(f"{'MISSING':10s} {name}: not in results "
                         f"(baseline {ref_value:g}) — renamed or dropped "
                         f"metric is no longer gated")
            failures.append(lines[-1])
            continue
        cur = float(current[name])
        if higher:
            bound = ref_value * (1.0 - band)
            ok = cur >= bound
            kind = "floor"
        else:
            bound = ref_value * (1.0 + band)
            ok = cur <= bound
            kind = "ceiling"
        verdict = "ok" if ok else "REGRESSION"
        lines.append(f"{verdict:10s} {name}: {cur:.3f} "
                     f"(baseline {ref_value:g}, {kind} {bound:.3f}, "
                     f"band {band:.0%})")
        if not ok:
            failures.append(lines[-1])
    extra = sorted(set(current) - set(baseline))
    for name in extra:
        lines.append(f"{'untracked':10s} {name}: {float(current[name]):.3f} "
                     f"(no baseline entry)")
    return failures, lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("results", nargs="+",
                    help="benchmark result JSONs with a 'metrics' dict")
    ap.add_argument("--baseline", default="benchmarks/baseline.json")
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="allowed fractional regression (default 0.30)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline's values from the measured "
                    "results (bands/directions/comment preserved) instead "
                    "of gating")
    args = ap.parse_args(argv)

    try:
        base_doc = json.loads(Path(args.baseline).read_text())
    except FileNotFoundError as e:
        print(f"check_bench: missing baseline file: {e.filename}")
        return 2
    baseline = base_doc["metrics"] if "metrics" in base_doc else base_doc
    try:
        current = load_metrics(expand_result_paths(args.results))
    except FileNotFoundError as e:
        print(f"check_bench: missing results file: "
              f"{getattr(e, 'filename', None) or e}")
        return 2

    if args.update_baseline:
        lines = update_baseline(base_doc, current, args.threshold)
        Path(args.baseline).write_text(json.dumps(base_doc, indent=2) + "\n")
        print(f"check_bench: baseline {args.baseline} updated "
              f"({len(current)} measured metrics)")
        for line in lines:
            print("  " + line)
        return 0

    failures, lines = check(current, baseline, args.threshold)
    print(f"check_bench: {len(baseline)} gated metrics, "
          f"{len(failures)} failure(s)")
    for line in lines:
        print("  " + line)
    if failures:
        print("\ncheck_bench: FAILED —")
        for f in failures:
            print("  " + f)
        return 1
    print("check_bench: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
