"""CI benchmark-regression gate.

Compares the flat ``metrics`` dict of one or more benchmark result JSONs
(``results/bench_arrival.json``, ``results/bench_switching.json`` — written
by ``benchmarks/run.py --sweep-arrival / --sweep-switching``) against the
committed reference in ``benchmarks/baseline.json``. Metrics are
higher-is-better by default (throughput, overlap ratios); the gate fails
when

    current < baseline_value * (1 - threshold)

i.e. a >``threshold`` regression (default 30%). Baseline entries are either
a bare number or ``{"value": x, "threshold": y}`` for a per-metric band;
a dict entry may also set ``"higher_is_better": false`` (latency, stall
seconds), flipping the gate to fail when

    current > baseline_value * (1 + threshold)

A baseline metric missing from the results is a failure too — a silently
dropped benchmark must not pass the gate.

    python tools/check_bench.py [--baseline benchmarks/baseline.json]
        [--threshold 0.30] results/bench_arrival.json results/bench_switching.json

Exit code 0 = pass, 1 = regression/missing metric, 2 = bad invocation.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def load_metrics(paths):
    merged = {}
    for p in paths:
        doc = json.loads(Path(p).read_text())
        metrics = doc.get("metrics", {})
        dup = set(metrics) & set(merged)
        if dup:
            raise SystemExit(f"duplicate metric keys across inputs: {dup}")
        merged.update(metrics)
    return merged


def check(current: dict, baseline: dict, threshold: float):
    """Returns (failures, lines): failure strings + a full report."""
    failures, lines = [], []
    for name, ref in sorted(baseline.items()):
        higher = True
        if isinstance(ref, dict):
            ref_value, band = float(ref["value"]), float(
                ref.get("threshold", threshold))
            higher = bool(ref.get("higher_is_better", True))
        else:
            ref_value, band = float(ref), threshold
        if name not in current:
            # a baseline-named metric absent from the produced JSON means a
            # benchmark was renamed/dropped and silently stopped being
            # gated — fail loudly, in the report body AND the failure list
            lines.append(f"{'MISSING':10s} {name}: not in results "
                         f"(baseline {ref_value:g}) — renamed or dropped "
                         f"metric is no longer gated")
            failures.append(lines[-1])
            continue
        cur = float(current[name])
        if higher:
            bound = ref_value * (1.0 - band)
            ok = cur >= bound
            kind = "floor"
        else:
            bound = ref_value * (1.0 + band)
            ok = cur <= bound
            kind = "ceiling"
        verdict = "ok" if ok else "REGRESSION"
        lines.append(f"{verdict:10s} {name}: {cur:.3f} "
                     f"(baseline {ref_value:g}, {kind} {bound:.3f}, "
                     f"band {band:.0%})")
        if not ok:
            failures.append(lines[-1])
    extra = sorted(set(current) - set(baseline))
    for name in extra:
        lines.append(f"{'untracked':10s} {name}: {float(current[name]):.3f} "
                     f"(no baseline entry)")
    return failures, lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("results", nargs="+",
                    help="benchmark result JSONs with a 'metrics' dict")
    ap.add_argument("--baseline", default="benchmarks/baseline.json")
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="allowed fractional regression (default 0.30)")
    args = ap.parse_args(argv)

    try:
        base_doc = json.loads(Path(args.baseline).read_text())
    except FileNotFoundError as e:
        print(f"check_bench: missing baseline file: {e.filename}")
        return 2
    baseline = base_doc["metrics"] if "metrics" in base_doc else base_doc
    try:
        current = load_metrics(args.results)
    except FileNotFoundError as e:
        print(f"check_bench: missing results file: {e.filename}")
        return 2

    failures, lines = check(current, baseline, args.threshold)
    print(f"check_bench: {len(baseline)} gated metrics, "
          f"{len(failures)} failure(s)")
    for line in lines:
        print("  " + line)
    if failures:
        print("\ncheck_bench: FAILED —")
        for f in failures:
            print("  " + f)
        return 1
    print("check_bench: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
