"""Integration checks over the recorded multi-pod dry-run artifacts, plus
unit tests for the CI benchmark gate itself (``tools/check_bench.py``).

The dry-run half validates the *results* of deliverable (e)/(g) — every
assigned (arch x shape x mesh) cell compiled (or was skipped by the
documented rule), and the roofline terms are physically sane. Those tests
skip when the artifact hasn't been generated; the gate unit tests always
run (the gate guards every bench-smoke job, so its own failure modes —
especially a baseline-named metric silently missing from the produced
JSON — need coverage that doesn't depend on artifacts).
"""
import json
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
RESULTS = REPO / "results" / "dryrun.json"

dryrun = pytest.mark.skipif(not RESULTS.exists(),
                            reason="dry-run results not generated yet")


def _load():
    return json.loads(RESULTS.read_text())


@dryrun
def test_all_80_cells_recorded():
    from repro.configs import ARCH_IDS, SHAPE_CELLS
    d = _load()
    missing = []
    for arch in ARCH_IDS:
        for cell in SHAPE_CELLS:
            for mesh in ("single", "multi"):
                k = f"{arch}|{cell.name}|{mesh}"
                if d.get(k, {}).get("status") not in ("ok", "skipped"):
                    missing.append(k)
    assert not missing, missing              # 10 archs x 4 cells x 2 meshes
    bad = {k: v.get("status") for k, v in d.items()
           if v.get("status") not in ("ok", "skipped")}
    assert not bad, bad


@dryrun
def test_skips_only_long500k_full_attention():
    d = _load()
    for k, v in d.items():
        if v.get("status") == "skipped":
            arch, cell, mesh = k.split("|")
            assert cell == "long_500k", k
            assert arch not in ("mixtral-8x7b", "recurrentgemma-9b",
                                "xlstm-1.3b"), k


@dryrun
def test_subquadratic_archs_run_long500k():
    d = _load()
    for arch in ("mixtral-8x7b", "recurrentgemma-9b", "xlstm-1.3b"):
        assert d[f"{arch}|long_500k|single"]["status"] == "ok"
        assert d[f"{arch}|long_500k|multi"]["status"] == "ok"


@dryrun
def test_roofline_terms_sane():
    d = _load()
    for k, v in d.items():
        if v.get("status") != "ok":
            continue
        r = v["roofline"]
        assert r["hlo_flops"] > 0, k
        assert r["hlo_bytes"] > 0, k
        assert r["compute_s"] > 0, k
        # corrected useful ratio must be physical (some slack for the
        # analytic 6ND proxy on recurrent families)
        if "loopfix" in v:
            assert r["useful_flops_ratio"] < 1.6, (k, r["useful_flops_ratio"])


@dryrun
def test_multi_pod_halves_per_chip_work():
    """Doubling chips (2 pods) should not increase per-chip compute time."""
    d = _load()
    for k, v in d.items():
        arch, cell, mesh = k.split("|")
        if mesh != "single" or v.get("status") != "ok":
            continue
        m = d.get(f"{arch}|{cell}|multi")
        if not m or m.get("status") != "ok" or "loopfix" not in m \
                or "loopfix" not in v:
            continue
        # compute term uses global work / (chips*peak): more chips -> <=
        assert m["roofline"]["compute_s"] <= v["roofline"]["compute_s"] * 1.2, k


@dryrun
def test_decode_cells_memory_bound():
    """The paper's decode regime: weights+cache streaming dominates."""
    d = _load()
    for k, v in d.items():
        arch, cell, mesh = k.split("|")
        if cell != "decode_32k" or mesh != "single" or \
                v.get("status") != "ok" or "loopfix" not in v:
            continue
        if arch == "whisper-small":      # tiny enc-dec: relayout dominates
            continue
        assert v["roofline"]["bottleneck"] == "memory", (k, v["roofline"])


# ----------------------------------------------------------------------
# tools/check_bench.py unit tests (always run — no artifacts needed)
# ----------------------------------------------------------------------

sys.path.insert(0, str(REPO / "tools"))
import check_bench  # noqa: E402


def test_check_bench_missing_metric_fails():
    """A baseline-named metric absent from the results must fail the gate
    AND appear in the printed report body — a renamed benchmark metric
    must never silently stop being gated."""
    failures, lines = check_bench.check(
        current={"present:metric": 1.0},
        baseline={"present:metric": {"value": 1.0, "threshold": 0.3},
                  "renamed:metric": {"value": 2.0, "threshold": 0.3}},
        threshold=0.3)
    assert len(failures) == 1
    assert "MISSING" in failures[0] and "renamed:metric" in failures[0]
    assert any("MISSING" in ln and "renamed:metric" in ln for ln in lines), \
        "missing metric must be visible in the report body, not only the " \
        "failure summary"


def test_check_bench_floor_and_ceiling_direction():
    """higher_is_better=True gates a floor; False flips to a ceiling."""
    failures, _ = check_bench.check(
        current={"tps": 0.6, "p99": 1.5},
        baseline={"tps": {"value": 1.0, "threshold": 0.3},
                  "p99": {"value": 1.0, "threshold": 0.3,
                          "higher_is_better": False}},
        threshold=0.3)
    assert len(failures) == 2                 # 0.6 < 0.7 floor; 1.5 > 1.3
    ok, _ = check_bench.check(
        current={"tps": 0.8, "p99": 1.2},
        baseline={"tps": {"value": 1.0, "threshold": 0.3},
                  "p99": {"value": 1.0, "threshold": 0.3,
                          "higher_is_better": False}},
        threshold=0.3)
    assert not ok


def test_check_bench_untracked_metric_passes():
    """Metrics in the results with no baseline entry are reported as
    untracked, never failed."""
    failures, lines = check_bench.check(
        current={"gated": 1.0, "brand_new": 123.0},
        baseline={"gated": 1.0}, threshold=0.3)
    assert not failures
    assert any("untracked" in ln and "brand_new" in ln for ln in lines)


def test_check_bench_main_exit_codes(tmp_path):
    base = tmp_path / "baseline.json"
    res = tmp_path / "bench.json"
    base.write_text(json.dumps(
        {"metrics": {"m": {"value": 1.0, "threshold": 0.3}}}))
    res.write_text(json.dumps({"metrics": {"m": 1.0}}))
    assert check_bench.main([str(res), "--baseline", str(base)]) == 0
    res.write_text(json.dumps({"metrics": {"m_renamed": 1.0}}))
    assert check_bench.main([str(res), "--baseline", str(base)]) == 1
    assert check_bench.main([str(tmp_path / "nope.json"),
                             "--baseline", str(base)]) == 2
