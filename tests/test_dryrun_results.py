"""Integration checks over the recorded multi-pod dry-run artifacts.

These validate the *results* of deliverable (e)/(g) — every assigned
(arch x shape x mesh) cell compiled (or was skipped by the documented
rule), and the roofline terms are physically sane.
"""
import json
from pathlib import Path

import pytest

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun.json"

pytestmark = pytest.mark.skipif(not RESULTS.exists(),
                                reason="dry-run results not generated yet")


def _load():
    return json.loads(RESULTS.read_text())


def test_all_80_cells_recorded():
    from repro.configs import ARCH_IDS, SHAPE_CELLS
    d = _load()
    missing = []
    for arch in ARCH_IDS:
        for cell in SHAPE_CELLS:
            for mesh in ("single", "multi"):
                k = f"{arch}|{cell.name}|{mesh}"
                if d.get(k, {}).get("status") not in ("ok", "skipped"):
                    missing.append(k)
    assert not missing, missing              # 10 archs x 4 cells x 2 meshes
    bad = {k: v.get("status") for k, v in d.items()
           if v.get("status") not in ("ok", "skipped")}
    assert not bad, bad


def test_skips_only_long500k_full_attention():
    d = _load()
    for k, v in d.items():
        if v.get("status") == "skipped":
            arch, cell, mesh = k.split("|")
            assert cell == "long_500k", k
            assert arch not in ("mixtral-8x7b", "recurrentgemma-9b",
                                "xlstm-1.3b"), k


def test_subquadratic_archs_run_long500k():
    d = _load()
    for arch in ("mixtral-8x7b", "recurrentgemma-9b", "xlstm-1.3b"):
        assert d[f"{arch}|long_500k|single"]["status"] == "ok"
        assert d[f"{arch}|long_500k|multi"]["status"] == "ok"


def test_roofline_terms_sane():
    d = _load()
    for k, v in d.items():
        if v.get("status") != "ok":
            continue
        r = v["roofline"]
        assert r["hlo_flops"] > 0, k
        assert r["hlo_bytes"] > 0, k
        assert r["compute_s"] > 0, k
        # corrected useful ratio must be physical (some slack for the
        # analytic 6ND proxy on recurrent families)
        if "loopfix" in v:
            assert r["useful_flops_ratio"] < 1.6, (k, r["useful_flops_ratio"])


def test_multi_pod_halves_per_chip_work():
    """Doubling chips (2 pods) should not increase per-chip compute time."""
    d = _load()
    for k, v in d.items():
        arch, cell, mesh = k.split("|")
        if mesh != "single" or v.get("status") != "ok":
            continue
        m = d.get(f"{arch}|{cell}|multi")
        if not m or m.get("status") != "ok" or "loopfix" not in m \
                or "loopfix" not in v:
            continue
        # compute term uses global work / (chips*peak): more chips -> <=
        assert m["roofline"]["compute_s"] <= v["roofline"]["compute_s"] * 1.2, k


def test_decode_cells_memory_bound():
    """The paper's decode regime: weights+cache streaming dominates."""
    d = _load()
    for k, v in d.items():
        arch, cell, mesh = k.split("|")
        if cell != "decode_32k" or mesh != "single" or \
                v.get("status") != "ok" or "loopfix" not in v:
            continue
        if arch == "whisper-small":      # tiny enc-dec: relayout dominates
            continue
        assert v["roofline"]["bottleneck"] == "memory", (k, v["roofline"])
