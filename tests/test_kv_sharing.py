"""Copy-on-write prefix sharing, session retention, and the refcounted
paged pool (ISSUE 9 tentpole).

Pool-level tests construct a tiny ``PagedKVCache`` directly (1 layer,
1 KV head, head_dim 2 — shapes are irrelevant to the bookkeeping under
test). Engine-level tests reuse the reduced samba-coe backbone and assert
the tentpole acceptance claims: byte-identical greedy streams shared vs
unshared, session turns adopting their history, zero leaked blocks and an
in-budget HBM accounting at every step of a drain.

Property tests run under the real ``hypothesis`` when installed, else the
deterministic sampling stub (tests/_hypothesis_stub.py, installed by
conftest.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config, reduced
from repro.core import CompositionOfExperts, ExpertHandle, HashRouter
from repro.models import get_model
from repro.serving import (PagedKVCache, PrefixIndex, Request, ServingEngine,
                           SessionManager)
from repro.serving.engine import _DeviceTableCache

B = 4  # block size for the pool-level tests


def mk_pool(n_blocks=16, scratch=False):
    return PagedKVCache(n_blocks, B, n_layers=1, kv_heads=1, head_dim=2,
                        dtype=jnp.float32, scratch=scratch)


def seat(pool, rid, tokens):
    """Open rid and commit len(tokens) positions whose K rows encode the
    token ids (so tests can check WHICH rows a table actually gathers)."""
    pool.open(rid)
    t = np.asarray(tokens, np.float32)
    k = t.reshape(1, -1, 1, 1) * np.ones((1, len(t), 1, 2), np.float32)
    pool.append(rid, jnp.asarray(k), jnp.asarray(k))


def rows(pool, rid):
    """Committed K rows of one rid as a flat int list (via gather)."""
    k, _ = pool.gather(rid)
    return [int(x) for x in np.asarray(k)[0, :, 0, 0]]


# ---------------------------------------------------------------- refcounts
def test_open_adopt_refcounts_and_free_ordering():
    pool = mk_pool()
    seat(pool, 0, range(10, 10 + 2 * B))          # two full blocks
    tbl = pool.table(0)
    assert [pool.refcount(b) for b in tbl] == [1, 1]

    pool.pin(tbl)                                  # match-window pin
    pool.open(1, adopt=tbl, adopt_len=2 * B)
    pool.unpin(tbl)
    assert [pool.refcount(b) for b in tbl] == [2, 2]
    assert pool.stats.shared_blocks == 2
    assert rows(pool, 1) == rows(pool, 0)          # same bytes, no copy

    pool.free(0)                                   # owner leaves first
    assert [pool.refcount(b) for b in tbl] == [1, 1]
    assert pool.stats.shared_blocks == 0
    assert rows(pool, 1) == list(range(10, 10 + 2 * B))
    pool.free(1)
    assert pool.stats.blocks_in_use == 0
    assert pool.free_blocks == pool.n_blocks


def test_adopt_validation():
    pool = mk_pool()
    seat(pool, 0, range(B))
    tbl = pool.table(0)
    with pytest.raises(ValueError):
        pool.open(1, adopt=tbl, adopt_len=0)       # empty adoption
    with pytest.raises(ValueError):
        pool.open(1, adopt=tbl, adopt_len=B + 1)   # beyond the blocks
    with pytest.raises(ValueError):
        pool.open(1, adopt=[7], adopt_len=2)       # block 7 is free
    pool.free(0)


def test_cow_split_preserves_sharers_bytes():
    """Writing into an adopted, partially-consumed shared tail block must
    split it: the writer gets a fresh copy, every other holder keeps the
    original rows byte-for-byte."""
    pool = mk_pool()
    seat(pool, 0, range(20, 20 + B + 2))           # one full + partial tail
    tbl = pool.table(0)
    pool.pin(tbl)
    pool.open(1, adopt=tbl, adopt_len=B + 2)       # adopt mid-block
    pool.unpin(tbl)
    assert pool.refcount(tbl[1]) == 2

    k = np.full((1, 1, 1, 2), 99.0, np.float32)
    pool.append(1, jnp.asarray(k), jnp.asarray(k))  # first write -> COW
    assert pool.stats.cow_splits == 1
    assert pool.table(1)[1] != tbl[1]              # tail swapped out
    assert pool.refcount(tbl[1]) == 1              # original back to owner
    assert rows(pool, 0) == list(range(20, 20 + B + 2))   # sharer untouched
    assert rows(pool, 1) == list(range(20, 20 + B + 2)) + [99]
    pool.free(0)
    pool.free(1)
    assert pool.stats.blocks_in_use == 0


def test_cow_skipped_when_tail_unshared_or_aligned():
    pool = mk_pool()
    seat(pool, 0, range(B + 1))
    pool.append(0, jnp.ones((1, 1, 1, 2)), jnp.ones((1, 1, 1, 2)))
    assert pool.stats.cow_splits == 0              # ref 1: write in place
    seat(pool, 1, range(30, 30 + B))               # block-aligned length
    tbl = pool.table(1)
    pool.pin(tbl)
    pool.open(2, adopt=tbl, adopt_len=B)
    pool.unpin(tbl)
    pool.append(2, jnp.ones((1, 1, 1, 2)), jnp.ones((1, 1, 1, 2)))
    assert pool.stats.cow_splits == 0              # tail full: new block
    assert pool.refcount(tbl[0]) == 2
    for r in (0, 1, 2):
        pool.free(r)
    assert pool.stats.blocks_in_use == 0


# ------------------------------------------------- free()/device-cache churn
def test_free_bumps_versions_before_block_reuse():
    """Regression: ``free`` must bump BOTH versions before its blocks hit
    the free list, so a ``_DeviceTableCache`` snapshot keyed on the old
    version can never serve a table whose blocks a later request reused."""
    pool = mk_pool(n_blocks=4, scratch=False)
    empty = np.zeros((4,), np.int32)
    cache = _DeviceTableCache(pool, max_blocks=4, empty_table=empty)

    seat(pool, 0, range(2 * B))
    t0 = np.asarray(cache.tables((0,)))
    v0 = pool.table_version
    pool.free(0)
    assert pool.table_version > v0 and pool.length_version > 0
    seat(pool, 1, range(40, 40 + 2 * B))           # reuses the freed blocks
    t1 = np.asarray(cache.tables((1,)))
    assert cache._tab_key[0] == pool.table_version     # fresh upload
    assert rows(pool, 1) == list(range(40, 40 + 2 * B))
    del t0, t1


def test_free_churn_many_rids_no_stale_reuse():
    """Interleaved open/free churn: every surviving rid still gathers its
    own rows (nobody reads a block that was recycled under them)."""
    pool = mk_pool(n_blocks=8)
    live = {}
    rid = 0
    rs = np.random.RandomState(3)
    for step in range(40):
        if live and (len(live) >= 3 or rs.rand() < 0.4):
            victim = int(rs.choice(list(live)))
            pool.free(victim)
            del live[victim]
        else:
            n = int(rs.randint(1, 2 * B))
            base = rid * 100
            seat(pool, rid, range(base, base + n))
            live[rid] = list(range(base, base + n))
            rid += 1
        for r, want in live.items():
            assert rows(pool, r) == want, f"rid {r} gathered foreign rows"
    for r in list(live):
        pool.free(r)
    assert pool.stats.blocks_in_use == 0
    assert pool.stats.allocs == pool.stats.frees


# ---------------------------------------------------------------- the index
def test_prefix_index_insert_match_roundtrip():
    pool = mk_pool()
    idx = PrefixIndex(pool)
    toks = np.arange(3 * B + 2, dtype=np.int32)
    seat(pool, 0, toks)
    assert idx.insert("e0", toks, pool.table(0)) == 3   # full blocks only
    pool.free(0)
    assert pool.stats.blocks_in_use == 3           # index keeps them alive

    m = idx.match("e0", toks)                      # same prompt again
    assert m is not None
    blocks, n = m
    assert n == 3 * B                              # every indexed full block
    assert len(blocks) == 3
    pool.unpin(blocks)

    m = idx.match("e0", toks[: 2 * B])             # exact-cover prompt:
    blocks, n = m                                  # capped so the suffix
    assert n == 2 * B - 1                          # forward has >=1 token
    assert len(blocks) == 2
    assert all(pool.refcount(b) >= 2 for b in blocks)   # pinned
    pool.unpin(blocks)

    assert idx.match("e1", toks) is None           # per-expert isolation
    assert idx.match("e0", toks + 1000) is None    # different tokens
    idx.clear()
    assert pool.stats.blocks_in_use == 0


def test_prefix_index_partial_tail_match():
    """A prompt sharing only part of an indexed block still adopts it —
    the rows are position-exact and the first write COW-splits."""
    pool = mk_pool()
    idx = PrefixIndex(pool)
    toks = np.arange(2 * B, dtype=np.int32)
    seat(pool, 0, toks)
    idx.insert("e0", toks, pool.table(0))
    pool.free(0)

    probe = np.concatenate([toks[: B + 2],
                            np.asarray([77, 78], np.int32)])
    m = idx.match("e0", probe)
    assert m is not None
    blocks, n = m
    assert n == B + 2                              # through the partial tail
    assert len(blocks) == 2
    pool.unpin(blocks)
    idx.clear()


def test_prefix_index_lru_leaf_reclaim():
    pool = mk_pool(n_blocks=4)
    idx = PrefixIndex(pool)
    pool.add_reclaimer(idx)
    for i in range(2):
        toks = np.arange(i * 50, i * 50 + 2 * B, dtype=np.int32)
        seat(pool, i, toks)
        idx.insert(f"e{i}", toks, pool.table(i))
        pool.free(i)
    assert pool.free_blocks == 0 and len(idx) == 4
    pool.open(9)                                   # needs fresh blocks
    pool.reserve(9, 2 * B)                         # forces a reclaim
    assert pool.length(9) == 0 and len(pool.table(9)) == 2
    assert len(idx) == 2                           # leaves (then roots) went
    pool.free(9)
    idx.clear()
    assert pool.stats.blocks_in_use == 0


# ------------------------------------------------------------------ sessions
def test_session_retain_adopt_evict():
    pool = mk_pool()
    sm = SessionManager(pool)
    toks = np.arange(2 * B + 1, dtype=np.int32)
    seat(pool, 0, toks)
    sm.retain("chat", 0, "e0", toks)
    assert "chat" in sm and pool.stats.blocks_in_use == 3

    nxt = np.concatenate([toks, np.asarray([5, 6], np.int32)])
    got = sm.adopt("chat", "e0", nxt)
    assert got is not None
    blocks, n = got
    assert n == len(toks)                          # whole history adopted
    assert "chat" not in sm                        # ownership handed over
    pool.open(1, adopt=blocks, adopt_len=n)
    pool.unpin(blocks)
    assert rows(pool, 1) == list(range(2 * B + 1))
    pool.free(1)
    assert pool.stats.blocks_in_use == 0

    seat(pool, 2, toks)
    sm.retain("chat", 2, "e0", toks)
    assert sm.adopt("chat", "e1", nxt) is None     # rerouted: KV useless
    assert "chat" not in sm and sm.evictions == 1
    assert pool.stats.blocks_in_use == 0


def test_session_cap_and_reclaim():
    pool = mk_pool(n_blocks=8)
    sm = SessionManager(pool, max_bytes=4 * pool._per_block_bytes())
    for i in range(3):
        seat(pool, i, np.arange(i * 30, i * 30 + 2 * B, dtype=np.int32))
        sm.retain(f"s{i}", i, "e0", np.arange(i * 30, i * 30 + 2 * B,
                                              dtype=np.int32))
    assert sm.bytes_retained() <= sm.max_bytes     # cap enforced on retain
    assert len(sm) == 2 and sm.evictions == 1
    freed = sm.reclaim(10)                         # pool-pressure path
    assert freed == 4 and len(sm) == 0
    assert pool.stats.blocks_in_use == 0


# --------------------------------------------------------- property tests
@settings(max_examples=30)
@given(st.lists(st.integers(0, 5), min_size=1, max_size=24),
       st.integers(1, 3))
def test_refcount_invariant_random_ops(ops, seed):
    """After ANY op sequence: every live block's refcounts sum to the table
    references + index references + outstanding pins, and no block is both
    referenced and on the free list."""
    pool = mk_pool(n_blocks=12)
    idx = PrefixIndex(pool)
    pool.add_reclaimer(idx)
    rs = np.random.RandomState(seed)
    rid = [0]
    live = []
    pins = []                                      # (blocks,) outstanding

    def check():
        index_refs = len(idx._entries)
        pin_refs = sum(len(p) for p in pins)
        assert (sum(pool._refs.values())
                == pool.live_table_refs() + index_refs + pin_refs)
        assert not (set(pool._refs) & set(pool._free))
        assert pool.stats.blocks_in_use == len(pool._refs)

    for op in ops:
        try:
            if op == 0:                            # open + append fresh
                n = int(rs.randint(1, 2 * B + 1))
                seat(pool, rid[0], rs.randint(0, 99, n))
                live.append(rid[0]); rid[0] += 1
            elif op == 1 and live:                 # free oldest
                pool.free(live.pop(0))
            elif op == 2 and live:                 # index a live rid
                r = live[int(rs.randint(len(live)))]
                toks = np.asarray(rows(pool, r), np.int32)
                idx.insert("e0", toks, pool.table(r))
            elif op == 3 and live:                 # match (leaves a pin)
                r = live[int(rs.randint(len(live)))]
                toks = np.asarray(rows(pool, r) + [1], np.int32)
                m = idx.match("e0", toks)
                if m is not None:
                    pins.append(m[0])
            elif op == 4 and pins:                 # adopt a pinned match
                blocks = pins.pop()
                n = (len(blocks) - 1) * B + 1
                pool.open(rid[0], adopt=blocks, adopt_len=n)
                pool.unpin(blocks)
                live.append(rid[0]); rid[0] += 1
            elif op == 5 and pins:                 # abandon a match
                pool.unpin(pins.pop())
        except MemoryError:
            pass                                   # pool exhausted: fine
        check()
    for p in pins:
        pool.unpin(p)
    for r in live:
        pool.free(r)
    idx.clear()
    check()
    assert pool.stats.blocks_in_use == 0


@settings(max_examples=25)
@given(st.integers(1, 3 * B - 1), st.integers(1, 6))
def test_cow_never_mutates_shared_rows(adopt_tokens, n_writes):
    """Whatever an adopter appends, every byte a sharer can gather stays
    exactly what it was before the adoption."""
    pool = mk_pool()
    total = 3 * B
    seat(pool, 0, range(100, 100 + total))
    before = rows(pool, 0)
    tbl = pool.table(0)[: -(-adopt_tokens // B)]
    pool.pin(tbl)
    pool.open(1, adopt=tbl, adopt_len=adopt_tokens)
    pool.unpin(tbl)
    for w in range(n_writes):
        k = np.full((1, 1, 1, 2), 500.0 + w, np.float32)
        pool.append(1, jnp.asarray(k), jnp.asarray(k))
    assert rows(pool, 0) == before
    assert rows(pool, 1)[:adopt_tokens] == before[:adopt_tokens]
    pool.free(0)
    pool.free(1)
    assert pool.stats.blocks_in_use == 0


@settings(max_examples=25)
@given(st.integers(2, 10), st.integers(1, 4))
def test_reclaim_never_frees_actively_referenced_block(n_sessions, seed):
    """Eviction under pressure (sessions then index) must only ever return
    blocks with NO remaining table/pin references to the free list."""
    pool = mk_pool(n_blocks=10)
    sm = SessionManager(pool, max_bytes=pool.capacity_bytes())
    idx = PrefixIndex(pool)
    pool.add_reclaimer(sm)
    pool.add_reclaimer(idx)
    rs = np.random.RandomState(seed)
    shared = np.arange(2 * B, dtype=np.int32)      # one common prefix
    active = None
    try:
        for i in range(n_sessions):
            seat(pool, i, shared)
            idx.insert("e0", shared, pool.table(i))
            sm.retain(f"s{i}", i, "e0", shared)
        m = idx.match("e0", np.concatenate(
            [shared, np.asarray([9], np.int32)]))
        if m is not None:
            pool.open(500, adopt=m[0], adopt_len=m[1])
            pool.unpin(m[0])
            active = 500
    except MemoryError:
        pass
    held = pool.table(active) if active is not None else []
    # drive hard pressure: ask for everything reclaimable and then some
    pool._reclaim(pool.n_blocks)
    for b in held:
        assert pool.refcount(b) >= 1, "reclaim freed an active block"
        assert b not in pool._free
    if active is not None:
        assert rows(pool, active) == [int(x) for x in shared[:pool.length(
            active)]]
        pool.free(active)
    sm.evict_all()
    idx.clear()
    assert pool.stats.blocks_in_use == 0


# ------------------------------------------------------------ engine level
@pytest.fixture(scope="module")
def cfg():
    return reduced(get_config("samba-coe-expert-7b"))


@pytest.fixture(scope="module")
def experts(cfg):
    m = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    return [jax.tree.map(np.asarray, m.init(jax.random.fold_in(rng, i)))
            for i in range(2)]


def _mk_coe(cfg, experts, **kw):
    nbytes = sum(x.nbytes for x in jax.tree.leaves(experts[0]))
    coe = CompositionOfExperts(HashRouter(len(experts)), None,
                               int(2.5 * nbytes), **kw)
    for i, h in enumerate(experts):
        coe.register(ExpertHandle(f"e{i}", cfg, h))
    return coe


def _session_trace(cfg, n_sessions=4, turns=2):
    rs = np.random.RandomState(11)
    sysp = rs.randint(1, cfg.vocab_size, (12,)).astype(np.int32)
    trace = []
    for s in range(n_sessions):
        trace.append({"sid": f"s{s}", "expert": f"e{s % 2}", "sys": sysp,
                      "user": [rs.randint(1, cfg.vocab_size, (5,))
                               .astype(np.int32) for _ in range(turns)]})
    return trace, turns


def _replay(eng, trace, turns, sharing):
    history = {}
    outs = {}
    for w in range(turns):
        rids = []
        for s in trace:
            p = np.concatenate([history.get(s["sid"], s["sys"]),
                                s["user"][w]])
            rid = w * 100 + int(s["sid"][1:])
            eng.submit(Request(rid=rid, tokens=p, max_new_tokens=3,
                               expert=s["expert"],
                               session_id=s["sid"] if sharing else None))
            rids.append((s, rid, p))
        done = {r.rid: r for r in eng.drain()}
        for s, rid, p in rids:
            outs[rid] = done[rid].output
            history[s["sid"]] = np.concatenate(
                [p, done[rid].output]).astype(np.int32)
    return outs


@pytest.mark.slow
def test_shared_vs_unshared_token_identity(cfg, experts):
    """The tentpole acceptance claim: prefix sharing changes where KV bytes
    live, never which tokens come out — and actually shares."""
    trace, turns = _session_trace(cfg)
    outs = {}
    for sharing in (False, True):
        coe = _mk_coe(cfg, experts)
        eng = ServingEngine(coe, cfg, max_len=64, n_slots=2, block_size=8,
                            prefix_sharing=sharing, kv_dtype=jnp.float32)
        outs[sharing] = _replay(eng, trace, turns, sharing)
        if sharing:
            assert eng.stats.prefix_hit_tokens > 0
            eng.release_shared()
            assert eng.pool.stats.blocks_in_use == 0
    assert outs[False].keys() == outs[True].keys()
    for rid in outs[False]:
        assert (outs[False][rid] == outs[True][rid]).all(), \
            f"rid {rid}: sharing changed the tokens"


@pytest.mark.slow
def test_session_resume_adopts_history(cfg, experts):
    """Turn 2 of a session must adopt turn 1's KV (history prefill skipped),
    second-turn hits covering at least the full first-turn sequence."""
    trace, turns = _session_trace(cfg, n_sessions=1, turns=2)
    coe = _mk_coe(cfg, experts)
    eng = ServingEngine(coe, cfg, max_len=64, n_slots=2, block_size=8,
                        prefix_sharing=True, kv_dtype=jnp.float32)
    s = trace[0]
    eng.submit(Request(rid=0, tokens=np.concatenate([s["sys"], s["user"][0]]),
                       max_new_tokens=3, expert=s["expert"],
                       session_id=s["sid"]))
    (r1,) = eng.drain()
    assert s["sid"] in eng.sessions
    turn1_len = len(r1.tokens) + len(r1.output)
    eng.submit(Request(
        rid=1, tokens=np.concatenate([r1.tokens, r1.output, s["user"][1]]),
        max_new_tokens=3, expert=s["expert"], session_id=s["sid"]))
    (r2,) = eng.drain()
    assert r2.prefix_hit_tokens >= turn1_len - 1   # -1: last KV not written
    eng.release_shared()
    assert eng.pool.stats.blocks_in_use == 0


@pytest.mark.slow
def test_drain_holds_hbm_budget_every_step(cfg, experts):
    """With a real carved HBM budget (weights vs KV reserve), a sharing
    drain must stay in budget at EVERY step and leak nothing."""
    nbytes = sum(x.nbytes for x in jax.tree.leaves(experts[0]))
    coe = _mk_coe(cfg, experts, kv_reserve_bytes=int(0.5 * nbytes))
    eng = ServingEngine(coe, cfg, max_len=64, n_slots=2, block_size=8,
                        prefix_sharing=True, kv_dtype=jnp.float32)
    trace, turns = _session_trace(cfg, n_sessions=3, turns=2)
    history = {}
    for w in range(turns):
        for s in trace:
            p = np.concatenate([history.get(s["sid"], s["sys"]),
                                s["user"][w]])
            eng.submit(Request(rid=w * 100 + int(s["sid"][1:]), tokens=p,
                               max_new_tokens=3, expert=s["expert"],
                               session_id=s["sid"]))
        pending = {w * 100 + int(s["sid"][1:]): s for s in trace}
        while pending:
            for r in eng.step():
                s = pending.pop(r.rid)
                history[s["sid"]] = np.concatenate(
                    [r.tokens, r.output]).astype(np.int32)
            assert eng.hbm_in_budget(), "HBM budget violated mid-drain"
    eng.release_shared()
    assert eng.pool.stats.blocks_in_use == 0
    assert eng.pool.stats.allocs == eng.pool.stats.frees
