"""Router coverage (ISSUE 5 satellite): `core/router.py` previously had no
direct tests. Pins HashRouter determinism/stability across processes,
LMRouter logit shapes + argmax routing on a tiny config, and the serving
engine's router wiring (untagged requests route, tagged requests keep their
tag, unknown tags fail fast)."""
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.router import HashRouter, LMRouter

_ROOT = str(pathlib.Path(__file__).resolve().parent.parent)


# ----------------------------------------------------------- HashRouter
def test_hash_router_stable_and_in_range():
    r = HashRouter(6)
    toks = np.random.RandomState(0).randint(0, 1000, (16, 9)).astype(np.int32)
    a = r.route_host(toks)
    b = r.route_host(toks)
    assert (a == b).all()                      # deterministic
    assert ((0 <= a) & (a < 6)).all()
    # row-wise: each prompt's assignment is independent of its batch mates
    solo = np.array([int(r.route_host(t[None])[0]) for t in toks])
    assert (solo == a).all()
    # seed changes the mapping (different composition, different hash)
    assert (HashRouter(6, seed=1).route_host(toks) != a).any()
    # device-path wrapper agrees with the host path
    assert (np.asarray(r.route(None, jnp.asarray(toks))) == a).all()


def test_hash_router_deterministic_across_processes():
    """The same prompts must map to the same experts in a fresh interpreter
    — multi-node front-ends rely on routing being process-invariant."""
    toks = np.arange(24, dtype=np.int32).reshape(4, 6)
    here = HashRouter(5, seed=3).route_host(toks).tolist()
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent("""
            import numpy as np
            from repro.core.router import HashRouter
            toks = np.arange(24, dtype=np.int32).reshape(4, 6)
            print("ROUTES", HashRouter(5, seed=3).route_host(toks).tolist())
        """)],
        capture_output=True, text=True, timeout=120,
        env={"PYTHONPATH": "src", "PATH": os.environ["PATH"],
             "HOME": os.environ.get("HOME", "/root"),
             "JAX_PLATFORMS": "cpu"},
        cwd=_ROOT)
    assert out.returncode == 0, out.stdout + out.stderr
    assert f"ROUTES {here}" in out.stdout


# ------------------------------------------------------------- LMRouter
@pytest.fixture(scope="module")
def lm_router():
    cfg = reduced(get_config("samba-coe-expert-7b"))
    router = LMRouter(cfg, n_experts=5)
    return router, router.init(jax.random.PRNGKey(0))


def test_lm_router_logits_shape_and_argmax(lm_router):
    router, params = lm_router
    toks = np.random.RandomState(1).randint(
        0, router.cfg.vocab_size, (3, 7)).astype(np.int32)
    logits = router.logits(params, jnp.asarray(toks))
    assert logits.shape == (3, 5)
    assert logits.dtype == jnp.float32
    idx = np.asarray(router.route(params, jnp.asarray(toks)))
    assert idx.shape == (3,)
    assert (idx == np.asarray(jnp.argmax(logits, axis=-1))).all()
    assert ((0 <= idx) & (idx < 5)).all()


def test_lm_router_param_specs_match_init(lm_router):
    router, params = lm_router
    assert params["head"].shape == (router.cfg.d_model, 5)
    abstract = router.abstract_params()
    flat_a = jax.tree.leaves(abstract)
    flat_p = jax.tree.leaves(params)
    assert len(flat_a) == len(flat_p)
    for a, p in zip(flat_a, flat_p):
        assert a.shape == p.shape


# ------------------------------------------------- engine router wiring
def test_engine_routes_untagged_and_honors_tags():
    """ISSUE 5 satellite: ``ServingEngine.submit`` routes ``expert=None``
    through the composition's router, keeps caller tags, and rejects
    unknown experts."""
    from repro.core import CompositionOfExperts, ExpertHandle
    from repro.models import get_model
    from repro.serving import Request, ServingEngine

    class FirstTokenRouter:
        def __init__(self, n):
            self.n = n

        def route(self, params, tokens):
            return jnp.asarray(np.asarray(tokens)[:, 0] % self.n)

    cfg = reduced(get_config("samba-coe-expert-7b"))
    m = get_model(cfg)
    experts = [jax.tree.map(np.asarray, m.init(jax.random.PRNGKey(i)))
               for i in range(2)]
    nbytes = sum(x.nbytes for x in jax.tree.leaves(experts[0]))
    coe = CompositionOfExperts(FirstTokenRouter(2), None, int(5 * nbytes))
    for i, h in enumerate(experts):
        coe.register(ExpertHandle(f"e{i}", cfg, h))
    eng = ServingEngine(coe, cfg, max_len=16, n_slots=2, block_size=8)

    def prompt(first):
        p = np.random.RandomState(first).randint(
            0, cfg.vocab_size, (6,)).astype(np.int32)
        p[0] = first
        return p

    eng.submit(Request(rid=0, tokens=prompt(1), max_new_tokens=2))
    assert eng.queue[-1].expert == "e1"          # routed at arrival
    # caller tag wins over what the router would have said
    eng.submit(Request(rid=1, tokens=prompt(1), max_new_tokens=2,
                       expert="e0"))
    assert eng.queue[-1].expert == "e0"
    with pytest.raises(KeyError, match="unknown expert"):
        eng.submit(Request(rid=2, tokens=prompt(0), max_new_tokens=2,
                           expert="nope"))
    done = eng.drain()
    assert {r.rid: r.expert for r in done} == {0: "e1", 1: "e0"}
    assert eng.stats.route_s >= 0.0
