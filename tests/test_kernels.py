"""Per-kernel shape/dtype sweeps vs the pure-jnp ref oracles (deliverable c).
All kernels run in interpret mode on CPU (TPU is the lowering target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention as fa_attention
from repro.kernels.flash_attention import decode as fa_decode
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.fused_decode import (decoder_layer_step, ffn_swiglu,
                                        qkv_rope)
from repro.kernels.fused_decode import ref as fd_ref
from repro.kernels.monarch_fft import monarch, monarch_conv, ref as mf_ref


def _tol(dt):
    return 2e-2 if dt == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------------- flash
@pytest.mark.parametrize("B,S,Hq,Hkv,dh", [
    (2, 256, 4, 2, 64),
    (1, 512, 8, 1, 128),
    (2, 256, 4, 4, 32),
    (1, 256, 2, 2, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 128])
def test_flash_prefill(B, S, Hq, Hkv, dh, dtype, window, rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, dh), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, dh), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, dh), dtype)
    out = fa_attention(q, k, v, causal=True, window=window)
    exp = fa_ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out.astype(np.float32), exp.astype(np.float32),
                               atol=_tol(dtype) * 3, rtol=0.05)


@pytest.mark.parametrize("B,S,Hq,Hkv,dh,length", [
    (2, 1024, 8, 2, 64, 700),
    (1, 512, 4, 1, 128, 512),
    (2, 512, 4, 4, 32, 100),
    (1, 512, 2, 1, 64, 1),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode(B, S, Hq, Hkv, dh, length, dtype, rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, Hq, dh), dtype)
    kc = jax.random.normal(ks[1], (B, S, Hkv, dh), dtype)
    vc = jax.random.normal(ks[2], (B, S, Hkv, dh), dtype)
    out = fa_decode(q, kc, vc, length)
    exp = fa_ref.decode_attention_ref(q, kc, vc, length)
    np.testing.assert_allclose(out.astype(np.float32), exp.astype(np.float32),
                               atol=_tol(dtype) * 3, rtol=0.05)


# ---------------------------------------------------------- fused decode
@pytest.mark.parametrize("B,D,n_q,n_kv,dh", [
    (2, 256, 8, 2, 32),
    (1, 128, 4, 4, 64),
    (4, 256, 4, 1, 128),
])
def test_qkv_rope(B, D, n_q, n_kv, dh, rng):
    H = n_q + 2 * n_kv
    x = jax.random.normal(rng, (B, D), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(rng, 1), (D, H * dh)) / np.sqrt(D)
    scale = jnp.ones(D)
    out = qkv_rope(x, scale, w, jnp.int32(13), n_q=n_q, n_kv=n_kv, dh=dh,
                   interpret=True)
    exp = fd_ref.qkv_rope_ref(x, scale, w, jnp.int32(13), n_q=n_q, n_kv=n_kv,
                              dh=dh)
    np.testing.assert_allclose(out, exp, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("B,D,F,bf", [(2, 128, 512, 128), (1, 256, 1024, 512),
                                      (3, 128, 256, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ffn_swiglu(B, D, F, bf, dtype, rng):
    ks = jax.random.split(rng, 4)
    x = jax.random.normal(ks[0], (B, D), dtype)
    wg = (jax.random.normal(ks[1], (D, F)) / np.sqrt(D)).astype(dtype)
    wu = (jax.random.normal(ks[2], (D, F)) / np.sqrt(D)).astype(dtype)
    wd = (jax.random.normal(ks[3], (F, D)) / np.sqrt(F)).astype(dtype)
    scale = jnp.ones(D, dtype)
    out = ffn_swiglu(x, scale, wg, wu, wd, block_f=bf, interpret=True)
    exp = fd_ref.ffn_swiglu_ref(x, scale, wg, wu, wd)
    np.testing.assert_allclose(out.astype(np.float32), exp.astype(np.float32),
                               atol=_tol(dtype) * 4, rtol=0.05)


def test_fused_decoder_layer_step(rng):
    B, D, n_q, n_kv, dh, F, S = 2, 256, 8, 2, 32, 512, 128
    ks = jax.random.split(rng, 8)
    x = jax.random.normal(ks[0], (B, D), jnp.float32)
    p = {
        "attn_norm": jnp.ones(D), "mlp_norm": jnp.ones(D),
        "w_qkv": jax.random.normal(ks[1], (D, (n_q + 2 * n_kv) * dh)) / 16,
        "w_o": jax.random.normal(ks[2], (n_q * dh, D)) / 16,
        "w_gate": jax.random.normal(ks[3], (D, F)) / 16,
        "w_up": jax.random.normal(ks[4], (D, F)) / 16,
        "w_down": jax.random.normal(ks[5], (F, D)) / 16,
    }
    kc = jax.random.normal(ks[6], (B, S, n_kv, dh))
    vc = jax.random.normal(ks[7], (B, S, n_kv, dh))
    pos = jnp.int32(57)
    y, kc2, vc2 = decoder_layer_step(x, p, kc.copy(), vc.copy(), pos,
                                     n_q=n_q, n_kv=n_kv, dh=dh, interpret=True)
    yr, kcr, vcr = fd_ref.decoder_layer_step_ref(x, p, kc, vc, pos,
                                                 n_q=n_q, n_kv=n_kv, dh=dh)
    np.testing.assert_allclose(y, yr, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(kc2, kcr, atol=1e-5)


# ---------------------------------------------------------------- monarch
@pytest.mark.parametrize("B,N1,N2", [(2, 128, 256), (1, 256, 128),
                                     (3, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_monarch(B, N1, N2, dtype, rng):
    ks = jax.random.split(rng, 4)
    x = jax.random.normal(ks[0], (B, N1, N2), dtype)
    w0 = (jax.random.normal(ks[1], (N1, N1)) / np.sqrt(N1)).astype(dtype)
    tw = jax.random.normal(ks[2], (N1, N2), dtype)
    w1 = (jax.random.normal(ks[3], (N2, N2)) / np.sqrt(N2)).astype(dtype)
    out = monarch(x, w0, tw, w1)
    exp = mf_ref.monarch_ref(x, w0, tw, w1)
    np.testing.assert_allclose(out.astype(np.float32), exp.astype(np.float32),
                               atol=_tol(dtype) * 5, rtol=0.05)


def test_monarch_conv_matches_ref(rng):
    B, N1, N2 = 2, 128, 128
    ks = jax.random.split(rng, 8)
    mk = lambda i, *s: jax.random.normal(ks[i], s) / np.sqrt(s[-1])
    x = jax.random.normal(ks[0], (B, N1, N2))
    args = (x, mk(1, N1, N1), jax.random.normal(ks[2], (N1, N2)),
            mk(3, N2, N2), jax.random.normal(ks[4], (N2, N1)),
            mk(5, N2, N2), jax.random.normal(ks[6], (N2, N1)),
            mk(7, N1, N1))
    out = monarch_conv(*args)
    exp = mf_ref.monarch_conv_ref(*args)
    rel = float(jnp.max(jnp.abs(out - exp))) / (float(jnp.max(jnp.abs(exp))) + 1e-9)
    assert rel < 1e-4


def test_fusion_raises_operational_intensity():
    """Paper Table I: fused intensity must far exceed unfused."""
    from repro.kernels.monarch_fft import operational_intensity
    none = operational_intensity(16, 1024, 1024, fusion="none")
    part = operational_intensity(16, 1024, 1024, fusion="gemm0_mul_t")
    full = operational_intensity(16, 1024, 1024, fusion="full")
    assert none < part < full
    assert full / none > 2.0


# ---------------------------------------------------------------- lru scan
@pytest.mark.parametrize("B,S,D,bs,bd", [
    (2, 512, 256, 256, 256),
    (1, 256, 512, 128, 256),
    (3, 128, 128, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lru_scan_kernel(B, S, D, bs, bd, dtype, rng):
    from repro.kernels.lru_scan import lru_scan, ref as lru_ref
    ks = jax.random.split(rng, 2)
    # decay-like coefficients keep the recurrence numerically tame
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, D))).astype(dtype)
    b = (jax.random.normal(ks[1], (B, S, D)) * 0.1).astype(dtype)
    out = lru_scan(a, b, block_s=bs, block_d=bd)
    exp = lru_ref.lru_scan_ref(a.astype(jnp.float32), b.astype(jnp.float32))
    np.testing.assert_allclose(out.astype(np.float32), exp,
                               atol=_tol(dtype) * 4, rtol=0.05)
