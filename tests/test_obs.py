"""Unified telemetry (ISSUE 6): metrics registry, span tracing, ledger.

Covers: P² streaming-quantile accuracy vs exact percentiles, registry
get-or-create/label semantics, registry-backed stats views (the rewired
``SwitchStats``/``ServeStats``/... surface), the tier-transfer ledger,
span nesting + thread-safety, Chrome-trace schema validity for a real
engine drain, the disabled-tracing zero-allocation guard, failed-prefetch
stall attribution, and the Prometheus/JSON HTTP endpoint.
"""
import json
import threading
import time
import urllib.request

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import CompositionOfExperts, ExpertHandle, HashRouter
from repro.core.switching import HBMWeightCache, SwitchStats
from repro.models import get_model
from repro.obs import trace
from repro.obs.httpd import serve_metrics
from repro.obs.ledger import TransferLedger
from repro.obs.metrics import Histogram, MetricsRegistry, scoped
from repro.obs.stats import StatsView, as_dict, counter_field, gauge_field
from repro.obs.trace import NOOP_SPAN, Tracer, validate_chrome_trace
from repro.serving import Request, ServingEngine
from repro.store import HostMemoryStore


# ----------------------------------------------------------------------
# streaming quantiles
# ----------------------------------------------------------------------
@pytest.mark.parametrize("dist", ["uniform", "lognormal"])
def test_p2_quantiles_match_exact_within_5pct(dist):
    rs = np.random.RandomState(0)
    xs = (rs.uniform(0.0, 10.0, 20000) if dist == "uniform"
          else rs.lognormal(0.0, 0.75, 20000))
    h = Histogram("lat")
    for x in xs:
        h.observe(float(x))
    for p in (0.5, 0.95, 0.99):
        exact = float(np.percentile(xs, p * 100))
        assert h.quantile(p) == pytest.approx(exact, rel=0.05), f"p{p}"
    s = h.summary()
    assert s["count"] == len(xs)
    assert s["min"] == pytest.approx(xs.min())
    assert s["max"] == pytest.approx(xs.max())
    assert s["mean"] == pytest.approx(xs.mean(), rel=1e-6)
    assert set(s) >= {"p50", "p95", "p99"}


def test_histogram_few_samples_falls_back_to_sorted():
    h = Histogram("lat")
    assert h.quantile(0.5) == 0.0          # empty
    for x in [3.0, 1.0, 2.0]:
        h.observe(x)
    assert h.quantile(0.5) == 2.0          # exact on <5 samples


# ----------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------
def test_registry_get_or_create_and_labels():
    reg = MetricsRegistry()
    c1 = reg.counter("x.hits")
    c1.inc(3)
    assert reg.counter("x.hits") is c1                 # get-or-create
    c2 = reg.counter("x.hits", {"group": "g0"})
    assert c2 is not c1                                # labels split series
    c2.inc()
    snap = reg.snapshot()
    assert snap["x.hits"] == 3
    assert snap["x.hits{group=g0}"] == 1
    with pytest.raises(TypeError):
        reg.gauge("x.hits")                            # kind mismatch
    text = reg.to_prometheus()
    assert "# TYPE x_hits counter" in text
    assert 'x_hits{group="g0"} 1' in text


def test_scoped_registry_isolation():
    from repro.obs.metrics import get_registry
    outer = get_registry()
    with scoped() as reg:
        assert get_registry() is reg
        reg.counter("only.inner").inc()
    assert get_registry() is outer
    assert "only.inner" not in outer.snapshot()


# ----------------------------------------------------------------------
# stats views
# ----------------------------------------------------------------------
class _ToyStats(StatsView):
    PREFIX = "toy"
    DERIVED = ("double",)

    hits = counter_field()
    lat_s = counter_field(0.0)
    depth = gauge_field()

    @property
    def double(self):
        return 2 * self.hits


def test_statsview_registry_backed():
    reg = MetricsRegistry()
    st = _ToyStats(registry=reg, labels={"group": "g1"}, hits=2)
    st.hits += 3
    st.lat_s += 0.25
    st.depth = 7
    # the same numbers are visible through the registry, no copying
    snap = reg.snapshot()
    assert snap["toy.hits{group=g1}"] == 5
    assert snap["toy.lat_s{group=g1}"] == 0.25
    assert snap["toy.depth{group=g1}"] == 7
    assert st.as_dict() == {"hits": 5, "lat_s": 0.25, "depth": 7,
                            "double": 10}
    st.reset()
    assert st.hits == 0 and reg.snapshot()["toy.hits{group=g1}"] == 0
    # reset keeps the same series object (benchmarks reuse views per phase)
    st.hits += 1
    assert reg.snapshot()["toy.hits{group=g1}"] == 1


def test_bare_statsviews_do_not_alias():
    a, b = _ToyStats(), _ToyStats()
    a.hits += 5
    assert b.hits == 0                     # private registry per bare view


def test_switchstats_as_dict_superset_of_seed_shape():
    seed_keys = {
        "hits", "misses", "prefetch_hits", "prefetches_issued",
        "prefetches_cancelled", "evictions", "drops", "bytes_copied_in",
        "bytes_copied_back", "bytes_copyback_elided", "switch_seconds",
        "stall_miss_seconds", "stall_prefetch_seconds",
        "store_read_seconds", "h2d_seconds", "copy_seconds",
        "overlap_ratio"}
    d = SwitchStats().as_dict()
    assert seed_keys <= set(d)             # baseline gate compatibility
    assert {"prefetch_failures", "stall_failed_prefetch_seconds"} <= set(d)


def test_shared_as_dict_handles_plain_dataclasses():
    from repro.store.base import StoreStats
    st = StoreStats()
    st.reads += 2
    d = as_dict(st)
    assert d["reads"] == 2 and "bytes_read" in d


# ----------------------------------------------------------------------
# transfer ledger
# ----------------------------------------------------------------------
def test_ledger_edges_stalls_and_overlap():
    reg = MetricsRegistry()
    led = TransferLedger(reg)
    led.record("store_read", 1000, 0.4, cause="miss", expert="e0")
    led.record("h2d", 1000, 0.6, cause="miss")
    led.note_stall(0.2, cause="miss")
    assert led.bytes_moved("store_read") == 1000
    assert led.copy_seconds == pytest.approx(1.0)
    assert led.stall_seconds == pytest.approx(0.2)
    assert led.overlap_ratio == pytest.approx(0.8)
    assert led.bandwidth_bps("h2d") == pytest.approx(1000 / 0.6)
    snap = reg.snapshot()
    assert snap["ledger.bytes{cause=miss,edge=store_read}"] == 1000
    assert snap["ledger.bytes_by_expert{expert=e0}"] == 1000
    assert snap["ledger.overlap_ratio"] == pytest.approx(0.8)
    assert snap["ledger.bandwidth_bps{edge=h2d}"] == pytest.approx(1000 / 0.6)
    led.reserve(512)
    assert led.reserved_bytes == 512
    led.release(512)
    assert led.reserved_bytes == 0
    d = led.as_dict()
    assert d["store_read_bytes"] == 1000 and d["overlap_ratio"] > 0
    with pytest.raises(ValueError):
        led.record("sideways", 1, 0.1)


# ----------------------------------------------------------------------
# tracing
# ----------------------------------------------------------------------
def test_disabled_tracing_is_allocation_free():
    tr = Tracer()
    assert tr.span("x") is NOOP_SPAN       # module-level singleton, no alloc
    assert tr.span("y", request_id=1) is NOOP_SPAN
    with tr.span("x") as sp:
        assert sp.add(k=1) is sp
    tr.instant("i")
    tr.async_begin("r", id=1)
    tr.async_end("r", id=1)
    assert tr.events() == []               # nothing recorded while disabled


def test_span_nesting_records_containment():
    tr = Tracer()
    tr.start()
    with tr.span("outer", cat="t"):
        time.sleep(0.002)
        with tr.span("inner", cat="t"):
            time.sleep(0.002)
        time.sleep(0.002)
    tr.stop()
    evs = {e["name"]: e for e in tr.events()}
    o, i = evs["outer"], evs["inner"]
    assert o["ts"] <= i["ts"]
    assert i["ts"] + i["dur"] <= o["ts"] + o["dur"] + 1.0   # 1us slack
    assert o["dur"] >= i["dur"]


def test_trace_thread_safety_no_lost_events():
    tr = Tracer()
    tr.start()
    n_threads, n_spans = 8, 200
    barrier = threading.Barrier(n_threads)   # all threads alive at once

    def worker(k):
        barrier.wait()
        for j in range(n_spans):
            with tr.span("w", cat="t", thread=k, j=j):
                pass
            tr.instant("tick", cat="t", thread=k)

    threads = [threading.Thread(target=worker, args=(k,))
               for k in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    tr.stop()
    evs = tr.events()
    assert len(evs) == n_threads * n_spans * 2
    assert len({e["tid"] for e in evs}) == n_threads
    doc = tr.to_chrome_trace()
    assert validate_chrome_trace(doc) == []


def test_validator_flags_malformed_documents():
    assert validate_chrome_trace({}) == ["missing top-level 'traceEvents'"]
    bad = {"traceEvents": [
        {"name": "x", "ph": "X", "pid": 1, "tid": 1, "ts": 0.0},  # no dur
        {"name": "q", "ph": "?", "pid": 1, "tid": 1, "ts": 0.0},  # bad phase
        {"name": "r", "ph": "e", "id": 9, "pid": 1, "tid": 1,
         "ts": 0.0},                                              # end<begin
        {"name": "r", "ph": "b", "id": 8, "pid": 1, "tid": 1,
         "ts": 0.0},                                              # unclosed
    ]}
    problems = validate_chrome_trace(bad)
    assert any("without dur" in p for p in problems)
    assert any("unknown phase" in p for p in problems)
    assert any("end before begin" in p for p in problems)
    assert any("unclosed" in p for p in problems)


# ----------------------------------------------------------------------
# engine integration: lifecycle spans + wall-clock accounting
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def cfg():
    return reduced(get_config("samba-coe-expert-7b"))


def _mk_engine(cfg, n_experts=2, **kw):
    m = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    experts = [jax.tree.map(np.asarray, m.init(jax.random.fold_in(rng, i)))
               for i in range(n_experts)]
    nbytes = sum(x.nbytes for x in jax.tree.leaves(experts[0]))
    coe = CompositionOfExperts(HashRouter(n_experts), None,
                               int(2.5 * nbytes))
    for i, h in enumerate(experts):
        coe.register(ExpertHandle(f"e{i}", cfg, h))
    return ServingEngine(coe, cfg, max_len=32, n_slots=2, block_size=8, **kw)


def _mk_requests(cfg, n, new_tokens=4):
    rs = np.random.RandomState(0)
    return [Request(rid=i,
                    tokens=rs.randint(0, cfg.vocab_size, (8,)).astype(np.int32),
                    max_new_tokens=new_tokens)
            for i in range(n)]


def test_engine_trace_covers_request_lifecycle(cfg, tmp_path):
    old = trace.set_tracer(Tracer())
    try:
        eng = _mk_engine(cfg)
        reqs = _mk_requests(cfg, 4)
        eng.submit(reqs[0])                # warm up jit outside the trace
        eng.drain()
        trace.enable()
        for r in reqs[1:]:
            eng.submit(r)
        t0 = time.perf_counter()
        done = eng.drain()
        wall = time.perf_counter() - t0
        trace.disable()
        assert len(done) == 3

        evs = trace.events()
        names = {e["name"] for e in evs}
        assert {"route", "step", "prefill", "decode", "admit",
                "request"} <= names
        # every submitted request opens and closes one async lane
        begins = {e["id"] for e in evs if e["ph"] == "b"
                  and e["name"] == "request"}
        ends = {e["id"] for e in evs if e["ph"] == "e"
                and e["name"] == "request"}
        assert begins == ends == {r.rid for r in reqs[1:]}

        # acceptance: step spans account for the drain's wall-clock
        step_s = sum(e["dur"] for e in evs
                     if e["name"] == "step" and e["ph"] == "X") / 1e6
        assert step_s == pytest.approx(wall, rel=0.10)

        # exported document is schema-valid Chrome trace JSON
        path = trace.export(tmp_path / "trace.json")
        doc = json.loads(path.read_text())
        assert validate_chrome_trace(doc) == []
        assert any(e["ph"] == "M" for e in doc["traceEvents"])
    finally:
        trace.set_tracer(old)


# ----------------------------------------------------------------------
# failed-prefetch stall attribution (satellite of ISSUE 6)
# ----------------------------------------------------------------------
class _FailOnceStore(HostMemoryStore):
    def __init__(self):
        super().__init__()
        self.fail_next = False

    def get(self, name):
        if self.fail_next:
            self.fail_next = False
            raise IOError("transient capacity-tier read failure")
        return super().get(name)


def test_failed_prefetch_attribution_and_ledger():
    s = _FailOnceStore()
    s.put("e0", {"w": np.zeros(1024, np.float32)})
    cache = HBMWeightCache(1 << 20, store=s)
    s.fail_next = True
    assert cache.prefetch("e0") is True
    deadline = time.time() + 2.0
    while cache.inflight("e0") and not cache._inflight["e0"].done():
        assert time.time() < deadline
        time.sleep(0.005)
    cache.activate("e0")                   # waits on the doomed future,
    st = cache.stats                       # then retries inline as a miss
    assert st.prefetch_failures == 1
    assert st.stall_failed_prefetch_seconds > 0.0
    assert st.misses == 1 and st.prefetch_hits == 0
    # the wasted wait is NOT in the miss bucket (the pre-ISSUE-6 bug)
    assert st.switch_seconds == pytest.approx(
        st.stall_miss_seconds + st.stall_failed_prefetch_seconds, rel=1e-6)
    snap = cache.stats.registry.snapshot()
    assert snap["ledger.stall_seconds{cause=failed_prefetch}"] > 0.0
    assert snap["ledger.stall_seconds{cause=miss}"] > 0.0
    assert cache.ledger.reserved_bytes == 0    # reservation released
    cache.close()


def test_cache_publishes_ledger_and_gauges():
    s = HostMemoryStore()
    s.put("e0", {"w": np.zeros(4096, np.float32)})
    reg = MetricsRegistry()
    cache = HBMWeightCache(1 << 20, store=s, registry=reg,
                           labels={"group": "g0"})
    cache.activate("e0")
    snap = reg.snapshot()
    assert snap["switch.misses{group=g0}"] == 1
    assert snap["switch.hbm_used_bytes{group=g0}"] == cache.used_bytes
    assert snap["ledger.bytes{cause=miss,edge=store_read,group=g0}"] > 0
    assert cache.ledger.bytes_moved("h2d") == cache.stats.bytes_copied_in
    cache.close()


# ----------------------------------------------------------------------
# HTTP exposition
# ----------------------------------------------------------------------
def test_metrics_http_endpoints():
    reg = MetricsRegistry()
    reg.counter("serve.requests").inc(7)
    reg.histogram("serve.lat_s").observe(0.25)
    srv = serve_metrics(reg, port=0)
    try:
        text = urllib.request.urlopen(f"{srv.url}/metrics").read().decode()
        assert "serve_requests 7" in text
        assert "serve_lat_s_count 1" in text
        snap = json.loads(
            urllib.request.urlopen(f"{srv.url}/metrics.json").read())
        assert snap["serve.requests"] == 7
        assert snap["serve.lat_s:count"] == 1
        ok = urllib.request.urlopen(f"{srv.url}/healthz")
        assert ok.status == 200
    finally:
        srv.stop()
