"""Distribution tests: partitioning rules, sharded-vs-single equivalence,
pipeline parallelism, gradient compression. Multi-device cases run in
subprocesses with --xla_force_host_platform_device_count (tests themselves
stay on 1 device)."""
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, pad_for_tp, reduced
from repro.distributed import partitioning as part
from repro.launch.mesh import single_device_mesh
from repro.models import get_model
from repro.models.common import ParamSpec


_ROOT = str(pathlib.Path(__file__).resolve().parent.parent)


def _run_sub(code: str):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": os.environ["PATH"],
                            "HOME": os.environ.get("HOME", "/root"),
                            "JAX_PLATFORMS": "cpu"},
                       cwd=_ROOT)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


@pytest.mark.parametrize("arch", sorted(ARCH_IDS))
def test_param_pspecs_divisibility(arch):
    """Every sharded param dim must divide the mesh axis on the production
    mesh (the dry-run's correctness precondition)."""
    import numpy as np
    from jax.sharding import PartitionSpec as P

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    cfg = pad_for_tp(get_config(arch), 16)
    model = get_model(cfg)
    specs = model.param_specs()
    pspecs = part.param_pspecs(specs, FakeMesh())
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    flat_p = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    for s, p in zip(flat_s, flat_p):
        for dim, entry in zip(s.shape, tuple(p) + (None,) * len(s.shape)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            tot = int(np.prod([FakeMesh.shape[a] for a in axes]))
            assert dim % tot == 0, (arch, s, p)


def test_fit_pspec_drops_undivisible():
    from jax.sharding import PartitionSpec as P

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    p = part.fit_pspec((1, 100, 32), P("data", None, "model"), FakeMesh())
    assert p == P(None, None, "model")


def test_sharded_equals_single_device_forward():
    """(2,2) sharded forward == single-device forward (numerical identity
    of the partitioning), via subprocess with 4 fake devices."""
    out = _run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, reduced, pad_for_tp
        from repro.models import get_model
        from repro.distributed import partitioning as part
        from repro.launch.mesh import make_mesh

        cfg = pad_for_tp(reduced(get_config("granite-8b")), 2)
        m = get_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                  cfg.vocab_size)
        ref = m.forward(params, {"tokens": toks}).astype(jnp.float32)

        mesh = make_mesh((2, 2), ("data", "model"))
        pspecs = part.param_pspecs(m.param_specs(), mesh)
        sh = jax.tree.map(lambda p: NamedSharding(mesh, p), pspecs,
                          is_leaf=lambda x: isinstance(x, P))
        sparams = jax.device_put(params, sh)
        stoks = jax.device_put(toks, NamedSharding(mesh, P("data", None)))
        with mesh:
            out = jax.jit(lambda p, t: m.forward(p, {"tokens": t}))(
                sparams, stoks).astype(jnp.float32)
        err = float(jnp.max(jnp.abs(out - ref)))
        scale = float(jnp.max(jnp.abs(ref))) + 1e-6
        assert err / scale < 2e-2, (err, scale)
        print("SHARDED_OK", err / scale)
    """)
    assert "SHARDED_OK" in out


def test_pipeline_parallel_matches_sequential():
    out = _run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from repro.distributed.pipeline import pipeline_apply, sequential_apply
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4,), ("stage",))
        S, M, D = 4, 6, 16
        k = jax.random.PRNGKey(0)
        params = {"w": jax.random.normal(k, (S, D, D)) * 0.3,
                  "b": jax.random.normal(k, (S, D))}
        x = jax.random.normal(jax.random.fold_in(k, 1), (M, 8, D))
        fn = lambda p, h: jnp.tanh(h @ p["w"] + p["b"])
        y = pipeline_apply(fn, params, x, mesh)
        yr = sequential_apply(fn, params, x)
        assert float(jnp.max(jnp.abs(y - yr))) < 1e-5
        print("PIPELINE_OK")
    """)
    assert "PIPELINE_OK" in out


def test_int8_compressed_allreduce_accuracy():
    out = _run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from repro.distributed.compression import make_compressed_allreduce
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
        red = make_compressed_allreduce(mesh, "data")({"g": g})["g"]
        exact = g.mean(0)
        rel = float(jnp.max(jnp.abs(red - exact)) /
                    (jnp.max(jnp.abs(exact)) + 1e-9))
        assert rel < 0.02, rel
        print("COMPRESS_OK", rel)
    """)
    assert "COMPRESS_OK" in out


def test_error_feedback_converges():
    """With error feedback, the accumulated compressed sum converges to the
    true sum (residual re-injection)."""
    from repro.distributed.compression import error_feedback_update
    true = jnp.asarray(np.random.RandomState(0).randn(32) * 0.01)
    resid = jnp.zeros(32)
    acc = jnp.zeros(32)
    for _ in range(50):
        v, resid = error_feedback_update(true, resid)
        acc = acc + v
    np.testing.assert_allclose(acc / 50, true, atol=1e-3)


def test_train_step_on_2x2_mesh():
    out = _run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from repro.configs import get_config, reduced, pad_for_tp
        from repro.distributed import stepfn
        from repro.launch.mesh import make_mesh
        from repro.models import get_model
        from repro.optim import init_opt_state
        cfg = pad_for_tp(reduced(get_config("mixtral-8x7b")), 2)
        mesh = make_mesh((2, 2), ("data", "model"))
        with mesh:
            fn, sh, _ = stepfn.make_train_step(cfg, mesh)
            m = get_model(cfg)
            params = m.init(jax.random.PRNGKey(0))
            state = jax.device_put({"params": params,
                                    "opt": init_opt_state(params)}, sh)
            toks = jax.random.randint(jax.random.PRNGKey(1), (4, 33), 0,
                                      cfg.vocab_size)
            batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
            l0 = None
            for i in range(3):
                state, metrics = fn(state, batch)
                if l0 is None:
                    l0 = float(metrics["loss"])
            l1 = float(metrics["loss"])
        assert l1 < l0, (l0, l1)
        print("TRAIN2x2_OK", l0, "->", l1)
    """)
    assert "TRAIN2x2_OK" in out


def test_moe_ep_local_matches_baseline():
    """shard_map-local EP dispatch == global sort-based dispatch."""
    out = _run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_config, reduced
        from repro.launch.mesh import make_mesh
        from repro.models.layers import moe_apply, moe_apply_ep_local, moe_specs
        from repro.models.common import init_params
        cfg = dataclasses.replace(reduced(get_config("deepseek-v2-lite-16b")),
                                  n_experts=4, top_k=2, capacity_factor=16.0,
                                  n_shared_experts=0)
        p = init_params(jax.random.PRNGKey(0), moe_specs(cfg))
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (4, 8, cfg.d_model)).astype(jnp.bfloat16)
        ref = moe_apply(cfg, p, x).astype(jnp.float32)
        mesh = make_mesh((2, 2), ("data", "model"))
        with mesh:
            out = jax.jit(lambda p, x: moe_apply_ep_local(cfg, p, x, mesh))(
                p, x).astype(jnp.float32)
        err = float(jnp.max(jnp.abs(out - ref)))
        scale = float(jnp.max(jnp.abs(ref))) + 1e-6
        assert err / scale < 0.02, (err, scale)
        print("EP_LOCAL_OK", err / scale)
    """)
    assert "EP_LOCAL_OK" in out


def test_elastic_restart_across_meshes():
    """Fault-tolerance/elasticity: train 3 steps on a (1,2) mesh, checkpoint,
    restore onto a (4,1) mesh (different chip count AND topology), continue
    training — loss trajectory must continue downward and params must match
    bit-exactly at the restore point."""
    out = _run_sub("""
        import os, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.checkpoint import CheckpointManager
        from repro.configs import get_config, reduced, pad_for_tp
        from repro.distributed import stepfn
        from repro.launch.mesh import make_mesh
        from repro.models import get_model
        from repro.optim import init_opt_state
        from repro.data import DataConfig, make_source

        cfg = pad_for_tp(reduced(get_config("granite-8b")), 2)
        src = make_source(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                     global_batch=4))
        ckdir = tempfile.mkdtemp()

        mesh1 = make_mesh((1, 2), ("data", "model"))
        with mesh1:
            fn, sh1, _ = stepfn.make_train_step(cfg, mesh1)
            m = get_model(cfg)
            state = jax.device_put({"params": m.init(jax.random.PRNGKey(0)),
                                    "opt": init_opt_state(
                                        m.init(jax.random.PRNGKey(0)))}, sh1)
            batch0 = jax.tree.map(jnp.asarray, src.batch_at(0))
            for step in range(3):
                state, metrics = fn(state, batch0)
            l3 = float(metrics["loss"])
            CheckpointManager(ckdir).save(3, state)
            w_before = np.asarray(jax.device_get(
                jax.tree.leaves(state["params"])[0]))

        # new "job": different mesh shape entirely
        mesh2 = make_mesh((4, 1), ("data", "model"))
        with mesh2:
            fn2, sh2, _ = stepfn.make_train_step(cfg, mesh2)
            m2 = get_model(cfg)
            like = {"params": m2.init(jax.random.PRNGKey(1)),
                    "opt": init_opt_state(m2.init(jax.random.PRNGKey(1)))}
            like = jax.device_put(like, sh2)
            state2, start = CheckpointManager(ckdir).restore_state(like, sh2)
            assert start == 3
            w_after = np.asarray(jax.device_get(
                jax.tree.leaves(state2["params"])[0]))
            assert (w_before == w_after).all(), "bit-exact restore"
            batch0 = jax.tree.map(jnp.asarray, src.batch_at(0))
            for step in range(3, 6):
                state2, metrics = fn2(state2, batch0)
            l6 = float(metrics["loss"])
        assert l6 < l3, (l3, l6)    # same batch: must keep descending
        print("ELASTIC_OK", l3, "->", l6)
    """)
    assert "ELASTIC_OK" in out
