import os

# Keep tests on ONE device — only the dry-run uses 512 placeholder devices
# (tests that need fake multi-device spawn subprocesses).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
