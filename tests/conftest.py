import os

# Keep tests on ONE device — only the dry-run uses 512 placeholder devices
# (tests that need fake multi-device spawn subprocesses).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

jax.config.update("jax_enable_x64", False)

# Property tests prefer the real hypothesis; fall back to the deterministic
# sampling stub in tests/_hypothesis_stub.py when it is not installed.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import importlib.util
    import pathlib
    import sys

    _stub_path = pathlib.Path(__file__).parent / "_hypothesis_stub.py"
    _spec = importlib.util.spec_from_file_location("hypothesis", _stub_path)
    _mod = importlib.util.module_from_spec(_spec)
    sys.modules["hypothesis"] = _mod
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis.strategies"] = _mod.strategies


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_sessionfinish(session, exitstatus):
    """On a failing run, dump the process-wide flight recorder so CI can
    attach the black-box bundle (ring events + metrics + component state)
    to the failure artifact."""
    if exitstatus == 0:
        return
    try:
        from repro.obs import flightrec, get_registry

        out = flightrec.dump("results/flight_pytest.json", get_registry(),
                             reason="pytest_failure")
        print(f"\nflight recorder bundle dumped to {out}")
    except Exception as e:  # noqa: BLE001 — never mask the real failure
        print(f"\nflight recorder dump failed: {type(e).__name__}: {e}")
