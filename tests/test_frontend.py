"""Streaming front door over the serving engine (ISSUE 9): token streaming
parity with the engine's own outputs, per-tenant quota enforcement,
SLO-priority preemption of unadmitted work, and the JSON-lines TCP server.

The engine under the frontend runs the reduced backbone with prefix sharing
on — the frontend is how the tenancy stack is meant to be driven.
"""
import asyncio
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import CompositionOfExperts, ExpertHandle, HashRouter
from repro.models import get_model
from repro.serving import (QuotaExceeded, Request, ServingEngine,
                           StreamingFrontend, TenantQuota)


@pytest.fixture(scope="module")
def cfg():
    return reduced(get_config("samba-coe-expert-7b"))


@pytest.fixture(scope="module")
def experts(cfg):
    m = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    return [jax.tree.map(np.asarray, m.init(jax.random.fold_in(rng, i)))
            for i in range(2)]


def mk_engine(cfg, experts, **kw):
    nbytes = sum(x.nbytes for x in jax.tree.leaves(experts[0]))
    coe = CompositionOfExperts(HashRouter(len(experts)), None,
                               int(2.5 * nbytes))
    for i, h in enumerate(experts):
        coe.register(ExpertHandle(f"e{i}", cfg, h))
    return ServingEngine(coe, cfg, max_len=48, n_slots=2, block_size=8,
                         prefix_sharing=True, kv_dtype=jnp.float32, **kw)


def prompt(cfg, seed, n=10):
    return np.random.RandomState(seed).randint(
        1, cfg.vocab_size, (n,)).astype(np.int32)


@pytest.mark.slow
def test_streamed_tokens_match_request_output(cfg, experts):
    """Every token observed through a TokenStream must equal the finished
    request's recorded output, in order."""
    fe = StreamingFrontend(mk_engine(cfg, experts))
    try:
        streams = [fe.submit(prompt(cfg, i), 4, tenant="t") for i in range(3)]
        assert fe.join(timeout=120)
        for s in streams:
            got = s.drain()
            assert got == [int(t) for t in s.request.output]
            assert len(got) == 4
    finally:
        fe.close()


@pytest.mark.slow
def test_quota_concurrency_and_rate(cfg, experts):
    """Over-concurrency and over-rate submits raise QuotaExceeded at the
    door (never reaching engine state) and are counted."""
    eng = mk_engine(cfg, experts)
    fe = StreamingFrontend(eng, quotas={
        "small": TenantQuota(max_concurrent=1),
        "slow": TenantQuota(max_concurrent=8, requests_per_s=0.001,
                            burst=1)})
    try:
        s1 = fe.submit(prompt(cfg, 0), 3, tenant="small")
        with pytest.raises(QuotaExceeded):
            fe.submit(prompt(cfg, 1), 3, tenant="small")
        s1.drain()                       # done -> concurrency slot returns
        s2 = fe.submit(prompt(cfg, 2), 3, tenant="small")
        s2.drain()

        fe.submit(prompt(cfg, 3), 3, tenant="slow").drain()   # bucket: 1
        with pytest.raises(QuotaExceeded):
            fe.submit(prompt(cfg, 4), 3, tenant="slow")       # bucket empty
        assert fe._m_rejected.value == 2
        assert fe.join(timeout=120)
    finally:
        fe.close()


@pytest.mark.slow
def test_priority_preempts_unadmitted_only(cfg, experts):
    """A high-priority submit pulls a LOWER-priority unadmitted request back
    out of the engine queue; requests already decoding are untouched."""
    eng = mk_engine(cfg, experts)
    fe = StreamingFrontend(eng, max_engine_queue=1)
    # park the pump thread so the engine queue stays observable, then
    # drive _feed_engine by hand
    fe.close()
    fe._closed = False
    lo = fe.submit(prompt(cfg, 0), 2, priority=0)
    fe._feed_engine()                    # lo lands in the engine queue
    assert [r.priority for r in eng.queue] == [0]
    hi = fe.submit(prompt(cfg, 1), 2, priority=5)
    fe._feed_engine()                    # queue full -> lo preempted out
    assert [r.priority for r in eng.queue] == [5]
    assert fe._m_preempt.value == 1
    # equal priority never preempts
    hi2 = fe.submit(prompt(cfg, 2), 2, priority=5)
    fe._feed_engine()
    assert fe._m_preempt.value == 1
    # restart the pump to finish everything off
    fe._thread = threading.Thread(target=fe._pump, daemon=True)
    fe._thread.start()
    for s in (lo, hi, hi2):
        assert len(s.drain()) == 2
    assert fe.join(timeout=120)
    fe.close()


@pytest.mark.slow
def test_tcp_roundtrip(cfg, experts):
    """JSON-lines TCP: tokens stream one line each, terminated by a done
    line whose output equals the streamed tokens."""
    fe = StreamingFrontend(mk_engine(cfg, experts))

    async def roundtrip():
        server = await fe.serve_tcp()
        host, port = server.sockets[0].getsockname()[:2]
        reader, writer = await asyncio.open_connection(host, port)
        writer.write(json.dumps({
            "tokens": [int(t) for t in prompt(cfg, 7)],
            "max_new_tokens": 3, "tenant": "net"}).encode() + b"\n")
        await writer.drain()
        toks, final = [], None
        while True:
            line = await asyncio.wait_for(reader.readline(), timeout=120)
            msg = json.loads(line)
            if "token" in msg:
                toks.append(msg["token"])
            else:
                final = msg
                break
        writer.close()
        server.close()
        await server.wait_closed()
        return toks, final

    try:
        toks, final = asyncio.run(roundtrip())
        assert final["done"] is True
        assert final["output"] == toks
        assert len(toks) == 3
    finally:
        fe.close()
