"""Capacity-tier expert store (repro.store) + async prefetch pipeline.

Covers: bit-exact round-trips through the host and mmap backends and
tolerance-bounded round-trip through the int8 backend (ISSUE-4 acceptance),
manifest persistence across store instances, and the HBMWeightCache
double-buffered prefetch pipeline — hit-under-prefetch, cancellation,
per-phase timing split, and the drop()/eviction dirty-writeback books.
"""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import CompositionOfExperts, ExpertHandle, HashRouter
from repro.core.switching import HBMWeightCache
from repro.models import get_model
from repro.store import (ExpertStore, HostMemoryStore, Int8BlockQuantizedStore,
                         MmapFileStore, make_store)


@pytest.fixture(scope="module")
def cfg():
    return reduced(get_config("samba-coe-expert-7b"))


@pytest.fixture(scope="module")
def params(cfg):
    """Real model params: the pytree shape every backend must survive."""
    return jax.tree.map(np.asarray, get_model(cfg).init(jax.random.PRNGKey(0)))


def _mixed_tree():
    rs = np.random.RandomState(7)
    return {"w": rs.randn(33, 17).astype(np.float32),
            "idx": np.arange(11, dtype=np.int32),          # non-float leaf
            "nested": {"b": rs.randn(5).astype(np.float32)},
            "lst": [np.float32(3.5), (rs.randn(2, 2).astype(np.float32),)]}


def _assert_trees_equal(a, b, exact=True, atol_fn=None):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.shape == y.shape and x.dtype == y.dtype
        if exact:
            np.testing.assert_array_equal(x, y)
        else:
            np.testing.assert_allclose(np.asarray(x, np.float64),
                                       np.asarray(y, np.float64),
                                       atol=atol_fn(x), rtol=0)


# ---------------------------------------------------------------- backends
def test_host_store_roundtrip_bit_exact(params):
    s = HostMemoryStore()
    s.put("e0", params)
    _assert_trees_equal(params, s.get("e0"))
    assert s.nbytes("e0") == s.stored_bytes("e0") > 0
    assert "e0" in s and s.keys() == ["e0"]
    s.delete("e0")
    assert "e0" not in s


def test_mmap_store_roundtrip_bit_exact(params, tmp_path):
    s = MmapFileStore(tmp_path)
    s.put("e0", params)
    s.put("mixed", _mixed_tree())
    _assert_trees_equal(params, s.get("e0"))
    _assert_trees_equal(_mixed_tree(), s.get("mixed"))
    # manifest + raw file survive a fresh store instance (real persistence)
    s2 = MmapFileStore(tmp_path)
    assert sorted(s2.keys()) == ["e0", "mixed"]
    assert s2.nbytes("e0") == s.nbytes("e0")
    _assert_trees_equal(params, s2.get("e0"))
    # containers come back with their python types
    back = s2.get("mixed")
    assert isinstance(back["lst"], list) and isinstance(back["lst"][1], tuple)
    s2.delete("mixed")
    assert not (tmp_path / "mixed.bin").exists()


def test_int8_store_roundtrip_within_block_tolerance(params):
    block = 64
    s = Int8BlockQuantizedStore(block)
    s.put("e0", params)

    def atol(x):
        # absmax block quantization: |err| <= blockmax/254 <= absmax/254,
        # plus one ulp of the storage dtype (bf16 params re-round on load)
        mx = float(np.abs(np.asarray(x, np.float64)).max())
        ulp = 2.0 ** -8 if np.asarray(x).dtype.name == "bfloat16" else 2e-7
        return mx * (1 / 254 + ulp) + 1e-12

    _assert_trees_equal(params, s.get("e0"), exact=False, atol_fn=atol)
    # ~2x effective DDR capacity: bf16 params compress ~1.9x (1 code byte
    # + 4/block scale bytes per element vs 2), fp32 params ~3.8x
    assert s.compression_ratio("e0") > 1.5
    assert s.stored_bytes("e0") < s.nbytes("e0")
    # non-float leaves pass through bit-exactly
    s.put("mixed", _mixed_tree())
    np.testing.assert_array_equal(s.get("mixed")["idx"],
                                  _mixed_tree()["idx"])


def test_make_store_specs(tmp_path):
    assert isinstance(make_store("host"), HostMemoryStore)
    assert isinstance(make_store(f"mmap:{tmp_path}"), MmapFileStore)
    assert isinstance(make_store("mmap", root=tmp_path), MmapFileStore)
    assert make_store("int8:32").block == 32
    with pytest.raises(ValueError):
        make_store("mmap")
    with pytest.raises(ValueError):
        make_store("zram")


# ------------------------------------------------------- prefetch pipeline
class _SlowStore(HostMemoryStore):
    """Host store with a deterministic read delay, to give the pipeline a
    window to overlap."""

    def __init__(self, delay_s=0.03):
        super().__init__()
        self.delay_s = delay_s

    def get(self, name):
        time.sleep(self.delay_s)
        return super().get(name)


def _mk_store(n=4, nbytes=4096):
    s = _SlowStore()
    for i in range(n):
        s.put(f"e{i}", {"w": np.full(nbytes // 4, float(i), np.float32)})
    return s, nbytes


def test_activate_consumes_prefetch_no_full_stall():
    s, nb = _mk_store()
    cache = HBMWeightCache(3 * nb, store=s)
    cache.activate("e0")                     # true miss: full load stalls
    assert cache.prefetch("e1") is True
    assert cache.prefetch("e1") is False     # already in flight
    deadline = time.time() + 2.0
    while not cache.ready("e1"):
        assert time.time() < deadline, "prefetch never landed"
        time.sleep(0.005)
    v = cache.activate("e1")                 # hit under prefetch: ~no stall
    st = cache.stats
    assert st.prefetch_hits == 1 and st.misses == 1 and st.hits == 1
    assert np.asarray(jax.tree.leaves(v)[0])[0] == 1.0
    # the landed prefetch stalls far less than the cold miss did: its store
    # read (>= delay_s) happened off the critical path
    assert st.stall_prefetch_seconds < s.delay_s / 2
    assert st.stall_miss_seconds >= s.delay_s * 0.9
    assert st.stall_prefetch_seconds < st.stall_miss_seconds
    assert st.store_read_seconds >= 2 * s.delay_s * 0.9   # both loads timed
    assert st.switch_seconds == pytest.approx(
        st.stall_miss_seconds + st.stall_prefetch_seconds)
    cache.close()


def test_prefetch_cancellation_discards_load():
    s, nb = _mk_store()
    cache = HBMWeightCache(3 * nb, store=s)
    cache.prefetch("e2")
    assert cache.cancel("e2") is True
    assert cache.cancel("e2") is False       # already cancelled
    assert not cache.resident("e2") and not cache.inflight("e2")
    assert cache.stats.prefetches_cancelled == 1
    # a later activate is a clean miss, not a stale consume
    cache.activate("e2")
    assert cache.stats.misses == 1
    cache.close()


def test_double_buffer_cancels_oldest_prediction():
    s, nb = _mk_store()
    cache = HBMWeightCache(4 * nb, store=s, max_inflight=2)
    cache.prefetch("e0")
    cache.prefetch("e1")
    cache.prefetch("e2")                     # pipe full: e0 is the stale one
    assert not cache.inflight("e0")
    assert cache.inflight("e1") and cache.inflight("e2")
    st = cache.stats
    assert st.prefetches_issued == 3 and st.prefetches_cancelled == 1
    cache.close()


class _FailOnceStore(HostMemoryStore):
    def __init__(self):
        super().__init__()
        self.fail_next = False

    def get(self, name):
        if self.fail_next:
            self.fail_next = False
            raise IOError("transient capacity-tier read failure")
        return super().get(name)


def test_failed_prefetch_falls_back_to_miss():
    s = _FailOnceStore()
    s.put("e0", {"w": np.zeros(1024, np.float32)})
    cache = HBMWeightCache(1 << 20, store=s)
    s.fail_next = True
    assert cache.prefetch("e0") is True
    deadline = time.time() + 2.0
    while cache.inflight("e0") and not cache._inflight["e0"].done():
        assert time.time() < deadline
        time.sleep(0.005)
    assert cache.ready("e0") is False        # dead future is not stall-free
    cache.activate("e0")                     # retries inline, store now works
    st = cache.stats
    assert st.misses == 1 and st.prefetch_hits == 0 and st.hits == 0
    assert cache.resident("e0")
    cache.close()


def test_prefetch_reservation_never_overcommits_capacity():
    s, nb = _mk_store()
    cache = HBMWeightCache(int(1.5 * nb), store=s)
    cache.activate("e0")
    # prefetching e1 must evict e0 from the books first — the reservation
    # plus residents can never exceed the tier
    assert cache.prefetch("e1") is True
    assert cache.used_bytes + sum(cache._reserved.values()) <= cache.capacity
    # a second prediction cannot fit next to the reservation: skipped
    assert cache.prefetch("e2") is False
    assert cache.stats.prefetches_issued == 1
    cache.activate("e1")
    assert cache.used_bytes <= cache.capacity and not cache._reserved
    cache.close()


def test_demand_miss_reclaims_stale_prefetch_reservation():
    """An expert that fits in HBM must activate even when a mispredicted
    in-flight prefetch has reserved most of the tier — demand outranks
    speculation (the stale prefetch is cancelled, not the miss failed)."""
    s = _SlowStore()
    s.put("small", {"w": np.zeros(128, np.float32)})     # 512 B
    s.put("big", {"w": np.zeros(1024, np.float32)})      # 4 KiB
    s.put("mid", {"w": np.zeros(768, np.float32)})       # 3 KiB
    cache = HBMWeightCache(5 * 1024, store=s)
    cache.activate("small")
    assert cache.prefetch("big") is True                 # reserves 4 KiB
    cache.activate("mid")       # 512 used + 4K reserved + 3K > 5K: reclaim
    st = cache.stats
    assert cache.resident("mid")
    assert st.prefetches_cancelled == 1 and not cache.inflight("big")
    assert cache.used_bytes + sum(cache._reserved.values()) <= cache.capacity
    cache.close()


def test_drop_writes_back_dirty_state_and_counts():
    s, nb = _mk_store()
    cache = HBMWeightCache(3 * nb, store=s)
    cache.activate("e0", read_only=False)
    cache.mark_dirty("e0")
    writes0 = s.stats.writes
    cache.drop("e0")
    st = cache.stats
    assert s.stats.writes == writes0 + 1     # dirty state reached the store
    assert st.drops == 1 and st.bytes_copied_back == nb
    _assert_trees_equal(s.get("e0"), {"w": np.full(nb // 4, 0.0, np.float32)})
    # read-only drop elides the copy-back but still keeps the books
    cache.activate("e1")
    elided0 = st.bytes_copyback_elided
    cache.drop("e1")
    assert st.drops == 2 and st.bytes_copyback_elided == elided0 + nb
    # dropping nothing is a no-op, not an error
    cache.drop("e3")
    assert st.drops == 2
    cache.close()


def test_eviction_writes_back_dirty_state():
    s, nb = _mk_store()
    cache = HBMWeightCache(int(1.5 * nb), store=s)   # one resident expert
    cache.activate("e0", read_only=False)
    cache.mark_dirty("e0")
    cache.activate("e1")                     # evicts dirty e0 -> writeback
    st = cache.stats
    assert st.evictions == 1 and st.bytes_copied_back == nb


# ------------------------------------------------- CoE over the store tiers
@pytest.mark.parametrize("backend", ["host", "mmap", "int8"])
def test_coe_generates_identically_across_backends(cfg, params, backend,
                                                   tmp_path):
    """The backend changes where bytes live, not what the CoE computes
    (int8 perturbs weights within tolerance -> same argmax tokens on this
    tiny config is NOT guaranteed, so int8 only asserts completion)."""
    store = make_store(backend, root=tmp_path / backend)
    nbytes = sum(x.nbytes for x in jax.tree.leaves(params))
    coe = CompositionOfExperts(HashRouter(2), None, int(2.5 * nbytes),
                               store=store)
    for i in range(2):
        coe.register(ExpertHandle(f"e{i}", cfg, params))
    toks = np.random.RandomState(0).randint(
        0, cfg.vocab_size, (2, 6)).astype(np.int32)
    res = coe.generate(toks, 3)
    assert res.tokens.shape == (2, 3)
    if backend != "int8":
        ref_coe = CompositionOfExperts(HashRouter(2), None, int(2.5 * nbytes))
        for i in range(2):
            ref_coe.register(ExpertHandle(f"e{i}", cfg, params))
        assert (res.tokens == ref_coe.generate(toks, 3).tokens).all()
        ref_coe.cache.close()
    # registering from a pre-populated store (no host params) works too
    coe2 = CompositionOfExperts(HashRouter(2), None, int(2.5 * nbytes),
                                store=store)
    coe2.register(ExpertHandle("e0", cfg))
    assert coe2.experts["e0"].nbytes == nbytes
    assert coe2.memory_contract("e0")["hbm_bytes"] == nbytes
    coe.cache.close()
    coe2.cache.close()


def test_register_unknown_expert_without_params_raises(cfg):
    coe = CompositionOfExperts(HashRouter(2), None, 1 << 20)
    with pytest.raises(KeyError):
        coe.register(ExpertHandle("ghost", cfg))
