"""Substrate tests: data pipeline determinism/resume, checkpoint atomic
roundtrip + elastic restore, optimizer, serving engine, layers properties."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced
from repro.data import DataConfig, SyntheticLM, make_source
from repro.models import get_model, layers as L
from repro.optim import AdamWConfig, adamw_update, init_opt_state, lr_schedule


# ---------------------------------------------------------------- data
def test_data_resume_exact():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=4, seed=7)
    src = SyntheticLM(cfg)
    a = src.batch_at(123)
    b = src.batch_at(123)
    assert (a["tokens"] == b["tokens"]).all()
    c = src.batch_at(124)
    assert not (a["tokens"] == c["tokens"]).all()
    # targets are next-token shifted
    full = SyntheticLM(cfg)
    d = full.batch_at(5)
    assert (d["tokens"][:, 1:] == d["targets"][:, :-1]).all()


def test_data_sharding_partitions_batch():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=8)
    src = SyntheticLM(cfg)
    full = src.batch_at(0)["tokens"]
    parts = [src.shard_at(0, i, 4)["tokens"] for i in range(4)]
    assert (np.concatenate(parts) == full).all()


def test_memmap_source(tmp_path):
    toks = np.arange(10000, dtype=np.int32)
    path = tmp_path / "corpus.bin"
    toks.tofile(path)
    cfg = DataConfig(vocab_size=10000, seq_len=16, global_batch=4,
                     corpus_path=str(path))
    src = make_source(cfg)
    b = src.batch_at(3)
    assert b["tokens"].shape == (4, 16)
    assert (b["tokens"][:, 1:] == b["targets"][:, :-1]).all()


# ---------------------------------------------------------------- ckpt
def test_checkpoint_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"step": jnp.int32(5)}}
    for s in (1, 2, 3):
        mgr.save(s, state)
    assert mgr.latest_step() == 3
    assert len(list(tmp_path.glob("step-*"))) == 2   # retention
    restored, manifest = mgr.restore(3, state)
    assert manifest["step"] == 3
    np.testing.assert_array_equal(restored["params"]["w"],
                                  state["params"]["w"])


def test_checkpoint_elastic_restore_different_sharding(tmp_path):
    """Save unsharded, restore onto an explicit (1,1) mesh sharding —
    the mesh-elastic path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import single_device_mesh
    mgr = CheckpointManager(tmp_path)
    state = {"w": jnp.ones((4, 4))}
    mgr.save(1, state)
    mesh = single_device_mesh()
    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    restored, _ = mgr.restore(1, state, sh)
    assert restored["w"].sharding == sh["w"]


def test_checkpoint_crash_safety(tmp_path):
    """A stale staging dir never corrupts restore."""
    mgr = CheckpointManager(tmp_path)
    (tmp_path / ".tmp-9-999").mkdir()
    state = {"w": jnp.zeros(3)}
    mgr.save(1, state)
    assert mgr.latest_step() == 1


# ---------------------------------------------------------------- optim
def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, decay_steps=100,
                      weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = init_opt_state(params)
    for _ in range(50):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(cfg, params, grads, opt)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_lr_schedule_shapes():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, decay_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in
           (0, 5, 10, 50, 100, 200)]
    assert lrs[0] < lrs[2]                   # warmup rises
    assert lrs[2] >= lrs[3] >= lrs[4]        # cosine decays
    assert lrs[-1] >= 0.1 - 1e-6             # floor


# ---------------------------------------------------------------- layers
@given(st.integers(2, 6), st.integers(2, 64))
@settings(max_examples=20, deadline=None)
def test_rmsnorm_scale_invariance(b, d):
    x = jnp.asarray(np.random.RandomState(b * d).randn(b, d), jnp.float32)
    y1 = L.rms_norm(x, jnp.ones(d))
    y2 = L.rms_norm(3.0 * x, jnp.ones(d))
    np.testing.assert_allclose(y1, y2, atol=1e-4)


@given(st.integers(1, 3), st.sampled_from([16, 32, 64]))
@settings(max_examples=15, deadline=None)
def test_rope_preserves_norm_and_relative_phase(b, dh):
    cfg = dataclasses.replace(get_config("granite-8b"), head_dim=dh)
    x = jnp.asarray(np.random.RandomState(dh).randn(b, 4, 2, dh), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(4)[None], (b, 4))
    y = L.apply_rope(cfg, x, pos)
    np.testing.assert_allclose(
        jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1),
        rtol=1e-4, atol=1e-4)
    # relative property: <rope(q,m), rope(k,n)> depends only on m-n
    q = jnp.asarray(np.random.RandomState(1).randn(1, 1, 1, dh), jnp.float32)
    k = jnp.asarray(np.random.RandomState(2).randn(1, 1, 1, dh), jnp.float32)
    def dot_at(m, n):
        qm = L.apply_rope(cfg, q, jnp.full((1, 1), m))
        kn = L.apply_rope(cfg, k, jnp.full((1, 1), n))
        return float(jnp.sum(qm * kn))
    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-3


def test_block_attention_equals_naive_long():
    rng = jax.random.PRNGKey(3)
    ks = jax.random.split(rng, 3)
    B, S, Hq, Hkv, dh = 1, 512, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, Hq, dh))
    k = jax.random.normal(ks[1], (B, S, Hkv, dh))
    v = jax.random.normal(ks[2], (B, S, Hkv, dh))
    for window in (0, 100):
        a = L.block_attention(q, k, v, window=window, block=128)
        b = L.naive_attention(q, k, v, window=window)
        np.testing.assert_allclose(a, b, atol=2e-5, rtol=1e-4)


@given(st.integers(2, 5), st.integers(1, 3))
@settings(max_examples=10, deadline=None)
def test_moe_routing_weights_sum(b, s):
    """Top-k combine weights (after renorm) sum to ~1 per token (mixtral)."""
    cfg = dataclasses.replace(reduced(get_config("mixtral-8x7b")),
                              capacity_factor=16.0)
    x = jnp.asarray(np.random.RandomState(b).randn(b, s, cfg.d_model),
                    jnp.float32)
    from repro.models.layers import moe_specs
    from repro.models.common import init_params
    p = init_params(jax.random.PRNGKey(0), moe_specs(cfg))
    out = L.moe_apply(cfg, p, x.astype(jnp.bfloat16))
    assert out.shape == x.shape
    assert not bool(jnp.isnan(out.astype(jnp.float32)).any())


def test_mlstm_chunkwise_matches_step():
    """Chunkwise-parallel mLSTM == sequential step recurrence."""
    from repro.models.xlstm import mlstm_chunkwise, mlstm_step
    rng = jax.random.PRNGKey(0)
    B, S, H, dh = 2, 32, 2, 16
    ks = jax.random.split(rng, 5)
    q = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, H, dh))
    v = jax.random.normal(ks[2], (B, S, H, dh))
    il = jax.random.normal(ks[3], (B, S, H)) * 0.5
    fl = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, S, H)))
    hc, st_c = mlstm_chunkwise(q, k, v, il, fl, chunk=8)
    state = {"C": jnp.zeros((B, H, dh, dh)), "n": jnp.zeros((B, H, dh))}
    outs = []
    for t in range(S):
        h, state = mlstm_step(q[:, t], k[:, t], v[:, t], il[:, t], fl[:, t],
                              state)
        outs.append(h)
    hs = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(hc, hs, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(st_c["C"], state["C"], atol=1e-4, rtol=1e-3)


def test_rglru_scan_matches_step():
    from repro.models.rglru import rec_block, _rec_specs
    from repro.models.common import init_params
    cfg = dataclasses.replace(reduced(get_config("recurrentgemma-9b")))
    p = init_params(jax.random.PRNGKey(0), _rec_specs(cfg))
    B, S = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                          jnp.bfloat16)
    y_par, st_par = rec_block(cfg, p, x)                # associative scan
    st = {"h": jnp.zeros((B, cfg.d_rnn), jnp.float32),
          "conv": jnp.zeros((B, cfg.conv_width - 1, cfg.d_rnn), jnp.bfloat16)}
    ys = []
    for t in range(S):
        y, st = rec_block(cfg, p, x[:, t:t + 1], st)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_par.astype(np.float32),
                               y_seq.astype(np.float32), atol=3e-2, rtol=3e-2)
    np.testing.assert_allclose(st_par["h"], st["h"], atol=1e-3, rtol=1e-2)


# ---------------------------------------------------------------- serving
def test_serving_engine_end_to_end(rng):
    from repro.core import CompositionOfExperts, ExpertHandle, HashRouter
    from repro.serving import Request, ServingEngine
    cfg = reduced(get_config("samba-coe-expert-7b"))
    m = get_model(cfg)
    experts = [jax.tree.map(np.asarray, m.init(jax.random.fold_in(rng, i)))
               for i in range(2)]
    nbytes = sum(x.nbytes for x in jax.tree.leaves(experts[0]))
    coe = CompositionOfExperts(HashRouter(2), None, 3 * nbytes)
    for i, h in enumerate(experts):
        coe.register(ExpertHandle(f"e{i}", cfg, h))
    eng = ServingEngine(coe, cfg, max_len=24, n_slots=4, block_size=8)
    rs = np.random.RandomState(0)
    for i in range(5):
        eng.submit(Request(rid=i, tokens=rs.randint(
            0, cfg.vocab_size, (16,)).astype(np.int32), max_new_tokens=4))
    done = eng.drain()
    assert len(done) == 5
    assert all(r.output.shape == (4,) for r in done)
    assert eng.stats.tokens_out == 20
    assert eng.stats.exec_s > 0
    assert eng.pool.stats.blocks_in_use == 0     # every slot recycled


def test_grad_accumulation_matches_full_batch(rng):
    """accum_steps=2 must produce (numerically close) identical updates to
    the full-batch step — f32 accumulation, mean-reduced loss."""
    from repro.launch.mesh import single_device_mesh
    from repro.distributed import stepfn
    from repro.optim import init_opt_state
    cfg = reduced(get_config("granite-8b"))
    mesh = single_device_mesh()
    with mesh:
        m = get_model(cfg)
        params = m.init(rng)
        toks = jax.random.randint(jax.random.fold_in(rng, 1), (4, 33), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        f1, sh, _ = stepfn.make_train_step(cfg, mesh)
        f2, _, _ = stepfn.make_train_step(cfg, mesh, accum_steps=2)
        # independent buffer copies: the train step donates its input state
        host = jax.tree.map(lambda x: np.asarray(x), params)
        s0 = jax.device_put({"params": jax.tree.map(jnp.asarray, host),
                             "opt": init_opt_state(params)}, sh)
        s1 = jax.device_put({"params": jax.tree.map(jnp.asarray, host),
                             "opt": init_opt_state(params)}, sh)
        s0, m0 = f1(s0, batch)
        s1, m1 = f2(s1, batch)
    assert abs(float(m0["loss"]) - float(m1["loss"])) < 0.05
    w0 = jax.tree.leaves(s0["params"])[0].astype(jnp.float32)
    w1 = jax.tree.leaves(s1["params"])[0].astype(jnp.float32)
    assert float(jnp.max(jnp.abs(w0 - w1))) < 2e-2


def test_async_checkpoint(tmp_path):
    mgr = CheckpointManager(tmp_path)
    state = {"w": jnp.arange(8.0), "s": jnp.int32(3)}
    mgr.save_async(1, state)
    mgr.wait()
    restored, _ = mgr.restore(1, state)
    np.testing.assert_array_equal(restored["w"], state["w"])


# ---------------------------------------------------------------- paged kv
def test_paged_kv_cache_roundtrip_and_reuse(rng):
    from repro.serving.kvcache import PagedKVCache
    L, H, dh, blk = 2, 2, 8, 4
    pool = PagedKVCache(n_blocks=6, block_size=blk, n_layers=L,
                        kv_heads=H, head_dim=dh, dtype=jnp.float32)
    ks = jax.random.split(rng, 4)
    ka = jax.random.normal(ks[0], (L, 6, H, dh))
    va = jax.random.normal(ks[1], (L, 6, H, dh))
    kb = jax.random.normal(ks[2], (L, 9, H, dh))
    vb = jax.random.normal(ks[3], (L, 9, H, dh))
    pool.open(1); pool.open(2)
    # interleaved appends across requests
    pool.append(1, ka[:, :4], va[:, :4])
    pool.append(2, kb[:, :5], vb[:, :5])
    pool.append(1, ka[:, 4:], va[:, 4:])
    pool.append(2, kb[:, 5:], vb[:, 5:])
    k1, v1 = pool.gather(1)
    k2, v2 = pool.gather(2)
    np.testing.assert_allclose(k1, ka, atol=0)
    np.testing.assert_allclose(v2, vb, atol=0)
    assert pool.stats.blocks_in_use == 2 + 3
    # free and reuse without fragmentation
    pool.free(1)
    pool.open(3)
    pool.append(3, kb[:, :8], vb[:, :8])     # needs 2 blocks, reuses freed
    k3, _ = pool.gather(3)
    np.testing.assert_allclose(k3, kb[:, :8], atol=0)
    assert pool.stats.blocks_in_use == 3 + 2


def test_paged_kv_cache_exhaustion(rng):
    from repro.serving.kvcache import PagedKVCache
    pool = PagedKVCache(n_blocks=2, block_size=2, n_layers=1, kv_heads=1,
                        head_dim=4, dtype=jnp.float32)
    pool.open(1)
    k = jnp.ones((1, 4, 1, 4))
    pool.append(1, k, k)                      # uses both blocks
    pool.open(2)
    import pytest as _pt
    with _pt.raises(MemoryError):
        pool.append(2, k[:, :1], k[:, :1])
