"""Speculative decoding (paper §VI-B): greedy draft-verify must produce
token-for-token identical output to the target's own greedy decode."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import get_model
from repro.serving.speculative import SpeculativeDecoder


def _greedy_ref(m, params, prompt, n):
    B, S = prompt.shape
    last, cache = m.prefill(params, {"tokens": jnp.asarray(prompt)}, S + n + 8)
    tok = jnp.argmax(last, -1).astype(jnp.int32)
    out = [np.asarray(tok)]
    for t in range(n - 1):
        lg, cache = m.decode_step(params, cache, tok[:, None], jnp.int32(S + t))
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        out.append(np.asarray(tok))
    return np.stack(out, 1)


def test_speculative_equals_greedy(rng):
    t_cfg = reduced(get_config("granite-8b"))
    d_cfg = dataclasses.replace(t_cfg, n_layers=2, d_ff=128)
    t_m = get_model(t_cfg)
    t_p = t_m.init(rng)
    d_p = get_model(d_cfg).init(jax.random.fold_in(rng, 7))
    prompt = np.random.RandomState(0).randint(
        0, t_cfg.vocab_size, (2, 16)).astype(np.int32)
    ref = _greedy_ref(t_m, t_p, prompt, 10)
    sd = SpeculativeDecoder(t_cfg, d_cfg, gamma=3)
    out = sd.generate(t_p, d_p, prompt, 10)
    assert (out == ref).all()


def test_speculative_self_draft_full_acceptance(rng):
    """Draft == target: every proposal must be accepted, output identical."""
    cfg = reduced(get_config("granite-8b"))
    m = get_model(cfg)
    p = m.init(rng)
    prompt = np.random.RandomState(1).randint(
        0, cfg.vocab_size, (1, 12)).astype(np.int32)
    ref = _greedy_ref(m, p, prompt, 9)
    sd = SpeculativeDecoder(cfg, cfg, gamma=4)
    out = sd.generate(p, p, prompt, 9)
    assert (out == ref).all()
    assert sd.stats.acceptance_rate == 1.0
    assert sd.stats.tokens_per_target_call > 2.0   # the paper's speedup lever
