"""Continuous-batching serving engine over the paged KV pool (ISSUE 3).

Covers: block churn (no leaks), slot-recycling decode correctness vs the
dense-cache reference, expert-aware admission fairness (no starvation),
speculative decode policy equivalence, run-to-completion baseline, and the
HBM weights-vs-KV budget threading.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import (CompositionOfExperts, ExpertHandle, HBMBudget,
                        HashRouter, plan_hbm_budget)
from repro.models import get_model
from repro.serving import (GreedyDecode, PagedKVCache, Request, ServingEngine,
                           SpeculativeDecode)


class FirstTokenRouter:
    """Deterministic test router: expert = first prompt token % n."""

    def __init__(self, n_experts):
        self.n_experts = n_experts

    def route(self, params, tokens):
        return jnp.asarray(np.asarray(tokens)[:, 0] % self.n_experts)


@pytest.fixture(scope="module")
def cfg():
    return reduced(get_config("samba-coe-expert-7b"))


@pytest.fixture(scope="module")
def experts(cfg):
    m = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    return [jax.tree.map(np.asarray, m.init(jax.random.fold_in(rng, i)))
            for i in range(3)]


def _mk_coe(cfg, experts, capacity_experts=2.5, router=None, **kw):
    nbytes = sum(x.nbytes for x in jax.tree.leaves(experts[0]))
    coe = CompositionOfExperts(router or HashRouter(len(experts)), None,
                               int(capacity_experts * nbytes), **kw)
    for i, h in enumerate(experts):
        coe.register(ExpertHandle(f"e{i}", cfg, h))
    return coe


def _greedy_ref(cfg, params, prompt, n):
    """Dense-cache greedy decode — the correctness oracle."""
    m = get_model(cfg)
    B, S = prompt.shape
    last, cache = m.prefill(params, {"tokens": jnp.asarray(prompt)}, S + n + 2)
    tok = jnp.argmax(last, -1).astype(jnp.int32)
    out = [np.asarray(tok)]
    for t in range(n - 1):
        lg, cache = m.decode_step(params, cache, tok[:, None], jnp.int32(S + t))
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        out.append(np.asarray(tok))
    return np.stack(out, 1)[0]


def _check_outputs(cfg, coe, experts, done):
    names = coe.expert_names()
    for r in done:
        ref = _greedy_ref(cfg, experts[names.index(r.expert)],
                          r.tokens[None], r.max_new_tokens)
        assert (r.output == ref).all(), f"rid {r.rid} diverged from dense ref"


# ---------------------------------------------------------------- churn
def test_paged_pool_churn_no_leaked_blocks(cfg, experts):
    """Staggered admissions/completions with mixed lengths: after drain the
    pool must be fully recycled (alloc count == free count, zero in use)."""
    coe = _mk_coe(cfg, experts)
    eng = ServingEngine(coe, cfg, max_len=32, n_slots=3, block_size=8)
    rs = np.random.RandomState(0)
    done = []
    rid = 0
    for wave in range(3):                    # submit-while-decoding churn
        for _ in range(3):
            eng.submit(Request(rid=rid, tokens=rs.randint(
                0, cfg.vocab_size, (6 + 2 * (rid % 4),)).astype(np.int32),
                max_new_tokens=2 + rid % 5))
            rid += 1
        done.extend(eng.step())
        done.extend(eng.step())
    done.extend(eng.drain())
    assert len(done) == rid
    st = eng.pool.stats
    assert st.blocks_in_use == 0
    assert st.allocs == st.frees
    assert st.peak_blocks > 0
    _check_outputs(cfg, coe, experts, done)


# ------------------------------------------------------- slot recycling
def test_slot_recycling_preserves_decode_correctness(cfg, experts):
    """More requests than slots with mixed decode lengths: recycled slots
    (and their recycled blocks) must not perturb surviving requests."""
    coe = _mk_coe(cfg, experts)
    eng = ServingEngine(coe, cfg, max_len=32, n_slots=2, block_size=8)
    rs = np.random.RandomState(1)
    n = 6
    for i in range(n):
        eng.submit(Request(rid=i, tokens=rs.randint(
            0, cfg.vocab_size, (10,)).astype(np.int32),
            max_new_tokens=3 + 2 * (i % 3)))
    done = eng.drain()
    assert len(done) == n
    assert eng.stats.admitted == n
    assert eng.pool.stats.blocks_in_use == 0
    _check_outputs(cfg, coe, experts, done)


def test_kv_backpressure_tiny_pool_still_completes(cfg, experts):
    """Pool smaller than total demand: admission backpressure serializes
    requests instead of exhausting the pool."""
    coe = _mk_coe(cfg, experts, capacity_experts=3.5)
    blk = PagedKVCache.block_bytes(8, cfg.n_layers, cfg.n_kv_heads,
                                   cfg.head_dim)
    eng = ServingEngine(coe, cfg, max_len=24, n_slots=4, block_size=8,
                        kv_budget_bytes=3 * blk)     # 3 blocks = 1 request
    rs = np.random.RandomState(2)
    for i in range(4):
        eng.submit(Request(rid=i, tokens=rs.randint(
            0, cfg.vocab_size, (8,)).astype(np.int32), max_new_tokens=4))
    done = eng.drain()
    assert len(done) == 4
    assert eng.pool.stats.peak_blocks <= 3
    assert eng.pool.stats.blocks_in_use == 0
    _check_outputs(cfg, coe, experts, done)


# ------------------------------------------------------------- fairness
def test_expert_aware_admission_never_starves(cfg, experts):
    """A lone request for a non-resident expert must complete even while
    resident-expert traffic keeps every slot busy (aging override)."""
    # capacity ~1 expert: whichever expert is active is the only resident one
    coe = _mk_coe(cfg, experts[:2], capacity_experts=1.5,
                  router=FirstTokenRouter(2))
    eng = ServingEngine(coe, cfg, max_len=32, n_slots=2, block_size=8,
                        starvation_limit=3, switch_quantum=4)
    rs = np.random.RandomState(3)

    def prompt(expert):                      # first token selects the expert
        p = rs.randint(0, cfg.vocab_size, (8,)).astype(np.int32)
        p[0] = p[0] - (p[0] % 2) + expert
        return p

    for i in range(6):
        eng.submit(Request(rid=i, tokens=prompt(0), max_new_tokens=4))
    eng.submit(Request(rid=99, tokens=prompt(1), max_new_tokens=4))
    for i in range(6, 10):
        eng.submit(Request(rid=i, tokens=prompt(0), max_new_tokens=4))
    done = eng.drain()
    assert len(done) == 11
    lone = next(r for r in done if r.rid == 99)
    assert lone.expert == "e1"
    assert lone.done_s is not None
    assert eng.pool.stats.blocks_in_use == 0
    _check_outputs(cfg, coe, experts, done)


# ------------------------------------------------------------- policies
def test_speculative_policy_matches_greedy_engine(cfg, experts):
    """Spec-decode on the paged slot machinery == greedy engine output;
    self-draft must accept every proposal (paper §VI-B invariant)."""
    rs = np.random.RandomState(4)
    prompts = [rs.randint(0, cfg.vocab_size, (9,)).astype(np.int32)
               for _ in range(4)]

    def run(policy):
        coe = _mk_coe(cfg, experts[:2])
        eng = ServingEngine(coe, cfg, max_len=32, n_slots=2, block_size=8,
                            policy=policy)
        for i, p in enumerate(prompts):
            # rid 0 completes at prefill (max_new=1): the on_admit/on_free
            # ordering regression for policies with per-request state
            eng.submit(Request(rid=i, tokens=p,
                               max_new_tokens=1 if i == 0 else 6))
        done = eng.drain()
        assert eng.pool.stats.blocks_in_use == 0
        return {r.rid: r.output for r in done}, eng

    greedy, _ = run(None)

    d_cfg = dataclasses.replace(cfg, n_layers=2, d_ff=128)
    d_host = jax.tree.map(np.asarray,
                          get_model(d_cfg).init(jax.random.PRNGKey(7)))
    spec, s_eng = run(SpeculativeDecode(d_cfg, d_host, gamma=3))
    assert all((greedy[i] == spec[i]).all() for i in greedy)
    assert s_eng.policy.d_pool.stats.blocks_in_use == 0

    selfdraft, sd_eng = run(SpeculativeDecode(cfg, experts[0], gamma=3))
    assert all((greedy[i] == selfdraft[i]).all() for i in greedy)
    # self-draft rows served by expert e0 accept everything; overall rate
    # is high because e0 serves part of the traffic
    assert sd_eng.policy.stats.accepted > 0


def test_run_to_completion_baseline_matches_continuous(cfg, experts):
    rs = np.random.RandomState(5)
    prompts = [rs.randint(0, cfg.vocab_size, (8,)).astype(np.int32)
               for _ in range(5)]

    def run(scheduler):
        coe = _mk_coe(cfg, experts)
        eng = ServingEngine(coe, cfg, max_len=24, n_slots=2, block_size=8,
                            scheduler=scheduler)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, tokens=p, max_new_tokens=4))
        done = eng.drain()
        assert eng.pool.stats.blocks_in_use == 0
        return {r.rid: r.output for r in done}

    cont = run("continuous")
    rtc = run("run_to_completion")
    assert all((cont[i] == rtc[i]).all() for i in cont)


# ------------------------------------------------------------ hbm budget
def test_hbm_budget_split_and_coe_threading():
    budget = plan_hbm_budget(100_000, expert_bytes=20_000, block_bytes=1_000,
                             kv_fraction=0.3)
    assert budget.weights_bytes + budget.kv_bytes == budget.total_bytes
    assert budget.kv_bytes == 30_000
    assert budget.resident_experts(20_000) == 3
    assert budget.kv_blocks(1_000) == 30

    # weight share never drops below min_resident_experts
    tight = plan_hbm_budget(45_000, expert_bytes=20_000, block_bytes=1_000,
                            kv_fraction=0.9)
    assert tight.weights_bytes >= 2 * 20_000

    with pytest.raises(MemoryError):
        plan_hbm_budget(10_000, expert_bytes=20_000, block_bytes=1_000)


def test_kv_reserve_shrinks_weight_cache(cfg, experts):
    nbytes = sum(x.nbytes for x in jax.tree.leaves(experts[0]))
    full = _mk_coe(cfg, experts, capacity_experts=3.0)
    carved = _mk_coe(cfg, experts, capacity_experts=3.0,
                     kv_reserve_bytes=int(1.5 * nbytes))
    assert full.cache.capacity == full.hbm_budget.total_bytes
    assert carved.cache.capacity == carved.hbm_budget.weights_bytes
    # the carve-out halves how many experts stay resident
    assert carved.hbm_budget.resident_experts(nbytes) == 1
    assert full.hbm_budget.resident_experts(nbytes) == 3
    # the engine sizes its pool from the reserved share by default
    eng = ServingEngine(carved, cfg, max_len=24, n_slots=2, block_size=8)
    assert eng.pool.capacity_bytes() <= carved.hbm_budget.kv_bytes
    with pytest.raises(ValueError):
        CompositionOfExperts(HashRouter(2), None, 100, kv_reserve_bytes=100)
