"""Request-lifecycle plane (ISSUE 10): phase ledger, SLO/goodput, watchdog,
flight recorder.

Covers: the telescoping phase decomposition (sum of phases == wall time,
exactly), hand-computed SLO attainment / goodput / burn-rate math, the
watchdog's fault-injection checks (stuck request, leaked KV block — and
silence on a clean drain), flight-recorder ring overflow + postmortem
bundle schema round-trip, trace-ring drop accounting, the ``/readyz`` and
``/debug/*`` HTTP endpoints, the SIGUSR2 dump handler naming the stuck
slot, and ``check_bench --update-baseline``.
"""
import json
import signal
import subprocess
import sys
import time
import urllib.error
import urllib.request
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import CompositionOfExperts, ExpertHandle, HashRouter
from repro.models import get_model
from repro.obs import flightrec, trace
from repro.obs.flightrec import FlightRecorder, validate_bundle
from repro.obs.httpd import serve_metrics
from repro.obs.lifecycle import PHASES, phase_record
from repro.obs.metrics import MetricsRegistry
from repro.obs.slo import SLOTracker, request_slo_met
from repro.obs.trace import Tracer
from repro.obs.watchdog import Watchdog, WatchdogError
from repro.serving import Request, ServingEngine

REPO = Path(__file__).resolve().parent.parent


@pytest.fixture(scope="module")
def cfg():
    return reduced(get_config("samba-coe-expert-7b"))


def _mk_engine(cfg, n_experts=2, **kw):
    m = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    experts = [jax.tree.map(np.asarray, m.init(jax.random.fold_in(rng, i)))
               for i in range(n_experts)]
    nbytes = sum(x.nbytes for x in jax.tree.leaves(experts[0]))
    coe = CompositionOfExperts(HashRouter(n_experts), None,
                               int(2.5 * nbytes))
    for i, h in enumerate(experts):
        coe.register(ExpertHandle(f"e{i}", cfg, h))
    return ServingEngine(coe, cfg, max_len=32, n_slots=2, block_size=8, **kw)


def _mk_requests(cfg, n, new_tokens=4):
    rs = np.random.RandomState(0)
    return [Request(rid=i,
                    tokens=rs.randint(0, cfg.vocab_size, (8,)).astype(np.int32),
                    max_new_tokens=new_tokens)
            for i in range(n)]


# ----------------------------------------------------------------------
# phase ledger
# ----------------------------------------------------------------------
def test_phase_record_hand_computed():
    r = Request(rid=7, tokens=np.zeros(4, np.int32), max_new_tokens=5,
                tenant="acme", priority=2)
    r.arrival_s, r.submit_s, r.admit_s = 10.0, 10.5, 11.0
    r.route_s = 0.1
    r.first_token_s, r.done_s = 11.4, 12.4
    r.output = np.arange(5, dtype=np.int32)
    rec = phase_record(r)
    ph = rec["phases"]
    assert ph["queue_wait"] == pytest.approx(0.5)
    assert ph["route"] == pytest.approx(0.1)
    assert ph["admit_wait"] == pytest.approx(0.4)
    assert ph["prefill"] == pytest.approx(0.4)
    assert ph["decode"] == pytest.approx(1.0)
    assert rec["wall_s"] == pytest.approx(2.4)
    assert rec["ttft_s"] == pytest.approx(1.4)
    assert rec["tpot_s"] == pytest.approx(1.0 / 4)
    assert rec["tenant"] == "acme" and rec["priority"] == 2


def test_phase_decomposition_sums_to_wall(cfg):
    reg = MetricsRegistry()
    eng = _mk_engine(cfg, registry=reg)
    for r in _mk_requests(cfg, 5):
        eng.submit(r)
    done = eng.drain()
    assert len(done) == 5
    recs = eng.lifecycle.records()
    assert len(recs) == 5
    for rec in recs:
        total = sum(rec["phases"][p] for p in PHASES)
        # telescoping identity: exact up to float rounding
        assert total == pytest.approx(rec["wall_s"], abs=1e-9)
        for p in PHASES:
            assert rec["phases"][p] >= -1e-9, (p, rec["phases"][p])
    # phases landed in the labeled histograms and tpot_s got observed
    snap = reg.snapshot()
    assert snap["serve.phase_seconds:count{phase=decode}"] == 5
    assert snap["serve.phase_seconds:count{phase=queue_wait}"] == 5
    assert snap["serve.tpot_s:count"] == 5          # 4 new tokens each
    assert snap["serve.ttft_s:count"] == 5


# ----------------------------------------------------------------------
# SLO attainment / goodput / burn rate
# ----------------------------------------------------------------------
def _finished(rid, *, tenant="a", ttft=0.1, tpot=0.01, n_out=5,
              slo_ttft=None, slo_tpot=None):
    r = Request(rid=rid, tokens=np.zeros(4, np.int32), max_new_tokens=n_out,
                tenant=tenant, slo_ttft_s=slo_ttft, slo_tpot_s=slo_tpot)
    r.arrival_s = 0.0
    r.first_token_s = ttft
    r.done_s = ttft + tpot * (n_out - 1)
    r.output = np.arange(n_out, dtype=np.int32)
    return r


def test_slo_goodput_hand_computed():
    reg = MetricsRegistry()
    t = {"now": 100.0}
    tr = SLOTracker(reg, target_attainment=0.9, windows=(60.0,),
                    clock=lambda: t["now"])
    t["now"] = 105.0
    # tenant a: two met, one TTFT miss — all 5 output tokens each
    assert tr.observe(_finished(1, slo_ttft=1.0, slo_tpot=0.5))
    assert tr.observe(_finished(2, slo_ttft=1.0))
    assert not tr.observe(_finished(3, ttft=2.0, slo_ttft=1.0))
    # tenant b: no declared SLO -> vacuously met
    assert tr.observe(_finished(4, tenant="b"))
    t["now"] = 110.0                       # 10s since construction
    assert tr.attainment("a") == pytest.approx(2 / 3)
    assert tr.attainment() == pytest.approx(3 / 4)
    assert tr.goodput("a") == pytest.approx(10 / 10.0)   # met tokens / wall
    assert tr.goodput("a", wall_s=5.0) == pytest.approx(2.0)
    # burn rate: 1 miss / 3 requests over the window, budget 0.1
    assert tr.burn_rate(60.0, "a") == pytest.approx((1 / 3) / 0.1)
    assert tr.burn_rate(60.0, "b") == 0.0
    snap = reg.snapshot()
    assert snap["slo.requests{priority=0,tenant=a}"] == 3
    assert snap["slo.requests_met{priority=0,tenant=a}"] == 2
    assert snap["slo.ttft_miss{priority=0,tenant=a}"] == 1
    assert snap["slo.tokens_met{priority=0,tenant=a}"] == 10
    assert snap["slo.burn_rate{tenant=a,window=60}"] == \
        pytest.approx((1 / 3) / 0.1)
    d = tr.as_dict("a")
    assert d["requests"] == 3 and d["tokens_out"] == 15
    assert tr.tenants() == ["a", "b"]


def test_request_slo_met_semantics():
    assert request_slo_met(_finished(1))                       # no SLO
    assert request_slo_met(_finished(2, slo_ttft=1.0, slo_tpot=0.5))
    assert not request_slo_met(_finished(3, ttft=2.0, slo_ttft=1.0))
    assert not request_slo_met(_finished(4, tpot=1.0, slo_tpot=0.5))


def test_engine_drain_feeds_slo_tracker(cfg):
    reg = MetricsRegistry()
    eng = _mk_engine(cfg, registry=reg)
    reqs = _mk_requests(cfg, 4)
    for r in reqs:
        r.slo_ttft_s, r.slo_tpot_s = 60.0, 60.0    # unmissable on CI
        eng.submit(r)
    eng.drain()
    assert eng.slo.attainment() == 1.0
    assert eng.slo.goodput() > 0.0
    assert reg.snapshot()["slo.requests_met{priority=0,tenant=default}"] == 4


# ----------------------------------------------------------------------
# watchdog fault injection
# ----------------------------------------------------------------------
def test_watchdog_silent_on_clean_drain(cfg):
    reg = MetricsRegistry()
    eng = _mk_engine(cfg, registry=reg)
    for r in _mk_requests(cfg, 3):
        eng.submit(r)
    eng.drain()
    wd = Watchdog([eng], strict=True, stall_s=30.0, queue_age_s=60.0)
    assert wd.check_now() == []            # strict mode: would raise
    assert "obs.anomaly{kind=stuck_request}" not in reg.snapshot()


def test_watchdog_flags_stuck_request_and_dump_names_slot(cfg, tmp_path):
    reg = MetricsRegistry()
    rec = FlightRecorder()
    eng = _mk_engine(cfg, registry=reg)
    req = _mk_requests(cfg, 1, new_tokens=8)[0]
    eng.submit(req)
    eng.step()                             # admit + one decode round
    occupied = [i for i, s in enumerate(eng.slots) if s is not None]
    assert occupied, "request should be seated in a slot"
    req.last_token_s -= 100.0              # inject: no progress for 100s
    wd = Watchdog([eng], strict=True, stall_s=30.0, recorder=rec,
                  dump_path=tmp_path / "dump.json")
    with pytest.raises(WatchdogError) as ei:
        wd.check_now()
    kinds = {a["kind"] for a in ei.value.anomalies}
    assert "stuck_request" in kinds
    stuck = next(a for a in ei.value.anomalies
                 if a["kind"] == "stuck_request")
    assert stuck["slot"] == occupied[0] and stuck["rid"] == req.rid
    assert reg.snapshot()["obs.anomaly{kind=stuck_request}"] == 1
    # the anomaly triggered a postmortem dump that names the stuck slot
    doc = json.loads((tmp_path / "dump.json").read_text())
    assert validate_bundle(doc) == []
    assert doc["reason"] == "watchdog_anomaly"
    anomalies = [e for e in doc["events"] if e["kind"] == "anomaly"]
    assert any(e.get("slot") == occupied[0] and e.get("rid") == req.rid
               for e in anomalies)
    req.last_token_s += 100.0              # undo; finish cleanly
    eng.drain()
    assert wd.check_now() == []


def test_watchdog_flags_leaked_kv_block(cfg):
    eng = _mk_engine(cfg, registry=MetricsRegistry())
    for r in _mk_requests(cfg, 2):
        eng.submit(r)
    eng.drain()
    assert eng.pool.check_invariants() == []
    leaked = eng.pool._free.pop()          # inject: block vanishes untracked
    wd = Watchdog([eng], strict=True)
    with pytest.raises(WatchdogError) as ei:
        wd.check_now()
    assert {a["kind"] for a in ei.value.anomalies} == {"kv_invariant"}
    assert "partition" in ei.value.anomalies[0]["violations"][0]
    eng.pool._free.append(leaked)          # undo the injection
    assert wd.check_now() == []


def test_watchdog_flags_stale_queue(cfg):
    eng = _mk_engine(cfg, registry=MetricsRegistry())
    req = _mk_requests(cfg, 1)[0]
    eng.submit(req)                        # queued, never stepped
    req.submit_s -= 100.0
    req.arrival_s -= 100.0
    wd = Watchdog([eng], strict=False, queue_age_s=60.0)
    kinds = {a["kind"] for a in wd.check_now()}
    assert "queue_stall" in kinds
    eng.drain()


def test_watchdog_background_thread_counts(cfg):
    eng = _mk_engine(cfg, registry=MetricsRegistry())
    wd = Watchdog([eng], interval_s=0.01)
    wd.start()
    time.sleep(0.1)
    wd.stop()
    assert wd.checks_run >= 2


# ----------------------------------------------------------------------
# flight recorder
# ----------------------------------------------------------------------
def test_flightrec_ring_overflow_counts_drops():
    rec = FlightRecorder(capacity=4)
    for i in range(10):
        rec.record("admit", rid=i)
    evs = rec.events()
    assert len(evs) == 4 and rec.dropped_events == 6
    assert [e["rid"] for e in evs] == [6, 7, 8, 9]     # oldest dropped


def test_flightrec_bundle_schema_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("x.hits").inc(3)
    rec = FlightRecorder(capacity=16)
    rec.record("switch", expert="e1", stall_s=0.5)
    rec.add_state_provider("slots", lambda: {"free": 2})
    rec.add_state_provider("broken", lambda: 1 / 0)
    path = rec.dump(tmp_path / "flight.json", reg, reason="test")
    doc = json.loads(path.read_text())
    assert validate_bundle(doc) == []
    assert doc["reason"] == "test"
    assert doc["metrics"]["x.hits"] == 3
    assert doc["state"]["slots"] == {"free": 2}
    assert "ZeroDivisionError" in doc["state"]["broken"]["error"]
    assert doc["events"][0]["kind"] == "switch"


def test_validate_bundle_catches_malformed():
    assert validate_bundle([]) == ["bundle is not an object"]
    problems = validate_bundle({"schema": "wrong", "events": [{"x": 1}]})
    assert any("schema" in p for p in problems)
    assert any("missing kind/ts" in p for p in problems)
    assert any("metrics" in p for p in problems)


def test_engine_drain_lands_flight_events(cfg):
    old = flightrec.set_recorder(FlightRecorder())
    try:
        eng = _mk_engine(cfg, registry=MetricsRegistry())
        for r in _mk_requests(cfg, 3):
            eng.submit(r)
        done = eng.drain()
        kinds = {e["kind"] for e in flightrec.get_recorder().events()}
        assert {"admit", "done"} <= kinds
        dones = [e for e in flightrec.get_recorder().events()
                 if e["kind"] == "done"]
        assert {e["rid"] for e in dones} == {r.rid for r in done}
    finally:
        flightrec.set_recorder(old)


# ----------------------------------------------------------------------
# trace-ring drop accounting
# ----------------------------------------------------------------------
def test_trace_ring_overflow_counted_and_exported():
    old = trace.set_tracer(Tracer(buffer_size=4))
    try:
        reg = MetricsRegistry()
        trace.register_metrics(reg)
        trace.enable()
        for i in range(10):
            trace.instant("tick", i=i)
        trace.disable()
        assert trace.dropped_events() == 6
        assert len(trace.events()) == 4
        doc = trace.get_tracer().to_chrome_trace()
        assert doc["metadata"]["trace.dropped_events"] == 6
        assert reg.snapshot()["trace.dropped_events"] == 6
        trace.get_tracer().clear()
        assert trace.dropped_events() == 0
    finally:
        trace.set_tracer(old)


# ----------------------------------------------------------------------
# HTTP endpoints: /readyz + /debug/*
# ----------------------------------------------------------------------
def _get(url):
    with urllib.request.urlopen(url, timeout=5) as resp:
        return resp.status, resp.read()


def test_httpd_readyz_and_debug_endpoints(tmp_path):
    reg = MetricsRegistry()
    reg.counter("x.hits").inc()
    rec = FlightRecorder()
    rec.record("admit", rid=1)
    state = {"warm": False}
    srv = serve_metrics(reg, port=0, ready_check=lambda: state["warm"],
                        debug={"slots": lambda: {"active": 1}},
                        recorder=rec)
    try:
        base = srv.url
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(f"{base}/readyz")
        assert ei.value.code == 503                    # still warming
        assert _get(f"{base}/healthz")[0] == 200       # but alive
        state["warm"] = True
        status, body = _get(f"{base}/readyz")
        assert status == 200 and body == b"ready\n"

        status, body = _get(f"{base}/debug/slots")
        assert status == 200 and json.loads(body) == {"active": 1}
        status, body = _get(f"{base}/debug/flight")
        doc = json.loads(body)
        assert validate_bundle(doc) == []
        assert doc["events"][0]["rid"] == 1
        # index lists every mounted endpoint
        idx = json.loads(_get(f"{base}/")[1])
        assert "/readyz" in idx["endpoints"]
        assert "/debug/slots" in idx["endpoints"]
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(f"{base}/debug/nope")
        assert ei.value.code == 404
        # a provider raising is a 500 with the error captured, not a crash
        srv.add_debug("boom", lambda: 1 / 0)
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(f"{base}/debug/boom")
        assert ei.value.code == 500
    finally:
        srv.stop()


def test_engine_debug_providers_snapshot(cfg):
    eng = _mk_engine(cfg, registry=MetricsRegistry())
    for r in _mk_requests(cfg, 2):
        eng.submit(r)
    eng.drain()
    provs = eng.debug_providers()
    assert set(provs) == {"slots", "pool", "sessions"}
    slots = provs["slots"]()
    assert len(slots["slots"]) == eng.n_slots
    assert all(s["state"] == "free" for s in slots["slots"])
    pool = provs["pool"]()
    assert pool["invariant_violations"] == []
    assert pool["blocks_in_use"] == 0                  # clean drain
    json.dumps({n: f() for n, f in provs.items()})     # JSON-able


def test_engine_warmed_flag_feeds_readyz(cfg):
    eng = _mk_engine(cfg, registry=MetricsRegistry())
    assert not eng.warmed
    eng.warmup()
    assert eng.warmed


# ----------------------------------------------------------------------
# SIGUSR2 postmortem dump (launch/serve.py)
# ----------------------------------------------------------------------
@pytest.mark.skipif(not hasattr(signal, "SIGUSR2"),
                    reason="platform lacks SIGUSR2")
def test_sigusr2_dumps_flight_bundle(cfg, tmp_path):
    from repro.launch.serve import install_flight_dump_signal

    old_rec = flightrec.set_recorder(FlightRecorder())
    prev = signal.getsignal(signal.SIGUSR2)
    try:
        reg = MetricsRegistry()
        eng = _mk_engine(cfg, registry=reg)
        req = _mk_requests(cfg, 1, new_tokens=8)[0]
        eng.submit(req)
        eng.step()                          # leave a live, seated slot
        for name, fn in eng.debug_providers().items():
            flightrec.add_state_provider(name, fn)
        out = tmp_path / "sig.json"
        assert install_flight_dump_signal(out, registry=reg) \
            == signal.SIGUSR2
        signal.raise_signal(signal.SIGUSR2)
        doc = json.loads(out.read_text())
        assert validate_bundle(doc) == []
        assert doc["reason"] == "signal"
        # the bundle's state snapshot names the busy slot and its rid
        busy = [s for s in doc["state"]["slots"]["slots"]
                if s["state"] == "decoding"]
        assert busy and busy[0]["rid"] == req.rid
        assert doc["state"]["pool"]["blocks_in_use"] > 0
        eng.drain()
    finally:
        signal.signal(signal.SIGUSR2, prev)
        flightrec.set_recorder(old_rec)


# ----------------------------------------------------------------------
# check_bench --update-baseline
# ----------------------------------------------------------------------
def test_check_bench_update_baseline(tmp_path):
    baseline = tmp_path / "baseline.json"
    baseline.write_text(json.dumps({
        "comment": ["keep me"],
        "metrics": {
            "a:tps": {"value": 100, "threshold": 0.5},
            "a:p99": {"value": 0.2, "threshold": 1.0,
                      "higher_is_better": False},
            "a:gone": {"value": 7, "threshold": 0.1},
        }}))
    results = tmp_path / "results"
    results.mkdir()
    (results / "bench_x.json").write_text(json.dumps({
        "metrics": {"a:tps": 140.0, "a:p99": 0.15, "a:new": 3.0}}))

    run = lambda *extra: subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_bench.py"),
         "--baseline", str(baseline), *extra, str(results)],
        capture_output=True, text=True, cwd=REPO)
    r = run("--update-baseline")
    assert r.returncode == 0, r.stdout + r.stderr
    doc = json.loads(baseline.read_text())
    assert doc["comment"] == ["keep me"]                   # preserved
    assert doc["metrics"]["a:tps"] == {"value": 140.0, "threshold": 0.5}
    assert doc["metrics"]["a:p99"]["higher_is_better"] is False
    assert doc["metrics"]["a:p99"]["value"] == 0.15
    assert doc["metrics"]["a:new"]["value"] == 3.0         # added
    assert doc["metrics"]["a:gone"]["value"] == 7          # untouched

    # gating against the refreshed baseline: only the dropped metric fails
    r = run()
    assert r.returncode == 1
    assert "a:gone" in r.stdout and "MISSING" in r.stdout
    assert r.stdout.count("REGRESSION") == 0
