"""Property + behaviour tests for the paper's core: three-tier memory,
LRU switching, static allocator, CoE composition, bandwidth model."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced
from repro.core import (CompositionOfExperts, DGX_A100, DGX_H100, ExpertHandle,
                        HashRouter, HBMWeightCache, SN40L_NODE, Symbol,
                        allocate_static, plan_placement, spill_order)
from repro.core.bandwidth_model import (coe_latency, decode_step_cost,
                                        footprint_nodes, switch_cost)
from repro.core.fusion import model_fusion_report, plan
from repro.models import get_model


# ---------------------------------------------------------------- allocator
@st.composite
def _symbols(draw):
    n = draw(st.integers(1, 24))
    syms = []
    for i in range(n):
        first = draw(st.integers(0, 20))
        last = first + draw(st.integers(0, 10))
        size = draw(st.integers(1, 1 << 20))
        syms.append(Symbol(f"s{i}", size, first, last,
                           transfer_footprint=draw(st.integers(0, 1 << 22))))
    return syms


@given(_symbols())
@settings(max_examples=60, deadline=None)
def test_allocator_no_live_overlap(syms):
    """Symbols with overlapping lifetimes must never share addresses."""
    alloc = allocate_static(syms)
    al = 512
    rng = {s.name: (alloc.offsets[s.name],
                    alloc.offsets[s.name] + ((s.size + al - 1) // al) * al)
           for s in syms}
    for a in syms:
        for b in syms:
            if a.name >= b.name:
                continue
            lives_overlap = not (a.last_use < b.first_use or
                                 b.last_use < a.first_use)
            if lives_overlap:
                ra, rb = rng[a.name], rng[b.name]
                assert ra[1] <= rb[0] or rb[1] <= ra[0], (a, b, ra, rb)


@given(_symbols())
@settings(max_examples=30, deadline=None)
def test_allocator_peak_bounded_by_sum(syms):
    alloc = allocate_static(syms)
    total = sum(((s.size + 511) // 512) * 512 for s in syms)
    assert alloc.peak <= total


@given(_symbols())
@settings(max_examples=30, deadline=None)
def test_spill_order_is_bandwidth_ascending(syms):
    order = spill_order(syms)
    feet = [s.transfer_footprint for s in order]
    assert feet == sorted(feet)


def test_plan_placement_spills_until_fit():
    syms = [Symbol(f"w{i}", 1000, 0, 10, transfer_footprint=i * 100)
            for i in range(10)]
    alloc, spilled = plan_placement(syms, hbm_capacity=3 * 1024)
    assert alloc.peak <= 3 * 1024
    # lowest-footprint symbols spilled first
    assert spilled == [f"w{i}" for i in range(len(spilled))]


# ---------------------------------------------------------------- LRU cache
def _mk_host(nbytes=1024):
    return {"w": np.ones(nbytes // 4, np.float32)}


def test_lru_eviction_order_and_capacity():
    fetched = []
    cache = HBMWeightCache(3 * 1024, fetch=lambda n: (fetched.append(n),
                                                      _mk_host())[1])
    for name in ["a", "b", "c"]:
        cache.activate(name)
    assert cache.expert_ids() == ["a", "b", "c"]
    cache.activate("a")                      # refresh a
    cache.activate("d")                      # evicts b (LRU)
    assert "b" not in cache.expert_ids()
    assert cache.used_bytes <= cache.capacity
    assert cache.stats.evictions == 1
    assert cache.stats.bytes_copyback_elided > 0   # read-only elision


def test_lru_hit_no_refetch():
    calls = []
    cache = HBMWeightCache(1 << 20, fetch=lambda n: (calls.append(n),
                                                     _mk_host())[1])
    cache.activate("x")
    cache.activate("x")
    assert calls == ["x"]
    assert cache.stats.hits == 1


def test_prefetch_overlap_counts_no_recency():
    cache = HBMWeightCache(1 << 20, fetch=lambda n: _mk_host())
    cache.activate("a")
    assert cache.prefetch("b") is True
    assert cache.prefetch("b") is False      # already resident
    cache.activate("b")                      # hit after prefetch
    assert cache.stats.hits == 1
    assert cache.stats.misses == 1


def test_oversized_expert_raises():
    cache = HBMWeightCache(128, fetch=lambda n: _mk_host(4096))
    with pytest.raises(MemoryError):
        cache.activate("big")


@given(st.lists(st.integers(0, 5), min_size=1, max_size=40))
@settings(max_examples=30, deadline=None)
def test_lru_capacity_invariant(seq):
    cache = HBMWeightCache(2 * 1024, fetch=lambda n: _mk_host())
    for e in seq:
        cache.activate(f"e{e}")
        assert cache.used_bytes <= cache.capacity
        assert len(cache.expert_ids()) <= 2


# ---------------------------------------------------------------- router
@given(st.integers(1, 64), st.integers(1, 8), st.integers(2, 16))
@settings(max_examples=20, deadline=None)
def test_hash_router_deterministic_and_in_range(n_exp, B, S):
    r = HashRouter(n_exp)
    toks = np.arange(B * S, dtype=np.int32).reshape(B, S)
    a = r.route_host(toks)
    b = r.route_host(toks)
    assert (a == b).all()
    assert ((0 <= a) & (a < n_exp)).all()


# ---------------------------------------------------------------- CoE
def test_coe_generate_groups_and_determinism(rng):
    cfg = reduced(get_config("samba-coe-expert-7b"))
    m = get_model(cfg)
    experts = []
    for i in range(3):
        p = m.init(jax.random.fold_in(rng, i))
        experts.append(jax.tree.map(np.asarray, p))
    nbytes = sum(x.nbytes for x in jax.tree.leaves(experts[0]))
    coe = CompositionOfExperts(HashRouter(3), None, int(2.5 * nbytes))
    for i, h in enumerate(experts):
        coe.register(ExpertHandle(f"e{i}", cfg, h))
    toks = np.random.RandomState(0).randint(0, cfg.vocab_size, (4, 8)).astype(np.int32)
    r1 = coe.generate(toks, 3)
    r2 = coe.generate(toks, 3)
    assert (r1.tokens == r2.tokens).all()
    assert r1.tokens.shape == (4, 3)
    # memory contract declared ahead of time (paper §V-B)
    c = coe.memory_contract("e0")
    assert c["hbm_bytes"] == nbytes


# ---------------------------------------------------------------- bw model
def test_bandwidth_model_reproduces_paper_trends():
    """Fig 12 / Table V trends: (1) SN40L-style capacity tier switches much
    faster than DGX host->GPU; (2) past-HBM expert counts spike DGX latency;
    (3) footprint: one capacity-tier node holds what needs many HBM-only
    nodes (Fig 13, 19x claim)."""
    seven_b = 7e9 * 2
    assert switch_cost(seven_b, DGX_A100) / switch_cost(seven_b, SN40L_NODE) > 25
    assert switch_cost(seven_b, DGX_H100) / switch_cost(seven_b, SN40L_NODE) > 12

    dc = decode_step_cost(7e9, 0, 8, DGX_A100)
    few = coe_latency(4, seven_b, 4, dc, 20, DGX_A100)     # all resident
    many = coe_latency(8, seven_b, 0, dc, 20, DGX_A100)    # all miss
    assert many["total_s"] > few["total_s"] * 2

    n_sn = footprint_nodes(850, seven_b, SN40L_NODE, use_capacity_tier=True)
    n_dgx = footprint_nodes(850, seven_b, DGX_A100, use_capacity_tier=False)
    assert n_sn == 1
    assert n_dgx >= 19


# ---------------------------------------------------------------- fusion
def test_fusion_plan_launch_ratio_matches_paper_range():
    """Fig 11: fused vs unfused kernel-call ratios land in the paper's
    observed 3x-30x band for decode. Decode HBM traffic is weight/cache
    bound so intensity barely moves (the paper's decode speedups come from
    launch overheads); prefill materializes activations unfused, so there
    fusion must raise intensity substantially (Table I regime)."""
    cfg = get_config("samba-coe-expert-7b")
    dec = model_fusion_report(cfg, batch=8, ctx=4096, seq=1)
    assert 3.0 < dec.launch_ratio < 30.0
    assert dec.traffic_ratio >= 1.0
    pre = model_fusion_report(cfg, batch=8, ctx=4096, seq=4096)
    assert pre.intensity_fused > pre.intensity_unfused * 1.5


def test_fusion_bytes_reduction():
    cfg = get_config("mixtral-8x7b")
    r = plan(cfg, batch=8, ctx=4096, seq=4096)
    assert r.fused_hbm_bytes < r.unfused_hbm_bytes
