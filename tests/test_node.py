"""Multi-socket RDU-node serving (ISSUE 5).

Three layers of coverage:

  * pure tests (topology validation, placement planning, pool pspecs) that
    run on any machine;
  * a subprocess acceptance test on 8 emulated CPU devices — part of the
    default tier-1 run, like ``tests/test_distributed.py`` — pinning the
    headline invariant: a TP=2 x 4-group node produces per-token outputs
    matching the single-device engine bit-for-bit (greedy) for the same
    request trace, no expert starves, and per-group HBM budgets are never
    exceeded;
  * in-process 8-device tests (the CI ``node-tests`` job runs the suite
    under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; they skip
    on fewer devices) covering the kv-replicated TP=8 path, least-loaded
    dispatch and online rebalancing.
"""
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.node.placement import (ExpertProfile, plan_expert_placement)
from repro.node.topology import make_node_topology

_ROOT = str(pathlib.Path(__file__).resolve().parent.parent)

needs_8_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(the node-tests CI job sets it)")


def _run_sub(code: str):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": os.environ["PATH"],
                            "HOME": os.environ.get("HOME", "/root"),
                            "JAX_PLATFORMS": "cpu"},
                       cwd=_ROOT)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


# ------------------------------------------------------------- topology
def test_topology_validation():
    with pytest.raises(ValueError):
        make_node_topology(0)
    with pytest.raises(ValueError, match="XLA_FLAGS"):
        make_node_topology(4, 4, devices=jax.devices()[:1])
    topo = make_node_topology(1, 1, devices=jax.devices()[:1])
    assert topo.name == "1x1" and topo.n_sockets == 1
    assert topo.groups[0].mesh.axis_names == ("model",)


@needs_8_devices
def test_topology_disjoint_device_groups():
    """Groups must partition the device list with no overlap."""
    topo = make_node_topology(2, 4)
    seen = [d for g in topo.groups for d in g.devices]
    assert len(seen) == 8 and len(set(seen)) == 8
    assert [g.tp for g in topo.groups] == [2, 2, 2, 2]
    assert make_node_topology(2).n_groups == 4     # default: fill the node


def test_paged_pool_pspec_rules():
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config, pad_for_tp, reduced
    from repro.distributed.partitioning import paged_pool_pspec

    class FakeMesh:
        axis_names = ("model",)
        shape = {"model": 2}

    cfg = reduced(get_config("samba-coe-expert-7b"))    # kv=4: divisible
    assert paged_pool_pspec(cfg, FakeMesh()) == P(None, None, None, "model",
                                                  None)

    class FakeMesh8:
        axis_names = ("model",)
        shape = {"model": 8}

    cfg8 = pad_for_tp(cfg, 8)                           # kv=4 < 8: replicate
    assert paged_pool_pspec(cfg8, FakeMesh8()) == P(None, None, None, None,
                                                    None)


# ------------------------------------------------------------ placement
def _profiles(sizes, demands):
    return [ExpertProfile(f"e{i}", s, d)
            for i, (s, d) in enumerate(zip(sizes, demands))]


def test_placement_respects_group_budgets():
    sizes = [100] * 6
    pl = plan_expert_placement(_profiles(sizes, [1] * 6), [350, 350])
    for gid, names in pl.resident.items():
        assert len(names) * 100 <= 350     # never over a group's HBM share
    assert not pl.spilled                  # 3 + 3 fit
    assert all(pl.owners(f"e{i}") for i in range(6))
    # tighter budgets: the overflow spills instead of over-committing
    tight = plan_expert_placement(_profiles(sizes, [1] * 6), [250, 250])
    assert all(len(n) <= 2 for n in tight.resident.values())
    assert len(tight.spilled) == 2
    assert all(tight.owners(f"e{i}") for i in range(6))


def test_placement_balances_demand():
    """Two groups, skewed demand: the two hottest experts must land on
    different groups."""
    pl = plan_expert_placement(
        _profiles([100] * 4, [10, 10, 1, 1]), [200, 200])
    assert pl.owners("e0") != pl.owners("e1")


def test_placement_replicates_hot_expert():
    pl = plan_expert_placement(
        _profiles([100] * 3, [20, 1, 1]), [300, 300, 300],
        replicate_share=0.25)
    assert len(pl.owners("e0")) > 1          # >= 2 replicas of the hot one
    assert len(pl.owners("e1")) == 1


def test_placement_spills_when_nothing_fits():
    """An expert bigger than every group's HBM share streams from the
    shared store but still gets a dispatch owner."""
    pl = plan_expert_placement(
        _profiles([100, 1000], [1, 1]), [200, 200])
    assert "e1" in pl.spilled
    assert len(pl.owners("e1")) == 1
    assert all("e1" not in names for names in pl.resident.values())


def test_placement_uniform_fallback_without_demand():
    """Zero observed demand (cold start) plans uniform demand — experts
    spread across groups rather than piling onto group 0."""
    pl = plan_expert_placement(_profiles([100] * 4, [0] * 4), [200, 200])
    assert len(pl.resident[0]) == len(pl.resident[1]) == 2


# ------------------------------------------- acceptance test (subprocess)
@pytest.mark.slow
def test_node_2x4_matches_single_engine_bit_exact():
    """ISSUE 5 acceptance: on 8 emulated CPU devices, a TP=2 x 4-group node
    reproduces the single-device engine's greedy outputs bit-for-bit for
    the same trace (mixed router-tagged and caller-tagged requests), no
    expert starves, per-group HBM budgets hold at every step, and the paged
    pools leak nothing."""
    out = _run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced
        from repro.core import CompositionOfExperts, ExpertHandle
        from repro.models import get_model
        from repro.serving import Request, ServingEngine
        from repro.node import make_node_topology, RDUNode

        class FirstTokenRouter:              # expert = first prompt token % n
            def __init__(self, n): self.n = n
            def route(self, params, tokens):
                return jnp.asarray(np.asarray(tokens)[:, 0] % self.n)

        cfg = reduced(get_config("samba-coe-expert-7b"))
        m = get_model(cfg)
        rng = jax.random.PRNGKey(0)
        n_exp = 4
        experts = [jax.tree.map(np.asarray,
                                m.init(jax.random.fold_in(rng, i)))
                   for i in range(n_exp)]
        nbytes = sum(x.nbytes for x in jax.tree.leaves(experts[0]))

        rs = np.random.RandomState(0)
        trace = []
        for i in range(12):                  # every expert gets traffic
            p = rs.randint(0, cfg.vocab_size, (8,)).astype(np.int32)
            p[0] = p[0] - (p[0] % n_exp) + (i % n_exp)
            trace.append((i, p, 3 + i % 4, f"e{i % n_exp}" if i >= 10
                          else None))       # last two: caller-tagged

        coe = CompositionOfExperts(FirstTokenRouter(n_exp), None,
                                   int(10 * nbytes))
        for i, h in enumerate(experts):
            coe.register(ExpertHandle(f"e{i}", cfg, h))
        ref = ServingEngine(coe, cfg, max_len=24, n_slots=4, block_size=8)
        for rid, toks, n, tag in trace:
            ref.submit(Request(rid=rid, tokens=toks, max_new_tokens=n,
                               expert=tag))
        ref_done = {r.rid: (r.expert, r.output) for r in ref.drain()}
        assert len(ref_done) == len(trace)

        topo = make_node_topology(2, 4)
        node = RDUNode(topo, cfg, FirstTokenRouter(n_exp), None,
                       group_hbm_bytes=int(2.5 * nbytes),
                       group_kv_reserve_bytes=int(0.8 * nbytes),
                       n_slots=2, block_size=8, max_len=24)
        for i, h in enumerate(experts):
            node.register_expert(f"e{i}", h)
        for rid, toks, n, tag in trace:
            node.submit(Request(rid=rid, tokens=toks, max_new_tokens=n,
                                expert=tag))
        done = {}
        while node.has_work:
            for r in node.step():
                done[r.rid] = (r.expert, r.output)
            assert node.hbm_within_budget(), "HBM budget exceeded mid-run"
        assert len(done) == len(trace), "a request starved"
        served = {e for e, _ in done.values()}
        assert served == {f"e{i}" for i in range(n_exp)}, served
        for rid, (re, ro) in ref_done.items():
            ne, no = done[rid]
            assert re == ne, (rid, re, ne)
            assert (ro == no).all(), f"rid {rid} diverged from 1-device ref"
        for gs in node.groups:
            assert gs.engine.pool.stats.blocks_in_use == 0
            assert gs.coe.cache.used_bytes <= gs.coe.cache.capacity
            assert (gs.engine.pool.capacity_bytes()
                    <= gs.coe.hbm_budget.kv_bytes)
        st = node.stats()
        assert st.tokens_out == sum(n for _, _, n, _ in trace)
        node.close()
        print("NODE_BIT_EXACT_OK", st.tokens_out, round(st.imbalance, 3))
    """)
    assert "NODE_BIT_EXACT_OK" in out


@pytest.mark.slow
def test_node_disaggregated_matches_colocated_bit_exact():
    """ISSUE 8 acceptance: a node with one socket group dedicated to prefill
    (``prefill_groups=1``) and three decode groups produces greedy token
    streams identical to the colocated 4-group node for the same trace; the
    prefill-group -> decode-group paged-KV handoff never violates any
    group's HBM budget, both nodes' staging/decode pools leak nothing, and
    the AOT-warmed prefill buckets trigger zero post-warmup compilations.

    Groups are TP=1 here (the same shape ``--sweep-prefill``'s disagg axis
    gates): with one device per group the packed forward is placement-
    independent, so the comparison is bit-for-bit. TP>1 prefill groups run
    the same GSPMD path but a near-tie in the worker's first-token argmax
    can resolve differently under a different psum order, so cross-shape
    identity is only asserted at TP=1 (see docs/architecture.md)."""
    out = _run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced
        from repro.core import CompositionOfExperts, ExpertHandle
        from repro.models import get_model
        from repro.serving import Request
        from repro.serving.prefill import compile_count
        from repro.node import make_node_topology, RDUNode

        class FirstTokenRouter:              # expert = first prompt token % n
            def __init__(self, n): self.n = n
            def route(self, params, tokens):
                return jnp.asarray(np.asarray(tokens)[:, 0] % self.n)

        cfg = reduced(get_config("samba-coe-expert-7b"))
        m = get_model(cfg)
        rng = jax.random.PRNGKey(0)
        n_exp = 3
        experts = [jax.tree.map(np.asarray,
                                m.init(jax.random.fold_in(rng, i)))
                   for i in range(n_exp)]
        nbytes = sum(x.nbytes for x in jax.tree.leaves(experts[0]))

        rs = np.random.RandomState(0)
        trace = []
        for i in range(10):                  # mixed prompt lengths 4..15
            S = 4 + rs.randint(0, 12)
            p = rs.randint(0, cfg.vocab_size, (S,)).astype(np.int32)
            p[0] = p[0] - (p[0] % n_exp) + (i % n_exp)
            trace.append((i, p, 3 + i % 4))

        def run(prefill_groups):
            node = RDUNode(make_node_topology(1, 4), cfg,
                           FirstTokenRouter(n_exp), None,
                           group_hbm_bytes=int(2.5 * nbytes),
                           group_kv_reserve_bytes=int(0.8 * nbytes),
                           n_slots=2, block_size=8, max_len=24,
                           prefill_groups=prefill_groups)
            for i, h in enumerate(experts):
                node.register_expert(f"e{i}", h)
            node.warmup()
            n_warm = compile_count()
            for rid, toks, n in trace:
                gid = node.submit(Request(rid=rid, tokens=toks,
                                          max_new_tokens=n))
                if prefill_groups:           # admits land on the worker
                    assert gid == 0, gid
            done, steps = {}, 0
            while node.has_work:
                for r in node.step():
                    done[r.rid] = (r.expert, r.output)
                assert node.hbm_within_budget(), "HBM budget exceeded"
                steps += 1
                assert steps < 10000
            assert len(done) == len(trace), "a request starved"
            for gs in node.groups:
                assert gs.engine.pool.stats.blocks_in_use == 0
            for w in node.workers:
                assert w.pool.stats.blocks_in_use == 0
            st = node.stats()
            compiles = compile_count() - n_warm
            node.close()
            return done, st, compiles

        co_done, co_st, co_compiles = run(prefill_groups=0)
        dis_done, dis_st, dis_compiles = run(prefill_groups=1)
        assert co_compiles == 0, f"colocated recompiled: {co_compiles}"
        assert dis_compiles == 0, f"disagg recompiled: {dis_compiles}"
        assert len(dis_st.prefill_groups) == 1
        assert len(co_st.prefill_groups) == 0
        for rid, (ce, co) in co_done.items():
            de, do = dis_done[rid]
            assert ce == de, (rid, ce, de)
            assert (co == do).all(), f"rid {rid}: {co} vs {do}"
        print("DISAGG_PARITY_OK", dis_st.tokens_out)
    """)
    assert "DISAGG_PARITY_OK" in out


# --------------------------------------------- in-process 8-device tests
@needs_8_devices
def test_tp8_single_group_matches_plain_engine():
    """The kv-replicated TP=8 path (GQA kv-heads < tp) matches the plain
    single-device engine on a padded config."""
    import jax.numpy as jnp
    from repro.configs import get_config, pad_for_tp, reduced
    from repro.core import CompositionOfExperts, ExpertHandle, HashRouter
    from repro.models import get_model
    from repro.node import make_node_topology, RDUNode
    from repro.serving import Request, ServingEngine

    cfg = pad_for_tp(reduced(get_config("samba-coe-expert-7b")), 8)
    m = get_model(cfg)
    experts = [jax.tree.map(np.asarray, m.init(jax.random.PRNGKey(i)))
               for i in range(2)]
    nbytes = sum(x.nbytes for x in jax.tree.leaves(experts[0]))
    rs = np.random.RandomState(1)
    trace = [(i, rs.randint(0, cfg.vocab_size, (6,)).astype(np.int32), 3)
             for i in range(4)]

    coe = CompositionOfExperts(HashRouter(2), None, int(6 * nbytes))
    for i, h in enumerate(experts):
        coe.register(ExpertHandle(f"e{i}", cfg, h))
    ref = ServingEngine(coe, cfg, max_len=16, n_slots=2, block_size=8)
    for rid, toks, n in trace:
        ref.submit(Request(rid=rid, tokens=toks, max_new_tokens=n))
    ref_done = {r.rid: r.output for r in ref.drain()}

    node = RDUNode(make_node_topology(8, 1), cfg, HashRouter(2), None,
                   group_hbm_bytes=int(3 * nbytes),
                   group_kv_reserve_bytes=int(0.8 * nbytes),
                   n_slots=2, block_size=8, max_len=16)
    for i, h in enumerate(experts):
        node.register_expert(f"e{i}", h)
    runner = node.groups[0].engine.runner
    assert runner.tp == 8 and not runner.kv_sharded and runner.vocab_sharded
    for rid, toks, n in trace:
        node.submit(Request(rid=rid, tokens=toks, max_new_tokens=n))
    done = {r.rid: r.output for r in node.drain()}
    assert all((ref_done[r] == done[r]).all() for r in ref_done)
    node.close()


@needs_8_devices
def test_dispatch_least_loaded_and_rebalance():
    """Requests for one expert spread over its replica groups (least-loaded
    dispatch); rebalancing from observed demand replans and prewarms."""
    from repro.configs import get_config, reduced
    from repro.core import HashRouter
    from repro.models import get_model
    from repro.node import make_node_topology, RDUNode
    from repro.serving import Request

    cfg = reduced(get_config("samba-coe-expert-7b"))
    m = get_model(cfg)
    host = jax.tree.map(np.asarray, m.init(jax.random.PRNGKey(0)))
    nbytes = sum(x.nbytes for x in jax.tree.leaves(host))
    node = RDUNode(make_node_topology(2, 4), cfg, HashRouter(2), None,
                   group_hbm_bytes=int(2.5 * nbytes),
                   group_kv_reserve_bytes=int(0.8 * nbytes),
                   n_slots=2, block_size=8, max_len=16,
                   replicate_share=0.25)
    node.register_expert("e0", host)
    node.register_expert("e1", jax.tree.map(np.copy, host))

    rs = np.random.RandomState(2)
    gids = [node.submit(Request(
        rid=i, tokens=rs.randint(0, cfg.vocab_size, (6,)).astype(np.int32),
        max_new_tokens=2, expert="e0")) for i in range(6)]
    owners = set(node.placement.owners("e0"))
    assert set(gids) <= owners
    if len(owners) > 1:                     # replicas exist: load spreads
        assert len(set(gids)) > 1
    node.drain()

    pl = node.rebalance()                   # e0 demand-heavy: replicated
    assert len(pl.owners("e0")) >= len(pl.owners("e1"))
    assert node.hbm_within_budget()
    st = node.stats()
    assert st.requests == 6 and st.tokens_out == 12
    assert sum(g["submitted"] for g in st.per_group) == 6
    node.close()
