"""Paged-native Pallas fused decode vs the XLA reference (ISSUE 7).

Covers, bottom-up:
  * ``decode_paged`` against a dense masked-softmax oracle across GQA
    ratios, ragged lengths straddling block boundaries, and minimal/full
    lanes;
  * the ``qkv_rope_paged`` prologue and ``oproj_ffn_swiglu`` epilogue
    against the model-layer reference math;
  * ``fused_paged_extend`` vs ``xla_paged_extend`` (fp tolerance) across
    GQA variants, with an inactive lane scattering to scratch;
  * the backend seam itself (selection, validation, unsupported configs);
  * end-to-end engine drains: at f32, greedy token streams must be
    IDENTICAL across backends (the acceptance claim), speculative decode
    must match too (its emitted tokens come from the g>1 verify step, which
    is the XLA body under both backends), and the device-side table cache
    must reuse arrays across rounds;
  * a TP=2 subprocess drain (node/execution.py fused shard_map path).

Precision contract: strict token identity holds at f32. In bf16 the XLA
body rounds every op boundary to bf16 while the fused kernels keep f32 in
VMEM, so bf16 gets tolerance-level parity only (the extend test covers it).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import CompositionOfExperts, ExpertHandle, HashRouter
from repro.models import get_model
from repro.models import layers as L
from repro.serving import (FusedPagedBackend, Request, ServingEngine,
                           SpeculativeDecode, XlaPagedBackend, make_backend,
                           make_runner)
from repro.serving.backends import (fused_kernel_hbm_bytes,
                                    fused_paged_extend, xla_paged_extend)


@pytest.fixture(scope="module")
def cfg():
    return reduced(get_config("samba-coe-expert-7b"))


def _f32(tree):
    """Param trees init as bf16 regardless of cfg.dtype — cast for the
    strict-parity contract."""
    return jax.tree.map(
        lambda x: np.asarray(x, np.float32)
        if x.dtype == jnp.bfloat16 else np.asarray(x), tree)


def _gqa_cfg(cfg, n_kv):
    return dataclasses.replace(cfg, n_kv_heads=n_kv)


# ------------------------------------------------------ decode_paged oracle
def _paged_attention_ref(q, kp, vp, tables, len1):
    """Dense gather + masked softmax — the oracle decode_paged must match."""
    B, Hq, dh = q.shape
    Hkv = kp.shape[2]
    G = Hq // Hkv
    maxb, block = tables.shape[1], kp.shape[1]
    S = maxb * block
    kc = kp[tables].reshape(B, S, Hkv, dh)
    vc = vp[tables].reshape(B, S, Hkv, dh)
    qg = q.reshape(B, Hkv, G, dh)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, kc,
                   preferred_element_type=jnp.float32) / np.sqrt(dh)
    mask = jnp.arange(S)[None, None, None, :] < len1[:, None, None, None]
    pa = jax.nn.softmax(jnp.where(mask, s, -jnp.inf), axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", pa, vc,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Hq, dh)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (4, 1)])
def test_decode_paged_matches_dense_reference(hq, hkv):
    """GQA ratios 1/2/4, ragged lengths straddling block boundaries, block
    tables in scrambled pool order."""
    from repro.kernels.flash_attention.ops import decode_paged

    B, dh, block, maxb = 4, 32, 8, 3
    rows = B * maxb + 1                       # + scratch
    rs = np.random.RandomState(0)
    q = jnp.asarray(rs.standard_normal((B, hq, dh)), jnp.float32)
    kp = jnp.asarray(rs.standard_normal((rows, block, hkv, dh)), jnp.float32)
    vp = jnp.asarray(rs.standard_normal((rows, block, hkv, dh)), jnp.float32)
    perm = rs.permutation(rows - 1)           # scrambled block placement
    tables = jnp.asarray(perm[:B * maxb].reshape(B, maxb), jnp.int32)
    # 1 token, mid-block, exactly one block, straddling into block 2
    len1 = jnp.asarray([1, 5, 8, 17], jnp.int32)

    got = decode_paged(q, kp, vp, tables, len1, interpret=True)
    ref = _paged_attention_ref(q, kp, vp, tables, len1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_decode_paged_minimal_and_full_lanes_finite():
    """len1=1 (single cached token) through len1=S (every block full) stay
    finite and correct — the inactive-lane story relies on garbage lanes
    producing finite output the caller ignores."""
    from repro.kernels.flash_attention.ops import decode_paged

    B, hq, hkv, dh, block, maxb = 4, 4, 2, 32, 8, 2
    S = maxb * block
    rows = B * maxb + 1
    rs = np.random.RandomState(1)
    q = jnp.asarray(rs.standard_normal((B, hq, dh)), jnp.float32)
    kp = jnp.asarray(rs.standard_normal((rows, block, hkv, dh)), jnp.float32)
    vp = jnp.asarray(rs.standard_normal((rows, block, hkv, dh)), jnp.float32)
    tables = jnp.arange(B * maxb, dtype=jnp.int32).reshape(B, maxb)
    len1 = jnp.asarray([1, S, S // 2, 1], jnp.int32)
    got = decode_paged(q, kp, vp, tables, len1, interpret=True)
    assert np.isfinite(np.asarray(got)).all()
    ref = _paged_attention_ref(q, kp, vp, tables, len1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------- prologue/epilogue kernels
def test_qkv_rope_paged_matches_layer_reference(cfg):
    """RMSNorm + QKV + per-lane RoPE == models.layers math (f32), including
    ragged per-lane positions (no shared position scalar in paged decode)."""
    from repro.kernels.fused_decode.kernel import qkv_rope_paged

    c = _gqa_cfg(cfg, 2)
    B, D, dh = 4, c.d_model, c.head_dim
    rs = np.random.RandomState(2)
    x = jnp.asarray(rs.standard_normal((B, D)), jnp.float32)
    scale = jnp.asarray(rs.standard_normal((D,)), jnp.float32)
    wq = jnp.asarray(rs.standard_normal((D, c.n_heads, dh)) * 0.05,
                     jnp.float32)
    wk = jnp.asarray(rs.standard_normal((D, c.n_kv_heads, dh)) * 0.05,
                     jnp.float32)
    wv = jnp.asarray(rs.standard_normal((D, c.n_kv_heads, dh)) * 0.05,
                     jnp.float32)
    pos = jnp.asarray([0, 3, 17, 100], jnp.int32)

    q, k, v = qkv_rope_paged(x, scale, wq, wk, wv, pos,
                             theta=c.rope_theta, interpret=True)

    xn = L.rms_norm(x, scale)
    q_ref = L.apply_rope(c, jnp.einsum("bd,dhk->bhk", xn, wq)[:, None],
                         pos[:, None])[:, 0]
    k_ref = L.apply_rope(c, jnp.einsum("bd,dhk->bhk", xn, wk)[:, None],
                         pos[:, None])[:, 0]
    v_ref = jnp.einsum("bd,dhk->bhk", xn, wv)
    for got, ref in ((q, q_ref), (k, k_ref), (v, v_ref)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


def test_oproj_ffn_swiglu_matches_reference(cfg):
    """Whole layer epilogue (out-proj + residual + norm + SwiGLU + residual)
    against the explicit composition, with a non-default block_f so the
    FFN grid actually iterates."""
    from repro.kernels.fused_decode.kernel import oproj_ffn_swiglu

    B, D, F, HD = 4, cfg.d_model, cfg.d_ff, cfg.n_heads * cfg.head_dim
    rs = np.random.RandomState(3)
    x = jnp.asarray(rs.standard_normal((B, D)), jnp.float32)
    attn = jnp.asarray(rs.standard_normal((B, HD)), jnp.float32)
    wo = jnp.asarray(rs.standard_normal((HD, D)) * 0.05, jnp.float32)
    scale = jnp.asarray(rs.standard_normal((D,)), jnp.float32)
    wg = jnp.asarray(rs.standard_normal((D, F)) * 0.05, jnp.float32)
    wu = jnp.asarray(rs.standard_normal((D, F)) * 0.05, jnp.float32)
    wd = jnp.asarray(rs.standard_normal((F, D)) * 0.05, jnp.float32)

    got = oproj_ffn_swiglu(x, attn, wo, scale, wg, wu, wd, block_f=64,
                           interpret=True)
    y = x + attn @ wo
    yn = L.rms_norm(y, scale)
    g, u = yn @ wg, yn @ wu
    ref = y + (g * jax.nn.sigmoid(g) * u) @ wd
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_ffn_swiglu_partial_form_composes_with_residual(cfg):
    """residual=False (the TP partial the fused shard_map path psums) plus
    the residual add equals the residual=True kernel."""
    from repro.kernels.fused_decode.kernel import ffn_swiglu

    B, D, F = 4, cfg.d_model, cfg.d_ff
    rs = np.random.RandomState(4)
    x = jnp.asarray(rs.standard_normal((B, D)), jnp.float32)
    scale = jnp.asarray(rs.standard_normal((D,)), jnp.float32)
    wg = jnp.asarray(rs.standard_normal((D, F)) * 0.05, jnp.float32)
    wu = jnp.asarray(rs.standard_normal((D, F)) * 0.05, jnp.float32)
    wd = jnp.asarray(rs.standard_normal((F, D)) * 0.05, jnp.float32)
    full = ffn_swiglu(x, scale, wg, wu, wd, block_f=64, interpret=True)
    part = ffn_swiglu(x, scale, wg, wu, wd, block_f=64, residual=False,
                      interpret=True)
    np.testing.assert_allclose(np.asarray(full), np.asarray(x + part),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------- fused vs XLA extend
@pytest.mark.parametrize("n_kv", [1, 2, 4])
def test_fused_extend_matches_xla_extend(cfg, n_kv):
    """One fused step == one XLA step (fp tolerance at f32): logits AND the
    scattered pool state, across GQA variants, with an inactive lane (must
    scatter to scratch under both backends) and ragged lengths straddling a
    block boundary."""
    c = _gqa_cfg(cfg, n_kv)
    params = _f32(get_model(c).init(jax.random.PRNGKey(5)))
    B, block, maxb = 4, 8, 3
    rows = B * maxb + 1
    scratch = rows - 1
    rs = np.random.RandomState(6)
    shape = (c.n_layers, rows, block, n_kv, c.head_dim)
    pk = jnp.asarray(rs.standard_normal(shape) * 0.1, jnp.float32)
    pv = jnp.asarray(rs.standard_normal(shape) * 0.1, jnp.float32)
    tables = jnp.asarray(
        rs.permutation(rows - 1)[:B * maxb].reshape(B, maxb), jnp.int32)
    lengths = jnp.asarray([0, 7, 8, 15], jnp.int32)   # ragged + straddling
    active = jnp.asarray([True, True, False, True])
    tokens = jnp.asarray(rs.randint(0, c.vocab_size, (B, 1)), jnp.int32)

    lg_x, pk_x, pv_x = xla_paged_extend(c, params, pk, pv, tables, lengths,
                                        active, tokens, scratch)
    lg_f, pk_f, pv_f = fused_paged_extend(c, params, pk, pv, tables, lengths,
                                          active, tokens, scratch,
                                          interpret=True)
    np.testing.assert_allclose(np.asarray(lg_f), np.asarray(lg_x),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(pk_f), np.asarray(pk_x),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(pv_f), np.asarray(pv_x),
                               rtol=2e-4, atol=2e-4)
    # the inactive lane's own blocks are untouched; only scratch absorbed it
    lane = 2
    for row in np.asarray(tables)[lane]:
        np.testing.assert_array_equal(np.asarray(pk_f[:, row]),
                                      np.asarray(pk[:, row]))


# ----------------------------------------------------------- backend seam
def test_backend_seam_selection_and_validation(cfg):
    runner = make_runner(cfg, scratch_row=7, backend="fused")
    assert runner.backend_name == "fused"
    assert isinstance(runner.backend, FusedPagedBackend)
    assert isinstance(make_runner(cfg, 7).backend, XlaPagedBackend)
    # instance passthrough
    be = FusedPagedBackend(cfg, 7, interpret=True)
    assert make_backend(be, cfg, 7) is be
    with pytest.raises(ValueError, match="unknown backend"):
        make_runner(cfg, 7, backend="dataflow")
    # g>1 under the fused backend falls back to the XLA body (speculative
    # verify) — both callables must exist
    assert be.extend_fn(4, 1) is not None and be.extend_fn(4, 3) is not None
    assert be.kernel_hbm_bytes(4, 3, 8) == fused_kernel_hbm_bytes(
        cfg, 4, 3, 8)


def test_fused_backend_rejects_unsupported_families(cfg):
    for bad in (dataclasses.replace(cfg, qkv_bias=True),
                dataclasses.replace(cfg, act="gelu"),
                dataclasses.replace(cfg, norm="layer")):
        with pytest.raises(ValueError, match="backend='xla'"):
            FusedPagedBackend(bad, 0)
    # the seam surfaces the same error through the engine constructor


# ------------------------------------------------- engine drains (f32)
def _mk_coe_f32(cfg, n_experts=2):
    m = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    experts = [_f32(m.init(jax.random.fold_in(rng, i)))
               for i in range(n_experts)]
    nbytes = sum(x.nbytes for x in jax.tree.leaves(experts[0]))
    coe = CompositionOfExperts(HashRouter(n_experts), None, int(5 * nbytes))
    for i, h in enumerate(experts):
        coe.register(ExpertHandle(f"e{i}", cfg, h))
    return coe


def _drain(cfg, backend, policy=None, n=6):
    """Fresh engine + fixed request trace -> {rid: tokens}. f32 weights and
    f32 KV: the regime where fused and xla are token-identical."""
    coe = _mk_coe_f32(cfg)
    eng = ServingEngine(coe, cfg, max_len=32, n_slots=3, block_size=8,
                        backend=backend, kv_dtype=jnp.float32,
                        policy=policy() if policy else None)
    rs = np.random.RandomState(7)
    for i in range(n):
        # ragged prompts so lengths straddle block boundaries mid-drain
        eng.submit(Request(rid=i, tokens=rs.randint(
            0, cfg.vocab_size, (5 + 3 * (i % 3),)).astype(np.int32),
            max_new_tokens=3 + i % 4))
    done = eng.drain()
    assert eng.pool.stats.blocks_in_use == 0
    return {r.rid: r.output for r in done}, eng


def test_greedy_drain_token_identical_across_backends(cfg):
    """The acceptance claim: at f32, fused and xla greedy token streams are
    byte-identical, request for request."""
    xla, _ = _drain(cfg, "xla")
    fused, eng = _drain(cfg, "fused")
    assert xla.keys() == fused.keys()
    for rid in xla:
        np.testing.assert_array_equal(xla[rid], fused[rid]), rid
    assert eng.runner.backend_name == "fused"


def test_speculative_drain_identical_across_backends(cfg):
    """Speculative emitted tokens come from the g>1 verify step — the XLA
    body under BOTH backends — so the streams match; the fused backend only
    accelerates the single-token draft loop."""
    d_cfg = dataclasses.replace(cfg, n_layers=2)

    def policy():
        d_host = _f32(get_model(d_cfg).init(jax.random.PRNGKey(9)))
        return SpeculativeDecode(d_cfg, d_host, gamma=3)

    xla, _ = _drain(cfg, "xla", policy=policy, n=4)
    fused, eng = _drain(cfg, "fused", policy=policy, n=4)
    for rid in xla:
        np.testing.assert_array_equal(xla[rid], fused[rid]), rid
    # the draft runner inherited the engine's backend through the seam
    assert eng.policy.d_runner.backend_name == "fused"


def test_device_table_cache_reuses_arrays(cfg):
    """Satellite (b): per-round host->device uploads are cached behind the
    pool's version counters — identical slot state yields the SAME device
    arrays, and mutation bumps the version."""
    coe = _mk_coe_f32(cfg)
    eng = ServingEngine(coe, cfg, max_len=32, n_slots=2, block_size=8)
    eng.submit(Request(rid=0, tokens=np.arange(6, dtype=np.int32),
                       max_new_tokens=4))
    eng.step()
    t1, l1 = eng._device_tables()
    t2, l2 = eng._device_tables()
    assert t1 is t2 and l1 is l2
    v_tab, v_len = eng.pool.table_version, eng.pool.length_version
    eng.step()                       # advances lengths (maybe allocs blocks)
    assert eng.pool.length_version > v_len
    t3, l3 = eng._device_tables()
    assert l3 is not l1
    if eng.pool.table_version == v_tab:      # no new block this round
        assert t3 is t1                      # table upload skipped entirely
    act = np.array([True, False])
    a1 = eng._device_active(act)
    a2 = eng._device_active(act.copy())
    assert a1 is a2
    eng.drain()
    assert eng.pool.stats.blocks_in_use == 0


# --------------------------------------------------- TP fused path (TP=2)
def test_tp2_fused_drain_matches_xla(cfg):
    """node/execution.py shard_map fused path: TP=2 greedy drains are
    token-identical to the TP=2 XLA backend at f32 (subprocess so the
    emulated 2-device env is set before jax imports)."""
    import os
    import pathlib
    import subprocess
    import sys
    import textwrap

    root = str(pathlib.Path(__file__).resolve().parent.parent)
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced, pad_for_tp
        from repro.core import CompositionOfExperts, ExpertHandle, HashRouter
        from repro.launch.mesh import make_device_mesh
        from repro.models import get_model
        from repro.node.execution import make_group_engine
        from repro.serving import Request

        cfg = pad_for_tp(reduced(get_config("samba-coe-expert-7b")), 2)
        f32 = lambda t: jax.tree.map(
            lambda x: np.asarray(x, np.float32)
            if x.dtype == jnp.bfloat16 else np.asarray(x), t)
        experts = [f32(get_model(cfg).init(jax.random.PRNGKey(i)))
                   for i in range(2)]
        nbytes = sum(x.nbytes for x in jax.tree.leaves(experts[0]))
        mesh = make_device_mesh((2,), ("model",), jax.devices()[:2])

        def drain(backend):
            coe = CompositionOfExperts(HashRouter(2), None, int(5 * nbytes))
            for i, h in enumerate(experts):
                coe.register(ExpertHandle(f"e{i}", cfg, h))
            eng = make_group_engine(coe, cfg, mesh, max_len=32, n_slots=2,
                                    block_size=8, backend=backend,
                                    kv_dtype=jnp.float32)
            assert eng.runner.backend_name == backend
            rs = np.random.RandomState(11)
            for i in range(4):
                eng.submit(Request(rid=i, tokens=rs.randint(
                    0, cfg.vocab_size, (5 + 2 * (i % 2),)).astype(np.int32),
                    max_new_tokens=3 + i % 3))
            done = {r.rid: r.output for r in eng.drain()}
            assert eng.pool.stats.blocks_in_use == 0
            return done

        xla, fused = drain("xla"), drain("fused")
        assert xla.keys() == fused.keys()
        for rid in xla:
            assert (xla[rid] == fused[rid]).all(), rid
        print("TP2_PARITY_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": os.environ["PATH"],
                            "HOME": os.environ.get("HOME", "/root"),
                            "JAX_PLATFORMS": "cpu"},
                       cwd=root)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "TP2_PARITY_OK" in r.stdout
