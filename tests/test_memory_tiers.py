"""Property tests for the static allocator + placement planner of
``core/memory_tiers.py`` (paper §V-A): lifetime-disjoint address sharing
never overlaps two *live* symbols, and the spill decisions of
``plan_placement`` follow the bandwidth-aware ``transfer_footprint``
ordering exactly (ISSUE-4 satellite)."""
import dataclasses

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (Symbol, allocate_static, plan_hbm_budget,
                        plan_placement, spill_order)

ALIGN = 512


@st.composite
def _symbols(draw):
    """Dense lifetimes + mixed sizes: maximizes address-sharing pressure."""
    n = draw(st.integers(2, 16))
    syms = []
    for i in range(n):
        first = draw(st.integers(0, 6))
        last = first + draw(st.integers(0, 4))
        size = draw(st.integers(1, 1 << 14))
        # distinct footprints so the expected spill order is unambiguous
        foot = draw(st.integers(0, 1 << 16)) * n + i
        syms.append(Symbol(f"s{i}", size, first, last,
                           transfer_footprint=foot))
    return syms


def _rounded(size):
    return (size + ALIGN - 1) // ALIGN * ALIGN


@given(_symbols())
@settings(max_examples=80, deadline=None)
def test_allocate_static_never_overlaps_live_lifetimes(syms):
    alloc = allocate_static(syms, align=ALIGN)
    spans = {s.name: (alloc.offsets[s.name],
                      alloc.offsets[s.name] + _rounded(s.size)) for s in syms}
    for i, a in enumerate(syms):
        for b in syms[i + 1:]:
            if a.last_use < b.first_use or b.last_use < a.first_use:
                continue                       # disjoint lifetimes may share
            (a0, a1), (b0, b1) = spans[a.name], spans[b.name]
            assert a1 <= b0 or b1 <= a0, (
                f"live overlap: {a.name}{spans[a.name]} vs "
                f"{b.name}{spans[b.name]}")
    assert alloc.peak <= sum(_rounded(s.size) for s in syms)
    assert all(off % ALIGN == 0 for off in alloc.offsets.values())


@given(_symbols(), st.integers(0, 1 << 15))
@settings(max_examples=80, deadline=None)
def test_plan_placement_spills_in_transfer_footprint_order(syms, cap_kib):
    hbm_capacity = cap_kib * 4                 # sweeps none..all spilled
    alloc, spilled = plan_placement(syms, hbm_capacity, align=ALIGN)
    assert alloc.peak <= hbm_capacity or not spilled or (
        len(spilled) == len(syms))             # everything spilled: peak 0
    if len(spilled) == len(syms):
        assert alloc.peak == 0
    # the spill sequence is EXACTLY the lowest-transfer-footprint prefix —
    # weights (high reuse) stay in HBM, low-reuse intermediates go first
    expected = [s.name for s in spill_order(syms)]
    assert spilled == expected[: len(spilled)]
    # every resident symbol out-ranks every spilled one by footprint
    by_name = {s.name: s for s in syms}
    resident = [n for n in alloc.offsets if n not in spilled]
    if spilled and resident:
        max_spilled = max(by_name[n].transfer_footprint for n in spilled)
        min_resident = min(by_name[n].transfer_footprint for n in resident)
        assert max_spilled <= min_resident


@given(_symbols())
@settings(max_examples=40, deadline=None)
def test_plan_placement_resident_allocation_stays_disjoint(syms):
    """Spilling must not break the allocator invariant for what remains."""
    cap = _rounded(max(s.size for s in syms)) * 2
    alloc, spilled = plan_placement(syms, cap, align=ALIGN)
    live = [s for s in syms if s.name not in spilled]
    spans = {s.name: (alloc.offsets[s.name],
                      alloc.offsets[s.name] + _rounded(s.size)) for s in live}
    for i, a in enumerate(live):
        for b in live[i + 1:]:
            if a.last_use < b.first_use or b.last_use < a.first_use:
                continue
            (a0, a1), (b0, b1) = spans[a.name], spans[b.name]
            assert a1 <= b0 or b1 <= a0


@given(st.integers(1, 64), st.integers(1, 8), st.integers(1, 4),
       st.integers(0, 10))
@settings(max_examples=60, deadline=None)
def test_plan_hbm_budget_invariants(total_mb, expert_mb, block_kb, kv_tenths):
    MB, KB = 1 << 20, 1 << 10
    total, expert, block = total_mb * MB, expert_mb * MB, block_kb * KB
    kv_fraction = kv_tenths / 10.0
    feasible = total >= 2 * expert + block
    if not feasible:
        with pytest.raises(MemoryError):
            plan_hbm_budget(total, expert, block, kv_fraction=kv_fraction)
        return
    b = plan_hbm_budget(total, expert, block, kv_fraction=kv_fraction)
    assert b.weights_bytes + b.kv_bytes == b.total_bytes == total
    assert b.kv_bytes >= block                 # at least one KV block
    assert b.weights_bytes >= 2 * expert       # active + prefetch target
    assert b.resident_experts(expert) >= 2
