"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and no NaNs (deliverable f)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, CONFIGS, get_config, reduced
from repro.distributed import stepfn
from repro.launch.mesh import single_device_mesh
from repro.models import get_model
from repro.optim import init_opt_state


@pytest.mark.parametrize("arch", sorted(CONFIGS.keys()))
def test_forward_shapes_no_nan(arch, rng):
    cfg = reduced(get_config(arch))
    m = get_model(cfg)
    params = m.init(rng)
    B, S = 2, 32
    batch = {"tokens": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jnp.ones((B, cfg.encoder_seq, cfg.d_model),
                                       jnp.bfloat16)
    logits = m.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", sorted(ARCH_IDS))
def test_train_step_decreases_loss_shapewise(arch, rng):
    cfg = reduced(get_config(arch))
    mesh = single_device_mesh()
    with mesh:
        step_fn, state_sh, _ = stepfn.make_train_step(cfg, mesh)
        m = get_model(cfg)
        params = m.init(rng)
        state = jax.device_put({"params": params,
                                "opt": init_opt_state(params)}, state_sh)
        B, S = 2, 32
        toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)
        batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        if cfg.family == "encdec":
            batch["enc_embeds"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model),
                                            jnp.bfloat16)
        state, metrics = step_fn(state, batch)
        loss0 = float(metrics["loss"])
        state, metrics = step_fn(state, batch)
        loss1 = float(metrics["loss"])
    assert np.isfinite(loss0) and np.isfinite(loss1)
    assert loss1 < loss0 + 0.1       # same batch twice: should not increase


@pytest.mark.parametrize("arch", sorted(ARCH_IDS))
def test_prefill_decode_consistency(arch, rng):
    """Prefill+decode logits must match teacher-forced forward."""
    cfg = reduced(get_config(arch))
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)  # no drops
    m = get_model(cfg)
    params = m.init(rng)
    B, S, extra = 2, 16, 3
    toks = jax.random.randint(jax.random.fold_in(rng, 1), (B, S + extra),
                              0, cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            jax.random.fold_in(rng, 2),
            (B, cfg.encoder_seq, cfg.d_model)).astype(jnp.bfloat16)
    fb = dict(batch, tokens=toks[:, :S + extra])
    full = m.forward(params, fb).astype(jnp.float32)
    scale = float(jnp.max(jnp.abs(full))) + 1e-6

    last, cache = m.prefill(params, batch, max_len=S + extra + 4)
    errs = [float(jnp.max(jnp.abs(last.astype(jnp.float32) - full[:, S - 1])))]
    for t in range(extra):
        lg, cache = m.decode_step(params, cache, toks[:, S + t:S + t + 1],
                                  jnp.int32(S + t))
        errs.append(float(jnp.max(jnp.abs(
            lg.astype(jnp.float32) - full[:, S + t]))))
    assert max(errs) / scale < 0.05, (errs, scale)


def test_swa_ring_cache_matches_full(rng):
    """SWA ring-buffer decode == full-cache decode inside the window."""
    cfg = dataclasses.replace(reduced(get_config("mixtral-8x7b")),
                              capacity_factor=16.0, sliding_window=24)
    m = get_model(cfg)
    params = m.init(rng)
    B, S = 1, 40                      # prefill longer than the window
    toks = jax.random.randint(rng, (B, S + 2), 0, cfg.vocab_size)
    full = m.forward(params, {"tokens": toks}).astype(jnp.float32)
    last, cache = m.prefill(params, {"tokens": toks[:, :S]}, max_len=S + 8)
    lg, cache = m.decode_step(params, cache, toks[:, S:S + 1], jnp.int32(S))
    err = float(jnp.max(jnp.abs(lg.astype(jnp.float32) - full[:, S])))
    assert err / (float(jnp.max(jnp.abs(full))) + 1e-6) < 0.05


def test_vlm_patch_embeds_path(rng):
    cfg = reduced(get_config("qwen2-vl-2b"))
    m = get_model(cfg)
    params = m.init(rng)
    B, S, P_ = 2, 32, 8
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "patch_embeds": jnp.ones((B, P_, cfg.d_model), jnp.bfloat16)}
    logits = m.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
