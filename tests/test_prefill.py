"""AOT bucketed packed prefill (ISSUE 8).

Covers: bucket-selection and packing-plan properties (hypothesis, or the
deterministic fallback in tests/_hypothesis_stub.py), packing never mixing
tokens across segment boundaries, bit-equality of the packed segment-masked
forward against per-prompt sequential ``prefill_kv`` at f32, engine-level
packed-vs-sequential drain parity, the zero-recompile-after-warmup
invariant on a mixed-length burst for both decode backends, and the
TTFT-histogram / bucket-counter observability series.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config, reduced
from repro.core import CompositionOfExperts, ExpertHandle, HashRouter
from repro.models import get_model
from repro.obs.metrics import MetricsRegistry
from repro.serving import (PackedPrefillRunner, Request, ServingEngine,
                           bucket_for, compile_count, compile_counts,
                           default_buckets, plan_packs,
                           reset_compile_counts)
from repro.serving.backends import PagedDecodeRunner

_CFG = None


def _cfg():
    """Lazy module-level config: hypothesis-wrapped tests can't take pytest
    fixtures through the deterministic stub (its wrapper hides positional
    params from fixture resolution)."""
    global _CFG
    if _CFG is None:
        _CFG = reduced(get_config("samba-coe-expert-7b"))
    return _CFG


@pytest.fixture(scope="module")
def cfg():
    return _cfg()


@pytest.fixture(scope="module")
def experts(cfg):
    m = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    return [jax.tree.map(np.asarray, m.init(jax.random.fold_in(rng, i)))
            for i in range(2)]


@pytest.fixture(scope="module")
def params_f32(experts):
    return jax.tree.map(
        lambda x: np.asarray(x, np.float32)
        if x.dtype == jnp.bfloat16 else np.asarray(x), experts[0])


def _mk_coe(cfg, experts, capacity_experts=2.5):
    nbytes = sum(x.nbytes for x in jax.tree.leaves(experts[0]))
    coe = CompositionOfExperts(HashRouter(len(experts)), None,
                               int(capacity_experts * nbytes))
    for i, h in enumerate(experts):
        coe.register(ExpertHandle(f"e{i}", cfg, h))
    return coe


# ------------------------------------------------------- bucket selection
def test_default_buckets_powers_of_two():
    for m in (1, 15, 16, 17, 100, 4096):
        bks = default_buckets(m)
        assert bks[-1] >= m                    # covers max_len
        assert bks[0] == 16
        assert all(b == 2 * a for a, b in zip(bks, bks[1:]))
        # minimal: dropping the last bucket would uncover max_len
        assert len(bks) == 1 or bks[-2] < m
    with pytest.raises(ValueError):
        default_buckets(0)


@settings(max_examples=200)
@given(st.integers(min_value=1, max_value=4096))
def test_bucket_for_smallest_cover(n):
    """Every length maps to the SMALLEST bucket covering it."""
    buckets = default_buckets(4096)
    b = bucket_for(n, buckets)
    assert b >= n
    for x in buckets:
        if x < b:                              # every smaller bucket is
            assert x < n                       # too small for n
    with pytest.raises(ValueError):
        bucket_for(buckets[-1] + 1, buckets)


@settings(max_examples=100)
@given(st.lists(st.integers(min_value=1, max_value=64),
                min_size=1, max_size=30),
       st.integers(min_value=1, max_value=8))
def test_plan_packs_order_capacity_maximality(lengths, max_segments):
    buckets = default_buckets(64)
    packs = plan_packs(lengths, buckets, max_segments)
    flat = [i for p in packs for i in p]
    assert flat == list(range(len(lengths)))   # in order, nothing dropped
    for p in packs:
        assert 1 <= len(p) <= max_segments
        assert sum(lengths[i] for i in p) <= buckets[-1]
    # greedy maximality: a pack closes only because the next prompt would
    # overflow the largest bucket or the segment budget
    for p, q in zip(packs, packs[1:]):
        assert (len(p) == max_segments
                or sum(lengths[i] for i in p) + lengths[q[0]] > buckets[-1])


@settings(max_examples=25)
@given(st.lists(st.integers(min_value=1, max_value=20),
                min_size=1, max_size=4))
def test_pack_never_mixes_tokens_across_segments(lengths):
    """``pack`` gives every prompt its own contiguous span with its own
    segment id and per-segment restarting positions; padding carries a
    DISTINCT id (``max_segments``) so no pad token can attend (or be
    attended by) any real token."""
    runner = PackedPrefillRunner(_cfg(), buckets=default_buckets(128),
                                 max_segments=4)
    prompts = [np.full((n,), i + 1, np.int32) for i, n in enumerate(lengths)]
    toks, seg, pos, last, spans, bucket = runner.pack(prompts)
    assert bucket == bucket_for(sum(lengths), runner.buckets)
    off = 0
    for i, n in enumerate(lengths):
        assert spans[i] == (off, n)
        assert (toks[0, off:off + n] == i + 1).all()
        assert (seg[0, off:off + n] == i).all()
        assert (pos[0, off:off + n] == np.arange(n)).all()
        assert last[i] == off + n - 1
        off += n
    assert (seg[0, off:] == runner.max_segments).all()   # pad: own segment


# --------------------------------------------------- forward bit-equality
def test_packed_prefill_bit_equal_sequential_f32(cfg, params_f32):
    """Packed segment-masked forward == per-prompt sequential ``prefill_kv``
    BIT-FOR-BIT at f32: logits of every prompt's last token and the full
    per-prompt K/V slices. Masked cross-segment scores contribute exact
    zeros, so packing is not an approximation."""
    runner = PackedPrefillRunner(cfg, buckets=default_buckets(64),
                                 max_segments=4)
    seq = PagedDecodeRunner(cfg, scratch_row=0)
    rs = np.random.RandomState(0)
    prompts = [rs.randint(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (7, 11, 5, 3)]
    res = runner(params_f32, prompts)
    assert res.bucket == 32                    # sum=26 -> bucket 32
    for i, p in enumerate(prompts):
        last, k, v = seq.prefill_kv(params_f32, jnp.asarray(p[None]))
        off, n = res.spans[i]
        assert np.array_equal(np.asarray(res.logits[i]),
                              np.asarray(last)), f"prompt {i}: logits"
        assert np.array_equal(np.asarray(res.k[:, off:off + n]),
                              np.asarray(k)), f"prompt {i}: K"
        assert np.array_equal(np.asarray(res.v[:, off:off + n]),
                              np.asarray(v)), f"prompt {i}: V"


# -------------------------------------------------- engine drain parity
def test_engine_packed_matches_sequential_drain(cfg, experts):
    """A mixed-length drain through ``prefill_mode='packed'`` produces the
    SAME token streams as ``prefill_mode='sequential'`` (bf16 engine
    default), and the packed engine emits the TTFT histogram and bucket
    counters."""
    rs = np.random.RandomState(7)
    lens = [3, 17, 9, 25, 5, 12, 7, 20, 4]
    prompts = [rs.randint(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in lens]

    def run(mode):
        reg = MetricsRegistry()
        coe = _mk_coe(cfg, experts)
        eng = ServingEngine(coe, cfg, max_len=40, n_slots=3, block_size=8,
                            prefill_mode=mode, registry=reg)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, tokens=p, max_new_tokens=2 + i % 4))
        done = eng.drain()
        assert len(done) == len(prompts)
        assert eng.pool.stats.blocks_in_use == 0
        return {r.rid: r.output for r in done}, reg.snapshot(), done

    packed, snap, done = run("packed")
    sequential, seq_snap, _ = run("sequential")
    assert all((packed[i] == sequential[i]).all() for i in packed)
    # every request got exactly one TTFT observation, in both modes
    assert snap["serve.ttft_s:count"] == len(prompts)
    assert seq_snap["serve.ttft_s:count"] == len(prompts)
    assert snap["serve.ttft_s:p99"] >= snap["serve.ttft_s:p50"] > 0
    for r in done:                             # stamps ordered per request
        assert r.arrival_s <= r.prefill_done_s <= r.first_token_s
    # packed admission labels REAL buckets; counts sum to the request count
    packed_counts = {k: v for k, v in snap.items()
                     if k.startswith("serve.prefill_bucket")}
    assert sum(packed_counts.values()) == len(prompts)
    assert all(f"bucket={b}" in k for k in packed_counts
               for b in [int(k.split("bucket=")[1].rstrip("}"))]
               if b in default_buckets(40))


# -------------------------------------------- recompile regression gate
@pytest.mark.parametrize("backend", [
    "xla", pytest.param("fused", marks=pytest.mark.slow)])
def test_zero_recompiles_after_warmup_mixed_burst(cfg, experts, backend):
    """THE tentpole invariant: after ``warmup()`` a 200-request drain with
    adversarially mixed prompt lengths triggers ZERO new XLA compilations —
    every compile site in the serving path (packed prefill, pool scatter,
    sequential prefill, decode extend) reports through
    ``prefill.record_compile``, so a silent recompile cannot hide."""
    coe = _mk_coe(cfg, experts)
    eng = ServingEngine(coe, cfg, max_len=48, n_slots=4, block_size=8,
                        backend=backend)
    eng.warmup()
    reset_compile_counts()
    rs = np.random.RandomState(11)
    n = 200
    done = []
    for i in range(n):
        L = int(rs.randint(1, 37))             # 36 distinct lengths
        eng.submit(Request(
            rid=i, tokens=rs.randint(0, cfg.vocab_size, (L,)).astype(np.int32),
            max_new_tokens=1 + int(rs.randint(0, 3))))
        if i % 5 == 4:                         # interleave decode + admits
            done.extend(eng.step())
    done.extend(eng.drain())
    assert len(done) == n
    assert eng.pool.stats.blocks_in_use == 0
    assert compile_count() == 0, (
        f"post-warmup XLA compilations detected: {compile_counts()}")


def test_sequential_mode_counts_recompiles(cfg, experts):
    """The control for the test above: the sequential path DOES recompile
    per novel prompt length — proving the counter hook actually observes
    the serving path rather than trivially reading zero."""
    coe = _mk_coe(cfg, experts)
    eng = ServingEngine(coe, cfg, max_len=32, n_slots=2, block_size=8,
                        prefill_mode="sequential")
    eng.warmup()
    reset_compile_counts()
    rs = np.random.RandomState(3)
    for i, L in enumerate((5, 9, 13)):         # three novel lengths
        eng.submit(Request(
            rid=i, tokens=rs.randint(0, cfg.vocab_size, (L,)).astype(np.int32),
            max_new_tokens=2))
    eng.drain()
    assert compile_count("prefill_kv") == 3
