"""Minimal hypothesis-compatible fallback used when the real ``hypothesis``
package is not installed (the CI/container baseline ships without it).

Implements exactly the surface this test suite uses — ``given``, ``settings``
and ``strategies.integers/lists/sampled_from/composite`` — as deterministic
random sampling (seeded PRNG, ``max_examples`` draws per test). No shrinking,
no database; a failing example fails the test directly with its drawn values
in the traceback.
"""
from __future__ import annotations

import random
import types


class Strategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn

    def example(self, rnd: random.Random):
        return self._draw(rnd)


def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(lambda rnd: rnd.randint(min_value, max_value))


def sampled_from(elements) -> Strategy:
    elements = list(elements)
    return Strategy(lambda rnd: rnd.choice(elements))


def lists(elements: Strategy, min_size: int = 0, max_size: int = 10) -> Strategy:
    def draw(rnd):
        n = rnd.randint(min_size, max_size)
        return [elements.example(rnd) for _ in range(n)]
    return Strategy(draw)


def composite(fn):
    """@st.composite: fn(draw, *args) -> value becomes fn(*args) -> Strategy."""
    def build(*args, **kwargs):
        def draw_fn(rnd):
            return fn(lambda s: s.example(rnd), *args, **kwargs)
        return Strategy(draw_fn)
    return build


def settings(max_examples: int = 25, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strats: Strategy):
    def deco(fn):
        n = getattr(fn, "_stub_max_examples", 25)

        # NOTE: signature must expose no positional params — pytest would
        # otherwise try to resolve the wrapped test's drawn args as fixtures.
        def wrapper(**kwargs):
            rnd = random.Random(0)
            for _ in range(n):
                fn(*[s.example(rnd) for s in strats], **kwargs)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco


strategies = types.ModuleType("hypothesis.strategies")
strategies.Strategy = Strategy
strategies.integers = integers
strategies.lists = lists
strategies.sampled_from = sampled_from
strategies.composite = composite
